fn main() {
    use feddart::util::base64::{encode_f32, decode_f32};
    let v: Vec<f32> = (0..436736).map(|i| (i as f32).sin()).collect();
    let t0 = std::time::Instant::now();
    let mut s = String::new();
    for _ in 0..20 { s = encode_f32(&v); }
    let enc = t0.elapsed() / 20;
    let t0 = std::time::Instant::now();
    let mut back = Vec::new();
    for _ in 0..20 { back = decode_f32(&s).unwrap(); }
    let dec = t0.elapsed() / 20;
    assert_eq!(back, v);
    let mb = (v.len() * 4) as f64 / 1e6;
    println!("encode: {:?} ({:.0} MB/s)  decode: {:?} ({:.0} MB/s)",
             enc, mb / enc.as_secs_f64(), dec, mb / dec.as_secs_f64());
}
