//! Personalized FL with clustering (paper §1.2, §2.2.1, Alg 4).
//!
//! Twelve clients belong to three hidden groups whose label spaces are
//! permuted — one global model cannot fit all of them.  FACT's clustered
//! FL trains a warmup round, reclusters clients by their local updates
//! (k-means), and then trains one global model *per cluster*.
//!
//! ```bash
//! make artifacts && cargo run --release --example heterogeneous_clustering
//! ```

use std::sync::Arc;

use feddart::coordinator::WorkflowManager;
use feddart::dart::TaskRegistry;
use feddart::fact::clustering::{ClusterContainer, KMeansClustering};
use feddart::fact::data::{synthesize, Partition, SyntheticConfig};
use feddart::fact::model::{FactModel, HloModel, Hyper};
use feddart::fact::stopping::{FixedClusteringRounds, FixedRoundFl};
use feddart::fact::{Aggregation, FactClientRuntime, FactServer};
use feddart::metrics::logserver::LogServer;
use feddart::runtime::{default_artifacts_dir, Engine};

const GROUPS: usize = 3;
const CLIENTS: usize = 12;

fn build(engine: &Engine) -> feddart::Result<(FactServer, Arc<dyn FactModel>)> {
    let registry = TaskRegistry::new();
    let rt = FactClientRuntime::new(engine.clone());
    let data = synthesize(&SyntheticConfig {
        clients: CLIENTS,
        samples_per_client: 512,
        dim: 32,
        classes: 10,
        partition: Partition::LatentGroups { groups: GROUPS },
        seed: 11,
    })?;
    for (name, d) in data {
        rt.add_supervised(&name, d);
    }
    rt.register(&registry);
    let wm = WorkflowManager::test_mode(CLIENTS, registry, 4);
    let model = HloModel::arc(engine, "mlp_default", Aggregation::WeightedFedAvg)?;
    let server = FactServer::new(wm)
        .with_hyper(Hyper { lr: 0.2, mu: 0.0, local_steps: 4, round: 0 });
    Ok((server, model))
}

fn main() -> feddart::Result<()> {
    LogServer::init(log::LevelFilter::Warn);
    let engine = Engine::load(&default_artifacts_dir(), 1)?;

    // Baseline: one global model for everyone.
    let (mut single, model) = build(&engine)?;
    single.initialization_by_model(Arc::clone(&model), Arc::new(FixedRoundFl(12)), 1)?;
    single.learn()?;
    let acc_single = single.evaluate()?[0].accuracy;
    println!("single global model accuracy: {acc_single:.3}");

    // Personalized: warmup -> k-means on client updates -> per-cluster FL.
    let (mut clustered, model2) = build(&engine)?;
    let names = clustered.workflow_manager().get_all_device_names()?;
    let container =
        ClusterContainer::single(Arc::clone(&model2), model2.init_params(1)?, names);
    clustered.initialization_by_cluster_container(
        container,
        Box::new(KMeansClustering::new(GROUPS)),
        Box::new(FixedClusteringRounds(2)),
        Arc::new(FixedRoundFl(6)),
    )?;
    clustered.learn()?;

    println!("\ndiscovered clusters:");
    for c in &clustered.container().clusters {
        println!("  cluster {}: {:?}", c.id, c.clients);
    }
    let evals = clustered.evaluate()?;
    let mut weighted = 0.0;
    for e in &evals {
        println!(
            "  cluster {}: accuracy {:.3} over {} clients",
            e.cluster_id, e.accuracy, e.n_clients
        );
        weighted += e.accuracy * e.n_clients as f64;
    }
    println!(
        "\npersonalized accuracy {:.3} vs single-global {acc_single:.3}",
        weighted / CLIENTS as f64
    );
    engine.shutdown();
    Ok(())
}
