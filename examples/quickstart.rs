//! Quickstart: federated training of an MLP classifier in local test mode.
//!
//! This is the paper's §3 workflow end to end: synthesize per-client data,
//! register the `@feddart` client functions, start the simulated DART
//! runtime, initialize the FACT Server with a model + stopping criterion,
//! call `learn()`, and evaluate.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use feddart::coordinator::WorkflowManager;
use feddart::dart::TaskRegistry;
use feddart::fact::data::{synthesize, Partition, SyntheticConfig};
use feddart::fact::model::{HloModel, Hyper};
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::{Aggregation, FactClientRuntime, FactServer};
use feddart::metrics::logserver::LogServer;
use feddart::runtime::{default_artifacts_dir, Engine};

fn main() -> feddart::Result<()> {
    LogServer::init(log::LevelFilter::Warn);

    // 1. The AOT-compiled compute (JAX + Pallas, built by `make artifacts`).
    let engine = Engine::load(&default_artifacts_dir(), 1)?;

    // 2. Client side: local data + the predefined @feddart functions.
    //    (In production each physical client runs this; in test mode one
    //    process hosts all of them — same code, paper §3.)
    let clients = 8;
    let registry = TaskRegistry::new();
    let client_rt = FactClientRuntime::new(engine.clone());
    let data = synthesize(&SyntheticConfig {
        clients,
        samples_per_client: 512,
        dim: 32,
        classes: 10,
        partition: Partition::Iid,
        seed: 42,
    })?;
    for (name, d) in data {
        client_rt.add_supervised(&name, d);
    }
    client_rt.register(&registry);

    // 3. The Fed-DART runtime in test mode (simulated DART-server+clients).
    let wm = WorkflowManager::test_mode(clients, registry, 4);

    // 4. The FACT Server: model + aggregation + stopping criterion.
    let mut server = FactServer::new(wm)
        .with_hyper(Hyper { lr: 0.2, mu: 0.0, local_steps: 4, round: 0 });
    let model = HloModel::arc(&engine, "mlp_default", Aggregation::WeightedFedAvg)?;
    server.initialization_by_model(model, Arc::new(FixedRoundFl(20)), 42)?;

    // 5. Train.
    server.learn()?;

    println!("round  mean_client_loss");
    for r in server.history() {
        println!("{:>5}  {:.4}", r.round, r.mean_loss);
    }

    // 6. Evaluate the global model on every client's held-out data.
    for e in server.evaluate()? {
        println!(
            "\nheld-out: loss {:.4}, accuracy {:.3} (chance would be 0.100)",
            e.loss, e.accuracy
        );
    }
    engine.shutdown();
    Ok(())
}
