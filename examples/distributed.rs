//! The full production topology in one process (paper §2.1.1, Figure 2):
//! a real DART-server (authenticated TCP + REST https-server role), four
//! DART-clients connecting over sockets, and the aggregation component
//! driving federated training through the REST-API — exactly what
//! `feddart server` / `feddart client` / `feddart train` do across
//! machines.
//!
//! ```bash
//! make artifacts && cargo run --release --example distributed
//! ```

use std::sync::Arc;
use std::time::Duration;

use feddart::config::ServerConfig;
use feddart::coordinator::WorkflowManager;
use feddart::dart::client::{DartClient, DartClientConfig};
use feddart::dart::rest::RestDartApi;
use feddart::dart::server::{DartServer, DartServerConfig};
use feddart::dart::TaskRegistry;
use feddart::fact::data::{synthesize, Partition, SyntheticConfig};
use feddart::fact::model::{HloModel, Hyper};
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::{Aggregation, FactClientRuntime, FactServer};
use feddart::metrics::logserver::LogServer;
use feddart::runtime::{default_artifacts_dir, Engine};

fn main() -> feddart::Result<()> {
    LogServer::init(log::LevelFilter::Info);
    let engine = Engine::load(&default_artifacts_dir(), 2)?;
    let n = 4;

    // --- infrastructure: DART-server (set up once, reused across use cases)
    let dart = DartServer::start(DartServerConfig::default())?;
    println!(
        "DART-server: transport={} rest={}",
        dart.dart_addr(),
        dart.rest_addr()
    );

    // --- edge side: four DART-clients joining over TCP with the shared key
    let registry = TaskRegistry::new();
    let rt = FactClientRuntime::new(engine.clone());
    let data = synthesize(&SyntheticConfig {
        clients: n,
        samples_per_client: 512,
        dim: 32,
        classes: 10,
        partition: Partition::Iid,
        seed: 42,
    })?;
    for (name, d) in data {
        rt.add_supervised(&name, d);
    }
    rt.register(&registry);
    let _clients: Vec<DartClient> = (0..n)
        .map(|i| {
            DartClient::spawn(
                DartClientConfig::new(
                    &format!("client-{i}"),
                    &dart.dart_addr().to_string(),
                    b"feddart-demo-key",
                ),
                registry.clone(),
            )
        })
        .collect();

    // --- aggregation component: WorkflowManager over the REST-API
    let server_cfg = ServerConfig {
        server: dart.rest_addr().to_string(),
        client_key: "000".into(),
    };
    let wm = WorkflowManager::production(&server_cfg)?;
    wm.start_fed_dart(n, Duration::from_secs(10))?;
    println!("clients connected: {:?}", wm.get_all_device_names()?);

    let mut fact = FactServer::new(wm)
        .with_hyper(Hyper { lr: 0.2, mu: 0.0, local_steps: 4, round: 0 });
    let model = HloModel::arc(&engine, "mlp_default", Aggregation::WeightedFedAvg)?;
    fact.initialization_by_model(model, Arc::new(FixedRoundFl(10)), 42)?;
    fact.learn()?;

    println!("\nround  loss     round_ms");
    for r in fact.history() {
        println!("{:>5}  {:.4}  {:>8.1}", r.round, r.mean_loss, r.round_ms);
    }
    let e = &fact.evaluate()?[0];
    println!("\nfinal accuracy over REST path: {:.3}", e.accuracy);

    // server-side observability through the REST-API
    let api = RestDartApi::from_addr(&dart.rest_addr().to_string(), "000");
    let m = api.metrics()?;
    println!(
        "server metrics: units_dispatched={} units_completed={}",
        m.get("counters")
            .and_then(|c| c.get("dart.units_dispatched"))
            .and_then(feddart::json::Json::as_i64)
            .unwrap_or(0),
        m.get("counters")
            .and_then(|c| c.get("dart.units_completed"))
            .and_then(feddart::json::Json::as_i64)
            .unwrap_or(0),
    );
    engine.shutdown();
    Ok(())
}
