//! Fault tolerance demo (paper §2.1): clients drop, crash mid-task, and
//! rejoin while a federated training workflow keeps running.
//!
//! Half the clients are flaky (30% of units dropped or crashed), a quarter
//! are 3x stragglers; the scheduler's Petri-net re-queue keeps every round
//! complete and training converges anyway.
//!
//! ```bash
//! make artifacts && cargo run --release --example fault_tolerance
//! ```
//!
//! Pass a directory as the first argument to also exercise coordinator
//! fault tolerance: every round transition is appended to a WAL-backed
//! round store there, and a re-run against the same directory replays
//! finished rounds and resumes whatever a kill left in flight
//! (docs/OPERATIONS.md walks through a crash-mid-round session):
//!
//! ```bash
//! cargo run --release --example fault_tolerance -- /tmp/ft-wal
//! # kill it mid-run (ctrl-c), then run the same command again
//! ```

use std::sync::Arc;
use std::time::Duration;

use feddart::coordinator::{RoundStore, WalRoundStore, WorkflowManager};
use feddart::dart::faults::{FaultInjector, FaultProfile};
use feddart::dart::testmode::SimClient;
use feddart::dart::TaskRegistry;
use feddart::fact::data::{synthesize, Partition, SyntheticConfig};
use feddart::fact::model::{HloModel, Hyper};
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::{Aggregation, FactClientRuntime, FactServer};
use feddart::metrics::logserver::LogServer;
use feddart::runtime::{default_artifacts_dir, Engine};

fn main() -> feddart::Result<()> {
    LogServer::init(log::LevelFilter::Warn);
    let engine = Engine::load(&default_artifacts_dir(), 1)?;
    let n = 12;

    let registry = TaskRegistry::new();
    let rt = FactClientRuntime::new(engine.clone());
    let data = synthesize(&SyntheticConfig {
        clients: n,
        samples_per_client: 384,
        dim: 32,
        classes: 10,
        partition: Partition::Iid,
        seed: 3,
    })?;
    for (name, d) in data {
        rt.add_supervised(&name, d);
    }
    rt.register(&registry);

    let clients: Vec<SimClient> = (0..n)
        .map(|i| {
            let (profile, kind) = match i % 4 {
                0 | 1 => (FaultProfile::reliable(), "reliable"),
                2 => (FaultProfile::flaky(0.3), "flaky(30%)"),
                _ => (FaultProfile::straggler(3.0, 10), "straggler(3x)"),
            };
            println!("client-{i}: {kind}");
            SimClient {
                name: format!("client-{i}"),
                hardware: Default::default(),
                faults: FaultInjector::new(i as u64, profile),
                capacity: 1,
            }
        })
        .collect();

    let wal_dir = std::env::args().nth(1);
    let store = match &wal_dir {
        Some(dir) => {
            let store = Arc::new(WalRoundStore::open(dir)?);
            println!("round store: WAL at {}", store.dir().display());
            Some(store)
        }
        None => None,
    };

    let wm = WorkflowManager::test_mode_with(clients, registry, 6);
    let mut server = FactServer::new(wm)
        .with_hyper(Hyper { lr: 0.2, mu: 0.0, local_steps: 3, round: 0 });
    server.round_timeout = Duration::from_secs(300);
    if let Some(store) = &store {
        server = server.with_round_store(store.clone());
    }
    let model = HloModel::arc(&engine, "mlp_default", Aggregation::WeightedFedAvg)?;
    server.initialization_by_model(model, Arc::new(FixedRoundFl(12)), 3)?;
    if store.is_some() {
        // replay whatever a previous (killed) run left in the WAL:
        // finished rounds are skipped, in-flight ones resumed
        let rep = server.recover()?;
        if rep.replayed_records > 0 || rep.resumed > 0 {
            println!(
                "recovered from WAL: {} round(s) replayed, {} resumed",
                rep.replayed_records, rep.resumed
            );
        }
    }

    println!("\ntraining 12 rounds under churn ...");
    server.learn()?;

    println!("\nround  clients  loss     round_ms");
    for r in server.history() {
        println!(
            "{:>5}  {:>7}  {:.4}  {:>8.1}",
            r.round, r.n_clients, r.mean_loss, r.round_ms
        );
    }
    let e = &server.evaluate()?[0];
    println!(
        "\nall {} rounds completed despite churn; final accuracy {:.3}",
        server.history().len(),
        e.accuracy
    );
    if let Some(store) = &store {
        let j = store.status_json()?;
        println!(
            "round store: {} round(s) on disk, {} in flight — inspect with \
             `feddart rounds --round-store {}`",
            j.get("total").and_then(|v| v.as_usize()).unwrap_or(0),
            j.get("in_flight").and_then(|v| v.as_usize()).unwrap_or(0),
            wal_dir.as_deref().unwrap_or(".")
        );
    }
    engine.shutdown();
    Ok(())
}
