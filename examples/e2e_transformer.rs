//! END-TO-END DRIVER: federated training of a causal transformer LM.
//!
//! This is the repository's full-stack validation: a decoder-only
//! transformer (tied embeddings, Pallas dense kernels in the MLP blocks)
//! AOT-compiled from JAX to HLO, trained federated across 8 simulated
//! clients on a synthetic token corpus for a few hundred rounds, with the
//! whole Fed-DART/FACT stack (WorkflowManager -> Selector -> Scheduler ->
//! client runtime -> PJRT engine) on the path.  The loss curve is logged
//! to stdout and `e2e_loss.csv`; EXPERIMENTS.md records a reference run.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_transformer -- \
//!     --rounds 300 --clients 8 --local-steps 1
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use feddart::cli::Args;
use feddart::coordinator::WorkflowManager;
use feddart::dart::TaskRegistry;
use feddart::fact::data::{synthesize_corpus, CorpusConfig};
use feddart::fact::model::{HloModel, Hyper};
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::{Aggregation, FactClientRuntime, FactServer};
use feddart::metrics::logserver::LogServer;
use feddart::runtime::{default_artifacts_dir, Engine};

fn main() -> feddart::Result<()> {
    LogServer::init(log::LevelFilter::Warn);
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let rounds = args.opt_usize("rounds", 300)?;
    let clients = args.opt_usize("clients", 8)?;
    let local_steps = args.opt_usize("local-steps", 1)?;
    let lr = args.opt_f64("lr", 0.05)? as f32;
    let parallelism = args.opt_usize("parallelism", 4)?;
    let engine_threads = args.opt_usize("engine-threads", 4)?;

    let engine = Engine::load(&default_artifacts_dir(), engine_threads)?;
    let meta = engine.manifest().model("tfm_tiny")?.clone();
    println!(
        "model tfm_tiny: {} parameters (d={}, layers={}, seq={}, vocab={})",
        meta.param_count,
        meta.field_usize("d_model")?,
        meta.field_usize("layers")?,
        meta.field_usize("seq")?,
        meta.field_usize("vocab")?,
    );
    // warm the train entry on every engine thread before the clock starts
    for _ in 0..engine_threads {
        engine.warm(meta.entry("train")?)?;
    }

    // per-client token streams: shared grammar + per-client noise
    let registry = TaskRegistry::new();
    let rt = FactClientRuntime::new(engine.clone());
    let corpus = synthesize_corpus(&CorpusConfig {
        clients,
        tokens_per_client: 1 << 15,
        vocab: meta.field_usize("vocab")?,
        groups: 1,
        seed: 7,
    });
    for (name, c) in corpus {
        rt.add_corpus(&name, c);
    }
    rt.register(&registry);

    let wm = WorkflowManager::test_mode(clients, registry, parallelism);
    let mut server = FactServer::new(wm)
        .with_hyper(Hyper { lr, mu: 0.0, local_steps, round: 0 });
    server.round_timeout = Duration::from_secs(600);
    let model = HloModel::arc(&engine, "tfm_tiny", Aggregation::WeightedFedAvg)?;

    let t0 = Instant::now();
    server.initialization_by_model(model, Arc::new(FixedRoundFl(rounds)), 7)?;
    server.learn()?;
    let wall = t0.elapsed();

    // loss curve
    let mut csv = String::from("round,mean_loss,round_ms\n");
    println!("\nround  mean_loss  (per-token nll; log(vocab) = {:.3})",
             (meta.field_usize("vocab")? as f64).ln());
    for r in server.history() {
        csv.push_str(&format!("{},{},{}\n", r.round, r.mean_loss, r.round_ms));
        if r.round % 10 == 0 || r.round + 1 == rounds {
            println!("{:>5}  {:.4}", r.round, r.mean_loss);
        }
    }
    std::fs::write("e2e_loss.csv", csv)?;

    let ev = &server.evaluate()?[0];
    let hist = server.history();
    let (first, last) = (hist[0].mean_loss, hist.last().unwrap().mean_loss);
    let steps = rounds * clients * local_steps;
    println!("\n=== E2E summary ===");
    println!("rounds: {rounds} x {clients} clients x {local_steps} local steps = {steps} train steps");
    println!("wall: {:.1}s ({:.1} steps/s)", wall.as_secs_f64(),
             steps as f64 / wall.as_secs_f64());
    println!("train loss: {first:.4} -> {last:.4}");
    println!("held-out per-token nll: {:.4} (uniform = {:.4})",
             ev.nll_per_token, (meta.field_usize("vocab")? as f64).ln());
    println!("engine: {} executions, {:.1}s exec time, {} compiles",
             engine.stats().executions(),
             engine.stats().exec_seconds(),
             engine.stats().compiles());
    println!("loss curve written to e2e_loss.csv");
    engine.shutdown();
    Ok(())
}
