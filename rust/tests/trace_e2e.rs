//! End-to-end round tracing: one secagg+dp session traced from the FACT
//! pipeline through the DART seam to the client runtimes and back.
//!
//! Covered here:
//! * a round with one straggler (dropout) and one wire retry produces a
//!   SINGLE trace: every pipeline phase span exactly once, per-client
//!   learn spans carrying the client id, the client-side echoed
//!   `fact_learn` spans parented under them, and the retry event attached
//!   to the right client's span;
//! * the trace survives a coordinator crash: `trace.jsonl` is written
//!   next to the round-store WAL when a round closes, and `recover()`
//!   replays it into a recorder that never saw the live spans.
//!
//! The client side is the same engine-free deterministic secagg registry
//! the recovery tests use, plus trace-context adoption so the shared
//! `wire_retry_event` helper can attach a simulated transport retry to
//! the in-flight client span.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

use feddart::coordinator::round_store::{
    LedgerCharge, RecoveryStatus, RoundEvent, RoundPhase, RoundState,
};
use feddart::coordinator::workflow::WorkflowManager;
use feddart::coordinator::{RoundStore, WalRoundStore};
use feddart::dart::TaskRegistry;
use feddart::error::FedError;
use feddart::fact::aggregation::Aggregation;
use feddart::fact::model::FactModel;
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::FactServer;
use feddart::json::Json;
use feddart::privacy::{
    dp, from_hex, keys, masking, round_id_from_hex, shamir, to_hex,
    PrivacyConfig, PrivacyMode,
};
use feddart::telemetry::{self, phase, FinishedSpan, TraceEvent};
use feddart::util::rng::{golden_f32, Rng};
use feddart::util::tensorbuf::TensorBuf;

const PARAMS: usize = 32;
const CLIENTS: usize = 5;
/// client-3 crashes in every learn phase: the round's straggler/dropout,
/// forcing the reveal + share-reconstruction path
const DROPPED: usize = 3;
/// client-1's transport "retries once" every learn: the wire-retry event
/// that must land on client-1's span
const RETRIED: usize = 1;

// ------------------------------------------------------------ fixture

struct TestModel;

impl FactModel for TestModel {
    fn name(&self) -> &str {
        "tracemodel"
    }
    fn param_count(&self) -> usize {
        PARAMS
    }
    fn init_params(&self, seed: i32) -> feddart::Result<Vec<f32>> {
        Ok(golden_f32(seed as u32, PARAMS))
    }
    fn aggregation(&self) -> &Aggregation {
        &Aggregation::WeightedFedAvg
    }
}

fn device_index(device: &str) -> usize {
    device.rsplit('-').next().unwrap().parse().unwrap()
}

fn client_secret(idx: usize) -> [u8; 32] {
    [idx as u8 + 11; 32]
}

fn round_keys_of(device: &str, round_id: u64) -> keys::RoundKeys {
    keys::keypair(&keys::derive_round_secret(
        &client_secret(device_index(device)),
        round_id,
        device,
    ))
}

fn keys_map_of(p: &Json) -> BTreeMap<String, String> {
    p.need("keys")
        .unwrap()
        .as_obj()
        .unwrap()
        .iter()
        .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
        .collect()
}

/// Deterministic secagg+dp clients (see `round_recovery.rs`): every
/// derivation is a pure function of `(round_id, device)`.  `fact_learn`
/// additionally adopts the trace context the coordinator injected, so
/// the simulated wire retry attaches to the right client span through
/// the SAME `wire_retry_event` helper the REST transport uses.
fn traced_registry() -> TaskRegistry {
    let registry = TaskRegistry::new();
    registry.register("fact_init", |_| Ok(Json::Null));

    registry.register("fact_keys", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let kp = round_keys_of(&device, round_id);
        Ok(Json::obj().set("pubkey", keys::pubkey_hex(&kp.public)))
    });

    registry.register("fact_shares", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let threshold = p.need("threshold")?.as_usize().unwrap();
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let peers: Vec<(String, u8)> = keys_map
            .keys()
            .enumerate()
            .filter(|(_, n)| *n != &device)
            .map(|(i, n)| (n.clone(), i as u8 + 1))
            .collect();
        let xs: Vec<u8> = peers.iter().map(|(_, x)| *x).collect();
        let mut rng = Rng::new(round_id ^ device_index(&device) as u64);
        let split = shamir::split_at(&kp.secret, threshold, &xs, &mut rng)?;
        let mut shares = Json::obj();
        let mut commits = Json::obj();
        for (share, (peer, _)) in split.iter().zip(peers.iter()) {
            let their = keys::parse_pubkey_hex(&keys_map[peer])?;
            let sk = keys::shared_key(&kp.secret, &their);
            let ct =
                keys::encrypt_share(&sk, round_id, &device, peer, &share.to_bytes());
            shares = shares.set(peer, to_hex(&ct));
            commits = commits.set(peer, to_hex(&shamir::share_commitment(share)));
        }
        Ok(Json::obj().set("shares", shares).set("commits", commits))
    });

    registry.register("fact_learn", |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let idx = device_index(&device);
        if idx == RETRIED {
            // a transport hiccup the client's retry loop absorbed: the
            // adopted trace context parents the event under THIS
            // client's in-flight learn span on the coordinator side
            if let Some(ctx) = telemetry::extract(p) {
                let _g = telemetry::ContextGuard::adopt(ctx);
                telemetry::wire_retry_event("learn", 1, "connection reset");
            }
        }
        if idx == DROPPED {
            return Err(FedError::Task(format!("'{device}' crashed mid-round")));
        }
        let global = TensorBuf::from_json(p.need("params")?)
            .map_err(|e| FedError::Task(e.to_string()))?;
        let gs = global.as_f32_slice();
        let delta = golden_f32(idx as u32 + 1, gs.len());
        let mut params: Vec<f32> =
            gs.iter().zip(&delta).map(|(g, d)| g + 0.1 * d).collect();
        let n_samples = 100.0 + 10.0 * idx as f32;
        let pj = p.need("privacy")?;
        let cfg = PrivacyConfig::from_json(pj)?;
        let round_id =
            round_id_from_hex(pj.need("round_id")?.as_str().unwrap_or_default())?;
        if cfg.mode.has_dp() {
            let mut rng = Rng::new(round_id ^ idx as u64);
            dp::privatize_update(
                &mut params,
                gs,
                cfg.clip_norm,
                cfg.noise_multiplier,
                &mut rng,
            )?;
        }
        let keys_map: BTreeMap<String, String> = pj
            .need("keys")?
            .as_obj()
            .unwrap()
            .iter()
            .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
            .collect();
        let participants: Vec<String> = pj
            .need("participants")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|j| j.as_str().map(String::from))
            .collect();
        let kp = round_keys_of(&device, round_id);
        let seeds: Vec<(i64, [u8; 32])> = participants
            .iter()
            .filter(|c| *c != &device)
            .map(|peer| {
                let their = keys::parse_pubkey_hex(&keys_map[peer]).unwrap();
                let sk = keys::shared_key(&kp.secret, &their);
                (
                    masking::pair_sign(&device, peer),
                    keys::pair_seed_from_shared(&sk, round_id, &device, peer),
                )
            })
            .collect();
        let weighted = pj.get("weighted").and_then(Json::as_bool).unwrap_or(true);
        let weight = if weighted {
            n_samples as f64 / cfg.weight_scale as f64
        } else {
            1.0
        };
        params =
            masking::mask_update_with_seeds(&params, weight, &seeds, cfg.frac_bits)?;
        Ok(Json::obj()
            .set("params", TensorBuf::from_f32_vec(params))
            .set("n_samples", n_samples)
            .set("loss", 0.5))
    });

    registry.register("fact_reveal", |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let mut seeds = Json::obj();
        let mut shares_out = Json::obj();
        for d in p.need("dropped")?.as_arr().unwrap_or(&[]) {
            let Some(name) = d.as_str() else { continue };
            if name == device {
                continue;
            }
            let Some(pub_hex) = keys_map.get(name) else { continue };
            let their = keys::parse_pubkey_hex(pub_hex)?;
            let sk = keys::shared_key(&kp.secret, &their);
            seeds = seeds.set(
                name,
                to_hex(&keys::pair_seed_from_shared(&sk, round_id, &device, name)),
            );
            if let Some(ct_hex) =
                p.get("shares").and_then(|s| s.get(name)).and_then(Json::as_str)
            {
                let plain = keys::decrypt_share(
                    &sk,
                    round_id,
                    name,
                    &device,
                    &from_hex(ct_hex)?,
                )?;
                shares_out = shares_out.set(name, to_hex(&plain));
            }
        }
        Ok(Json::obj().set("seeds", seeds).set("shares", shares_out))
    });
    registry
}

// ---------------------------------------------------------- kill store

/// Same crash-injection store as `round_recovery.rs`, but exposing
/// `trace_dir()` so the coordinator dumps `trace.jsonl` next to the WAL.
struct KillStore {
    inner: WalRoundStore,
    remaining: AtomicI64,
}

impl KillStore {
    fn new(dir: &std::path::Path, kill_after: i64) -> KillStore {
        KillStore {
            inner: WalRoundStore::open(dir).unwrap(),
            remaining: AtomicI64::new(kill_after),
        }
    }

    fn tick(&self) -> bool {
        self.remaining.fetch_sub(1, Ordering::SeqCst) <= 1
    }

    fn dead(&self) -> bool {
        self.remaining.load(Ordering::SeqCst) <= 0
    }

    fn crash<T>() -> feddart::Result<T> {
        Err(FedError::Fact("injected coordinator crash".into()))
    }
}

impl RoundStore for KillStore {
    fn append(&self, ev: RoundEvent) -> feddart::Result<RoundPhase> {
        if self.dead() {
            return Self::crash();
        }
        let phase = self.inner.append(ev)?;
        if self.tick() {
            return Self::crash();
        }
        Ok(phase)
    }
    fn append_charge(&self, charge: LedgerCharge) -> feddart::Result<()> {
        if self.dead() {
            return Self::crash();
        }
        self.inner.append_charge(charge)?;
        if self.tick() {
            return Self::crash();
        }
        Ok(())
    }
    fn charges(&self) -> feddart::Result<Vec<LedgerCharge>> {
        self.inner.charges()
    }
    fn round(&self, round_id: u64) -> feddart::Result<Option<RoundState>> {
        self.inner.round(round_id)
    }
    fn rounds(&self) -> feddart::Result<Vec<RoundState>> {
        self.inner.rounds()
    }
    fn session_tag(&self) -> feddart::Result<Option<u64>> {
        self.inner.session_tag()
    }
    fn set_session_tag(&self, tag: u64) -> feddart::Result<u64> {
        self.inner.set_session_tag(tag)
    }
    fn compact(&self) -> feddart::Result<()> {
        self.inner.compact()
    }
    fn recovery(&self) -> RecoveryStatus {
        self.inner.recovery()
    }
    fn trace_dir(&self) -> Option<PathBuf> {
        self.inner.trace_dir()
    }
}

// ------------------------------------------------------------- drivers

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("feddart-trace-e2e-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn server_with(
    store: Arc<dyn RoundStore>,
    session_tag: u64,
    rounds: usize,
) -> FactServer {
    let wm = WorkflowManager::test_mode(CLIENTS, traced_registry(), 4);
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig {
            mode: PrivacyMode::SecAggDp,
            clip_norm: 4.0,
            noise_multiplier: 0.05,
            weight_scale: 128.0,
            ..PrivacyConfig::default()
        })
        .with_round_store(store)
        .with_session_tag(session_tag);
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(rounds)),
            7,
        )
        .unwrap();
    server
}

/// Fetch one round's trace (spans + events) and sanity-check it is a
/// single connected trace rooted at the `round` span.
fn round_trace(
    rec: &telemetry::Recorder,
    round_id: u64,
) -> (Vec<FinishedSpan>, Vec<TraceEvent>) {
    let (spans, events) = rec
        .round_trace(round_id)
        .unwrap_or_else(|| panic!("no trace recorded for round {round_id:x}"));
    let roots: Vec<&FinishedSpan> =
        spans.iter().filter(|s| s.parent_id == 0).collect();
    assert_eq!(roots.len(), 1, "expected exactly one root span");
    assert_eq!(roots[0].name, phase::ROUND);
    let tid = roots[0].trace_id;
    for s in &spans {
        assert_eq!(s.trace_id, tid, "span '{}' left the trace", s.name);
    }
    for e in &events {
        assert_eq!(e.trace_id, tid, "event '{}' left the trace", e.kind);
    }
    (spans, events)
}

fn count_named(spans: &[FinishedSpan], name: &str) -> usize {
    spans.iter().filter(|s| s.name == name).count()
}

// --------------------------------------------------------------- tests

/// One secagg+dp round with a straggler and a wire retry: a single trace
/// holding every pipeline phase exactly once, per-client spans with
/// client ids, client-side echoed spans beneath them, and the retry
/// event on the retried client's span.
#[test]
fn single_round_trace_is_complete() {
    let dir = tmp_dir("complete");
    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    let mut server = server_with(store.clone(), 0x7ace_0001, 1);
    server.learn().unwrap();
    assert_eq!(server.history().len(), 1);

    let states = store.rounds().unwrap();
    assert_eq!(states.len(), 1);
    let rid = states[0].round_id;
    let rec = server.telemetry();
    let (spans, events) = round_trace(rec.as_ref(), rid);

    // every pipeline phase exactly once
    for name in phase::ALL {
        assert_eq!(
            count_named(&spans, name),
            1,
            "phase '{name}' must appear exactly once"
        );
    }

    // one coordinator-side span per addressed client, each carrying the
    // client id, with outcomes matching the round (one dropout)
    let client_spans: Vec<&FinishedSpan> = spans
        .iter()
        .filter(|s| s.name == phase::CLIENT_LEARN)
        .collect();
    assert_eq!(client_spans.len(), CLIENTS);
    let mut ok = 0;
    let mut dropped = 0;
    for s in &client_spans {
        let client = s.attr("client").expect("client span without client id");
        match s.attr("outcome") {
            Some("ok") => ok += 1,
            Some("dropped") => {
                dropped += 1;
                assert_eq!(device_index(client), DROPPED);
            }
            other => panic!("unexpected outcome {other:?} for '{client}'"),
        }
    }
    assert_eq!((ok, dropped), (CLIENTS - 1, 1));

    // client-side echoed learn spans: parented under the coordinator's
    // client spans, one per responder
    let echoes: Vec<&FinishedSpan> =
        spans.iter().filter(|s| s.name == "fact_learn").collect();
    assert_eq!(echoes.len(), CLIENTS - 1, "one echo per responding client");
    for e in &echoes {
        let parent = spans
            .iter()
            .find(|s| s.span_id == e.parent_id)
            .expect("echo parented outside the trace");
        assert_eq!(parent.name, phase::CLIENT_LEARN);
        assert_eq!(parent.attr("client"), e.attr("client"));
    }

    // the wire retry landed on the retried client's span
    let retries: Vec<&TraceEvent> =
        events.iter().filter(|e| e.kind == "wire_retry").collect();
    assert_eq!(retries.len(), 1, "exactly one wire retry in the round");
    let holder = spans
        .iter()
        .find(|s| s.span_id == retries[0].span_id)
        .expect("retry event attached outside the trace");
    assert_eq!(holder.name, phase::CLIENT_LEARN);
    assert_eq!(
        holder.attr("client").map(device_index),
        Some(RETRIED),
        "retry attached to the wrong client span"
    );

    // the queryable tree assembles and the flight-recorder dump landed
    // next to the WAL
    let tree = rec.trace_json(rid).expect("trace_json");
    assert!(telemetry::render_tree(&tree).contains(phase::QUORUM_WAIT));
    assert!(dir.join("trace.jsonl").exists(), "trace.jsonl not dumped");
}

/// Crash the coordinator mid-round-1: round 0 closed and its trace was
/// dumped to `trace.jsonl`, so a restarted coordinator — with a PRIVATE
/// recorder that never saw the live spans — replays the full round-0
/// trace on `recover()` and finishes the session.
#[test]
fn trace_survives_crash_and_replays() {
    const TAG: u64 = 0x7ace_0002;
    let dir = tmp_dir("crash");

    // phase 1: kill after round 0's full event arc (8 events) plus
    // round 1's Configured + KeysCollected — round 0 terminal, dumped
    let killed = Arc::new(KillStore::new(&dir, 10));
    let mut server = server_with(killed.clone(), TAG, 2);
    server.learn().unwrap_err();
    let rid0 = killed
        .rounds()
        .unwrap()
        .iter()
        .find(|s| s.round == 0)
        .expect("round 0 persisted")
        .round_id;
    assert!(dir.join("trace.jsonl").exists(), "dump must precede charges");

    // phase 2: fresh coordinator, fresh PRIVATE recorder (empty by
    // construction — a restarted process has no in-memory spans)
    let replay_rec = Arc::new(telemetry::Recorder::with_defaults());
    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    let mut server =
        server_with(store.clone(), TAG, 2).with_telemetry(Arc::clone(&replay_rec));
    assert!(replay_rec.round_trace(rid0).is_none(), "recorder not fresh");
    server.recover().unwrap();

    // the replayed round-0 trace is complete: every phase span made it
    // through the dump/replay cycle
    let (spans, events) = round_trace(replay_rec.as_ref(), rid0);
    for name in phase::ALL {
        assert_eq!(
            count_named(&spans, name),
            1,
            "replayed phase '{name}' must appear exactly once"
        );
    }
    assert_eq!(count_named(&spans, phase::CLIENT_LEARN), CLIENTS);
    assert!(
        events.iter().any(|e| e.kind == "wire_retry"),
        "retry event lost in the dump/replay cycle"
    );

    // and the resumed session still completes, with round 1's live
    // spans landing in the private recorder too (a resumed round may
    // skip already-durable phases, so only the root is guaranteed)
    server.learn().unwrap();
    assert_eq!(server.history().len(), 2);
    let rid1 = store
        .rounds()
        .unwrap()
        .iter()
        .find(|s| s.round == 1)
        .expect("round 1 persisted")
        .round_id;
    let (spans1, _) = round_trace(replay_rec.as_ref(), rid1);
    assert_eq!(count_named(&spans1, phase::ROUND), 1);
}
