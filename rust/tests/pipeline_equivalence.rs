//! Behavioral-equivalence golden for the round-pipeline refactor.
//!
//! The FactServer god-module was decomposed into `fact::rounds::{ctx,
//! phases, pipeline}` with pluggable `ServerOptimizer` / `LocalStrategy`
//! seams.  Under the identity configuration — `PlainReplace` + `plain` —
//! the pipeline must be *behaviorally invisible*: a fixed-seed 3-round
//! secagg+dp session reproduces bit-identically run over run, in
//!
//! * the final aggregate parameters (bitwise),
//! * the ε-ledger (steps and epsilon, bitwise),
//! * the durable event sequence (same tags in the same order), and
//! * the per-round records (everything except wall-clock timings).
//!
//! It also pins the WAL compatibility anchor: a stateless optimizer must
//! leave `Aggregated` events WITHOUT an `opt_state` key (pre-refactor
//! byte format), while a stateful one must write it — so pre-refactor
//! WALs replay unchanged and stateful sessions resume exactly.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use feddart::coordinator::round_store::{
    LedgerCharge, MemRoundStore, RecoveryStatus, RoundEvent, RoundPhase,
    RoundState,
};
use feddart::coordinator::workflow::WorkflowManager;
use feddart::coordinator::RoundStore;
use feddart::dart::TaskRegistry;
use feddart::error::FedError;
use feddart::fact::aggregation::Aggregation;
use feddart::fact::model::FactModel;
use feddart::fact::rounds::optimizer::FedAvgM;
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::FactServer;
use feddart::json::Json;
use feddart::privacy::{
    dp, from_hex, keys, masking, round_id_from_hex, shamir, to_hex,
    PrivacyConfig, PrivacyMode,
};
use feddart::util::rng::{golden_f32, Rng};
use feddart::util::tensorbuf::TensorBuf;

const PARAMS: usize = 48;
const CLIENTS: usize = 4;
const ROUNDS: usize = 3;
const SESSION_TAG: u64 = 0x901d_e0aa;

// ------------------------------------------------------------ fixture

struct TestModel;

impl FactModel for TestModel {
    fn name(&self) -> &str {
        "equivalencemodel"
    }
    fn param_count(&self) -> usize {
        PARAMS
    }
    fn init_params(&self, seed: i32) -> feddart::Result<Vec<f32>> {
        Ok(golden_f32(seed as u32, PARAMS))
    }
    fn aggregation(&self) -> &Aggregation {
        &Aggregation::WeightedFedAvg
    }
}

fn device_index(device: &str) -> usize {
    device.rsplit('-').next().unwrap().parse().unwrap()
}

fn client_secret(idx: usize) -> [u8; 32] {
    [idx as u8 + 11; 32]
}

fn round_keys_of(device: &str, round_id: u64) -> keys::RoundKeys {
    keys::keypair(&keys::derive_round_secret(
        &client_secret(device_index(device)),
        round_id,
        device,
    ))
}

fn keys_map_of(p: &Json) -> BTreeMap<String, String> {
    p.need("keys")
        .unwrap()
        .as_obj()
        .unwrap()
        .iter()
        .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
        .collect()
}

/// Deterministic secagg+dp clients (the same construction the recovery
/// and privacy integration tests use): every derived quantity is a pure
/// function of `(round_id, device)`, so two identically configured
/// sessions produce byte-identical client traffic.
fn deterministic_registry() -> TaskRegistry {
    let registry = TaskRegistry::new();
    registry.register("fact_init", |_| Ok(Json::Null));

    registry.register("fact_keys", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let kp = round_keys_of(&device, round_id);
        Ok(Json::obj().set("pubkey", keys::pubkey_hex(&kp.public)))
    });

    registry.register("fact_shares", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let threshold = p.need("threshold")?.as_usize().unwrap();
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let peers: Vec<(String, u8)> = keys_map
            .keys()
            .enumerate()
            .filter(|(_, n)| *n != &device)
            .map(|(i, n)| (n.clone(), i as u8 + 1))
            .collect();
        let xs: Vec<u8> = peers.iter().map(|(_, x)| *x).collect();
        let mut rng = Rng::new(round_id ^ device_index(&device) as u64);
        let split = shamir::split_at(&kp.secret, threshold, &xs, &mut rng)?;
        let mut shares = Json::obj();
        let mut commits = Json::obj();
        for (share, (peer, _)) in split.iter().zip(peers.iter()) {
            let their = keys::parse_pubkey_hex(&keys_map[peer])?;
            let sk = keys::shared_key(&kp.secret, &their);
            let ct =
                keys::encrypt_share(&sk, round_id, &device, peer, &share.to_bytes());
            shares = shares.set(peer, to_hex(&ct));
            commits = commits.set(peer, to_hex(&shamir::share_commitment(share)));
        }
        Ok(Json::obj().set("shares", shares).set("commits", commits))
    });

    registry.register("fact_learn", |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let idx = device_index(&device);
        let global = TensorBuf::from_json(p.need("params")?)
            .map_err(|e| FedError::Task(e.to_string()))?;
        let gs = global.as_f32_slice();
        let delta = golden_f32(idx as u32 + 1, gs.len());
        let mut params: Vec<f32> =
            gs.iter().zip(&delta).map(|(g, d)| g + 0.1 * d).collect();
        let n_samples = 100.0 + 10.0 * idx as f32;

        let Some(pj) = p.get("privacy") else {
            return Ok(Json::obj()
                .set("params", TensorBuf::from_f32_vec(params))
                .set("n_samples", n_samples)
                .set("loss", 0.5));
        };
        let cfg = PrivacyConfig::from_json(pj)?;
        let round_id =
            round_id_from_hex(pj.need("round_id")?.as_str().unwrap_or_default())?;
        if cfg.mode.has_dp() {
            let mut rng = Rng::new(round_id ^ idx as u64);
            dp::privatize_update(
                &mut params,
                gs,
                cfg.clip_norm,
                cfg.noise_multiplier,
                &mut rng,
            )?;
        }
        if cfg.mode.has_secagg() {
            let keys_map: BTreeMap<String, String> = pj
                .need("keys")?
                .as_obj()
                .unwrap()
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect();
            let participants: Vec<String> = pj
                .need("participants")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|j| j.as_str().map(String::from))
                .collect();
            let kp = round_keys_of(&device, round_id);
            let seeds: Vec<(i64, [u8; 32])> = participants
                .iter()
                .filter(|c| *c != &device)
                .map(|peer| {
                    let their = keys::parse_pubkey_hex(&keys_map[peer]).unwrap();
                    let sk = keys::shared_key(&kp.secret, &their);
                    (
                        masking::pair_sign(&device, peer),
                        keys::pair_seed_from_shared(&sk, round_id, &device, peer),
                    )
                })
                .collect();
            let weighted =
                pj.get("weighted").and_then(Json::as_bool).unwrap_or(true);
            let weight = if weighted {
                n_samples as f64 / cfg.weight_scale as f64
            } else {
                1.0
            };
            params = masking::mask_update_with_seeds(
                &params,
                weight,
                &seeds,
                cfg.frac_bits,
            )?;
        }
        Ok(Json::obj()
            .set("params", TensorBuf::from_f32_vec(params))
            .set("n_samples", n_samples)
            .set("loss", 0.5))
    });

    registry.register("fact_reveal", |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let mut seeds = Json::obj();
        let mut shares_out = Json::obj();
        for d in p.need("dropped")?.as_arr().unwrap_or(&[]) {
            let Some(name) = d.as_str() else { continue };
            if name == device {
                continue;
            }
            let Some(pub_hex) = keys_map.get(name) else { continue };
            let their = keys::parse_pubkey_hex(pub_hex)?;
            let sk = keys::shared_key(&kp.secret, &their);
            seeds = seeds.set(
                name,
                to_hex(&keys::pair_seed_from_shared(&sk, round_id, &device, name)),
            );
            if let Some(ct_hex) =
                p.get("shares").and_then(|s| s.get(name)).and_then(Json::as_str)
            {
                let plain = keys::decrypt_share(
                    &sk,
                    round_id,
                    name,
                    &device,
                    &from_hex(ct_hex)?,
                )?;
                shares_out = shares_out.set(name, to_hex(&plain));
            }
        }
        Ok(Json::obj().set("seeds", seeds).set("shares", shares_out))
    });
    registry
}

// -------------------------------------------------------- logging store

/// Delegates to a [`MemRoundStore`] while journaling every appended
/// event's tag and whether its serialized form carries an `opt_state`
/// key — the observable surface the golden compares across runs.
#[derive(Default)]
struct EventLogStore {
    inner: MemRoundStore,
    tags: Mutex<Vec<String>>,
    aggregated_with_opt_state: Mutex<Vec<bool>>,
}

impl RoundStore for EventLogStore {
    fn append(&self, ev: RoundEvent) -> feddart::Result<RoundPhase> {
        let tag = ev.kind.tag().to_string();
        if tag == "aggregated" {
            self.aggregated_with_opt_state
                .lock()
                .unwrap()
                .push(ev.to_json().get("opt_state").is_some());
        }
        self.tags.lock().unwrap().push(tag);
        self.inner.append(ev)
    }
    fn append_charge(&self, charge: LedgerCharge) -> feddart::Result<()> {
        self.tags.lock().unwrap().push("charge".to_string());
        self.inner.append_charge(charge)
    }
    fn charges(&self) -> feddart::Result<Vec<LedgerCharge>> {
        self.inner.charges()
    }
    fn round(&self, round_id: u64) -> feddart::Result<Option<RoundState>> {
        self.inner.round(round_id)
    }
    fn rounds(&self) -> feddart::Result<Vec<RoundState>> {
        self.inner.rounds()
    }
    fn session_tag(&self) -> feddart::Result<Option<u64>> {
        self.inner.session_tag()
    }
    fn set_session_tag(&self, tag: u64) -> feddart::Result<u64> {
        self.inner.set_session_tag(tag)
    }
    fn compact(&self) -> feddart::Result<()> {
        self.inner.compact()
    }
    fn recovery(&self) -> RecoveryStatus {
        self.inner.recovery()
    }
}

// -------------------------------------------------------------- driver

/// The timing-free projection of a round record (wall-clock fields are
/// the only legitimately nondeterministic part of a fixed-seed session).
fn record_fingerprint(r: &feddart::fact::server::RoundRecord) -> String {
    format!(
        "round={} clients={} sampled={} late={} dropped={} loss={} q={} \
         server_opt={} local_strategy={}",
        r.round,
        r.n_clients,
        r.sampled,
        r.late,
        r.dropped,
        r.mean_loss,
        r.sample_rate,
        r.server_opt,
        r.local_strategy
    )
}

struct RunOutcome {
    params: Vec<f32>,
    steps: u64,
    epsilon: f64,
    tags: Vec<String>,
    aggregated_with_opt_state: Vec<bool>,
    records: Vec<String>,
    summaries: Vec<Json>,
}

/// One fixed-seed secagg+dp session under the identity seams.
fn run_identity_session() -> RunOutcome {
    let store = Arc::new(EventLogStore::default());
    let wm = WorkflowManager::test_mode(CLIENTS, deterministic_registry(), 4);
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig {
            mode: PrivacyMode::SecAggDp,
            clip_norm: 4.0,
            noise_multiplier: 0.05,
            weight_scale: 128.0,
            ..PrivacyConfig::default()
        })
        .with_round_store(store.clone())
        .with_session_tag(SESSION_TAG);
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(ROUNDS)),
            5,
        )
        .unwrap();
    server.learn().unwrap();
    let summaries = store
        .rounds()
        .unwrap()
        .iter()
        .map(|r| r.summary_json())
        .collect();
    RunOutcome {
        params: server.container().clusters[0].params.clone(),
        steps: server.accountant().steps,
        epsilon: server.accountant().epsilon(1e-5),
        tags: store.tags.lock().unwrap().clone(),
        aggregated_with_opt_state: store
            .aggregated_with_opt_state
            .lock()
            .unwrap()
            .clone(),
        records: server.history().iter().map(record_fingerprint).collect(),
        summaries,
    }
}

// --------------------------------------------------------------- tests

/// THE golden: two identically configured fixed-seed sessions through
/// the layered pipeline are bit-identical in parameters, ε-ledger,
/// event sequence, and per-round records.
#[test]
fn identity_seams_reproduce_bit_identically() {
    let a = run_identity_session();
    let b = run_identity_session();

    assert_eq!(a.params, b.params, "aggregate params must be bit-identical");
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.steps, ROUNDS as u64);
    assert!(
        (a.epsilon - b.epsilon).abs() < 1e-12,
        "ε diverged: {} vs {}",
        a.epsilon,
        b.epsilon
    );
    assert_eq!(a.tags, b.tags, "durable event sequence must be identical");
    assert_eq!(a.records, b.records, "round records must be identical");

    // the sequence itself is the full secagg arc every round (Revealed
    // carries the audit even without dropouts), with the ε charges
    // appended once the clustering round settles
    assert_eq!(a.tags.len(), ROUNDS * 8 + ROUNDS);
    for r in 0..ROUNDS {
        assert_eq!(
            &a.tags[r * 8..(r + 1) * 8],
            &[
                "configured",
                "keys_collected",
                "shares_dealt",
                "learn_dispatched",
                "learn_closed",
                "revealed",
                "aggregated",
                "closed",
            ],
            "round {r} event arc"
        );
    }
    assert!(
        a.tags[ROUNDS * 8..].iter().all(|t| t == "charge"),
        "tail must be the ε charges: {:?}",
        &a.tags[ROUNDS * 8..]
    );
}

/// WAL-format anchor: the stateless identity optimizer leaves
/// `Aggregated` events without an `opt_state` key (pre-refactor byte
/// format), and the round summaries echo the identity seams.
#[test]
fn stateless_optimizer_keeps_pre_refactor_event_format() {
    let run = run_identity_session();
    assert_eq!(run.aggregated_with_opt_state.len(), ROUNDS);
    assert!(
        run.aggregated_with_opt_state.iter().all(|w| !w),
        "PlainReplace must not serialize opt_state into Aggregated events"
    );
    for s in &run.summaries {
        assert_eq!(s.get("server_opt").and_then(Json::as_str), Some("plain"));
        assert_eq!(
            s.get("local_strategy").and_then(Json::as_str),
            Some("plain")
        );
    }
}

/// The contrast case: a stateful optimizer writes its buffers into the
/// `Aggregated` event — that payload is what makes resume-at-Aggregated
/// exact for FedAvgM/FedAdam.
#[test]
fn stateful_optimizer_persists_opt_state_in_aggregated_events() {
    let store = Arc::new(EventLogStore::default());
    let wm = WorkflowManager::test_mode(CLIENTS, deterministic_registry(), 4);
    let mut server = FactServer::new(wm)
        .with_server_opt(Arc::new(FedAvgM { lr: 1.0, momentum: 0.9 }))
        .with_round_store(store.clone())
        .with_session_tag(SESSION_TAG);
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(2)),
            5,
        )
        .unwrap();
    server.learn().unwrap();
    let with_state = store.aggregated_with_opt_state.lock().unwrap().clone();
    assert_eq!(with_state, vec![true, true]);
    for s in store.rounds().unwrap().iter().map(|r| r.summary_json()) {
        assert_eq!(
            s.get("server_opt").and_then(Json::as_str),
            Some("fedavgm")
        );
    }
}
