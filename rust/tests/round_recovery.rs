//! Crash-recoverable rounds: kill the coordinator at EVERY write-ahead
//! boundary of a secagg+dp session, restart from the WAL, and assert the
//! resumed session produces the bit-identical aggregate and the identical
//! final ε-ledger as an uninterrupted run.
//!
//! The client side is the same engine-free deterministic registry the
//! privacy integration tests use (per-pair DH keys, encrypted Shamir
//! shares, DP noise and pairwise masks all derived from `(round_id,
//! device)`), so a re-run phase reproduces byte-identical contributions —
//! which is exactly the property coordinator recovery leans on.
//!
//! Also covered: a corrupt WAL tail is detected (CRC), truncated, and the
//! wounded round is voided per `RevealPolicy` — never silently resumed —
//! and the ε-ledger can no longer fork between a stale model snapshot
//! and the round store (the store's charge log wins in either restore
//! order).

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

use feddart::coordinator::round_store::{
    LedgerCharge, RecoveryStatus, RoundEvent, RoundPhase, RoundState,
};
use feddart::coordinator::workflow::WorkflowManager;
use feddart::coordinator::{RoundStore, WalRoundStore};
use feddart::dart::TaskRegistry;
use feddart::error::FedError;
use feddart::fact::aggregation::Aggregation;
use feddart::fact::model::FactModel;
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::FactServer;
use feddart::json::Json;
use feddart::privacy::{
    dp, from_hex, keys, masking, round_id_from_hex, shamir, to_hex,
    PrivacyConfig, PrivacyMode, RevealPolicy,
};
use feddart::util::rng::{golden_f32, Rng};
use feddart::util::tensorbuf::TensorBuf;

const PARAMS: usize = 64;
const CLIENTS: usize = 5;
const ROUNDS: usize = 2;
const SESSION_TAG: u64 = 0xfeed_d001;
/// client-3 crashes in every learn phase (so every round exercises the
/// dropout-recovery reveal path too)
const DROPPED: &[usize] = &[3];

// ------------------------------------------------------------ fixture

struct TestModel;

impl FactModel for TestModel {
    fn name(&self) -> &str {
        "recoverymodel"
    }
    fn param_count(&self) -> usize {
        PARAMS
    }
    fn init_params(&self, seed: i32) -> feddart::Result<Vec<f32>> {
        Ok(golden_f32(seed as u32, PARAMS))
    }
    fn aggregation(&self) -> &Aggregation {
        &Aggregation::WeightedFedAvg
    }
}

fn device_index(device: &str) -> usize {
    device.rsplit('-').next().unwrap().parse().unwrap()
}

fn client_secret(idx: usize) -> [u8; 32] {
    [idx as u8 + 1; 32]
}

fn round_keys_of(device: &str, round_id: u64) -> keys::RoundKeys {
    keys::keypair(&keys::derive_round_secret(
        &client_secret(device_index(device)),
        round_id,
        device,
    ))
}

fn keys_map_of(p: &Json) -> BTreeMap<String, String> {
    p.need("keys")
        .unwrap()
        .as_obj()
        .unwrap()
        .iter()
        .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
        .collect()
}

/// Deterministic secagg+dp clients (same construction as the privacy
/// integration tests): everything a client derives is a pure function of
/// `(round_id, device)`, so a coordinator that re-runs a phase after a
/// crash gets byte-identical responses.
fn deterministic_registry() -> TaskRegistry {
    let registry = TaskRegistry::new();
    registry.register("fact_init", |_| Ok(Json::Null));

    registry.register("fact_keys", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let kp = round_keys_of(&device, round_id);
        Ok(Json::obj().set("pubkey", keys::pubkey_hex(&kp.public)))
    });

    registry.register("fact_shares", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let threshold = p.need("threshold")?.as_usize().unwrap();
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let peers: Vec<(String, u8)> = keys_map
            .keys()
            .enumerate()
            .filter(|(_, n)| *n != &device)
            .map(|(i, n)| (n.clone(), i as u8 + 1))
            .collect();
        let xs: Vec<u8> = peers.iter().map(|(_, x)| *x).collect();
        let mut rng = Rng::new(round_id ^ device_index(&device) as u64);
        let split = shamir::split_at(&kp.secret, threshold, &xs, &mut rng)?;
        let mut shares = Json::obj();
        let mut commits = Json::obj();
        for (share, (peer, _)) in split.iter().zip(peers.iter()) {
            let their = keys::parse_pubkey_hex(&keys_map[peer])?;
            let sk = keys::shared_key(&kp.secret, &their);
            let ct =
                keys::encrypt_share(&sk, round_id, &device, peer, &share.to_bytes());
            shares = shares.set(peer, to_hex(&ct));
            commits = commits.set(peer, to_hex(&shamir::share_commitment(share)));
        }
        Ok(Json::obj().set("shares", shares).set("commits", commits))
    });

    registry.register("fact_learn", |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let idx = device_index(&device);
        if DROPPED.contains(&idx) {
            return Err(FedError::Task(format!("'{device}' crashed mid-round")));
        }
        let global = TensorBuf::from_json(p.need("params")?)
            .map_err(|e| FedError::Task(e.to_string()))?;
        let gs = global.as_f32_slice();
        let delta = golden_f32(idx as u32 + 1, gs.len());
        let mut params: Vec<f32> =
            gs.iter().zip(&delta).map(|(g, d)| g + 0.1 * d).collect();
        let n_samples = 100.0 + 10.0 * idx as f32;

        // clear-mode rounds carry no privacy envelope at all
        let Some(pj) = p.get("privacy") else {
            return Ok(Json::obj()
                .set("params", TensorBuf::from_f32_vec(params))
                .set("n_samples", n_samples)
                .set("loss", 0.5));
        };
        let cfg = PrivacyConfig::from_json(pj)?;
        let round_id =
            round_id_from_hex(pj.need("round_id")?.as_str().unwrap_or_default())?;
        if cfg.mode.has_dp() {
            let mut rng = Rng::new(round_id ^ idx as u64);
            dp::privatize_update(
                &mut params,
                gs,
                cfg.clip_norm,
                cfg.noise_multiplier,
                &mut rng,
            )?;
        }
        if cfg.mode.has_secagg() {
            let keys_map: BTreeMap<String, String> = pj
                .need("keys")?
                .as_obj()
                .unwrap()
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect();
            let participants: Vec<String> = pj
                .need("participants")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|j| j.as_str().map(String::from))
                .collect();
            let kp = round_keys_of(&device, round_id);
            let seeds: Vec<(i64, [u8; 32])> = participants
                .iter()
                .filter(|c| *c != &device)
                .map(|peer| {
                    let their = keys::parse_pubkey_hex(&keys_map[peer]).unwrap();
                    let sk = keys::shared_key(&kp.secret, &their);
                    (
                        masking::pair_sign(&device, peer),
                        keys::pair_seed_from_shared(&sk, round_id, &device, peer),
                    )
                })
                .collect();
            let weighted =
                pj.get("weighted").and_then(Json::as_bool).unwrap_or(true);
            let weight = if weighted {
                n_samples as f64 / cfg.weight_scale as f64
            } else {
                1.0
            };
            params = masking::mask_update_with_seeds(
                &params,
                weight,
                &seeds,
                cfg.frac_bits,
            )?;
        }
        Ok(Json::obj()
            .set("params", TensorBuf::from_f32_vec(params))
            .set("n_samples", n_samples)
            .set("loss", 0.5))
    });

    registry.register("fact_reveal", |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let mut seeds = Json::obj();
        let mut shares_out = Json::obj();
        for d in p.need("dropped")?.as_arr().unwrap_or(&[]) {
            let Some(name) = d.as_str() else { continue };
            if name == device {
                continue;
            }
            let Some(pub_hex) = keys_map.get(name) else { continue };
            let their = keys::parse_pubkey_hex(pub_hex)?;
            let sk = keys::shared_key(&kp.secret, &their);
            seeds = seeds.set(
                name,
                to_hex(&keys::pair_seed_from_shared(&sk, round_id, &device, name)),
            );
            if let Some(ct_hex) =
                p.get("shares").and_then(|s| s.get(name)).and_then(Json::as_str)
            {
                let plain = keys::decrypt_share(
                    &sk,
                    round_id,
                    name,
                    &device,
                    &from_hex(ct_hex)?,
                )?;
                shares_out = shares_out.set(name, to_hex(&plain));
            }
        }
        Ok(Json::obj().set("seeds", seeds).set("shares", shares_out))
    });
    registry
}

// ---------------------------------------------------------- kill store

/// Delegates to a real [`WalRoundStore`] but injects a coordinator crash:
/// the `kill_after`-th durable write (event or charge) is persisted and
/// then errors — the moment a real process would die with the record
/// already on disk — and every later write fails like a dead process.
struct KillStore {
    inner: WalRoundStore,
    remaining: AtomicI64,
}

impl KillStore {
    fn new(dir: &std::path::Path, kill_after: i64) -> KillStore {
        KillStore {
            inner: WalRoundStore::open(dir).unwrap(),
            remaining: AtomicI64::new(kill_after),
        }
    }

    /// Count one durable write; `Err(true)` once the crash point is hit.
    fn tick(&self) -> bool {
        self.remaining.fetch_sub(1, Ordering::SeqCst) <= 1
    }

    fn dead(&self) -> bool {
        self.remaining.load(Ordering::SeqCst) <= 0
    }

    fn crash<T>() -> feddart::Result<T> {
        Err(FedError::Fact("injected coordinator crash".into()))
    }
}

impl RoundStore for KillStore {
    fn append(&self, ev: RoundEvent) -> feddart::Result<RoundPhase> {
        if self.dead() {
            return Self::crash();
        }
        let phase = self.inner.append(ev)?;
        if self.tick() {
            return Self::crash();
        }
        Ok(phase)
    }
    fn append_charge(&self, charge: LedgerCharge) -> feddart::Result<()> {
        if self.dead() {
            return Self::crash();
        }
        self.inner.append_charge(charge)?;
        if self.tick() {
            return Self::crash();
        }
        Ok(())
    }
    fn charges(&self) -> feddart::Result<Vec<LedgerCharge>> {
        self.inner.charges()
    }
    fn round(&self, round_id: u64) -> feddart::Result<Option<RoundState>> {
        self.inner.round(round_id)
    }
    fn rounds(&self) -> feddart::Result<Vec<RoundState>> {
        self.inner.rounds()
    }
    fn session_tag(&self) -> feddart::Result<Option<u64>> {
        self.inner.session_tag()
    }
    fn set_session_tag(&self, tag: u64) -> feddart::Result<u64> {
        self.inner.set_session_tag(tag)
    }
    fn compact(&self) -> feddart::Result<()> {
        self.inner.compact()
    }
    fn recovery(&self) -> RecoveryStatus {
        self.inner.recovery()
    }
}

// ------------------------------------------------------------- drivers

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "feddart-round-recovery-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn server_with_store(store: Arc<dyn RoundStore>) -> FactServer {
    let wm = WorkflowManager::test_mode(CLIENTS, deterministic_registry(), 4);
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig {
            mode: PrivacyMode::SecAggDp,
            clip_norm: 4.0,
            noise_multiplier: 0.05,
            weight_scale: 128.0,
            ..PrivacyConfig::default()
        })
        .with_round_store(store)
        .with_session_tag(SESSION_TAG);
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(ROUNDS)),
            3,
        )
        .unwrap();
    server
}

/// Run a full session against `store`; recover first (replays whatever a
/// previous run left), then learn.
fn run_session(store: Arc<dyn RoundStore>) -> (feddart::Result<()>, FactServer) {
    let mut server = server_with_store(store);
    if let Err(e) = server.recover() {
        return (Err(e), server);
    }
    let out = server.learn();
    (out, server)
}

struct Reference {
    params: Vec<f32>,
    steps: u64,
    epsilon: f64,
    total_writes: i64,
}

/// The uninterrupted run: final params + ε, and how many durable writes
/// the session performs (the size of the kill matrix).
fn reference_run(tag: &str) -> Reference {
    let dir = tmp_dir(tag);
    let store = Arc::new(KillStore::new(&dir, i64::MAX));
    let start = store.remaining.load(Ordering::SeqCst);
    let (out, server) = run_session(store.clone());
    out.unwrap();
    let total_writes = start - store.remaining.load(Ordering::SeqCst);
    assert_eq!(server.history().len(), ROUNDS);
    assert_eq!(server.accountant().steps, ROUNDS as u64);
    Reference {
        params: server.container().clusters[0].params.clone(),
        steps: server.accountant().steps,
        epsilon: server.accountant().epsilon(1e-5),
        total_writes,
    }
}

// --------------------------------------------------------------- tests

/// THE acceptance test: kill the coordinator after every single durable
/// write of a 2-round secagg+dp session — covering a crash inside each of
/// Configured/Keys/Shares/Learn/Reveal/Aggregated/Closed and between the
/// ε-ledger charges — restart from the WAL, and require the resumed
/// session to converge to the bit-identical aggregate and ε-ledger.
#[test]
fn killed_at_every_wal_boundary_resumes_to_identical_state() {
    let reference = reference_run("reference");
    assert!(
        reference.total_writes >= 16,
        "expected >= 8 events/round + charges, saw {}",
        reference.total_writes
    );

    for k in 1..=reference.total_writes {
        let dir = tmp_dir(&format!("kill-{k}"));

        // phase 1: run until the injected crash
        let killed = Arc::new(KillStore::new(&dir, k));
        let (out, _) = run_session(killed);
        out.unwrap_err(); // every kill point must surface the crash

        // phase 2: a fresh coordinator restarts from the same WAL dir
        let resumed_store = Arc::new(WalRoundStore::open(&dir).unwrap());
        let (out, server) = run_session(resumed_store.clone());
        out.unwrap_or_else(|e| panic!("kill point {k}: resume failed: {e}"));

        assert_eq!(
            server.container().clusters[0].params, reference.params,
            "kill point {k}: resumed aggregate diverged"
        );
        assert_eq!(
            server.accountant().steps, reference.steps,
            "kill point {k}: ε-ledger step count diverged"
        );
        let eps = server.accountant().epsilon(1e-5);
        assert!(
            (eps - reference.epsilon).abs() < 1e-12,
            "kill point {k}: ε diverged ({eps} vs {})",
            reference.epsilon
        );
        assert_eq!(server.history().len(), ROUNDS, "kill point {k}");

        // the store agrees: every round terminal, every charge present
        assert!(resumed_store.in_flight().unwrap().is_empty());
        assert_eq!(resumed_store.charges().unwrap().len(), ROUNDS);
    }
}

/// A crash between `Closed` and the ε charge used to fork the ledger
/// (rounds in the snapshot, charge nowhere).  The charge log in the
/// round store is now the source of truth: recovery heals the missing
/// charge exactly once.
#[test]
fn closed_but_uncharged_round_is_healed_exactly_once() {
    let reference = reference_run("charge-ref");
    // kill right after the LAST round event and before any charge: both
    // rounds closed, zero charges on disk
    let events_only = reference.total_writes - ROUNDS as i64;
    let dir = tmp_dir("charge-fork");
    let killed = Arc::new(KillStore::new(&dir, events_only));
    let (out, _) = run_session(killed);
    out.unwrap_err();

    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    assert!(store.charges().unwrap().is_empty(), "no charge reached disk");
    let (out, server) = run_session(store.clone());
    out.unwrap();
    assert_eq!(server.accountant().steps, reference.steps);
    assert_eq!(store.charges().unwrap().len(), ROUNDS);

    // a second restart replays the healed charges without re-charging
    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    let (out, server) = run_session(store.clone());
    out.unwrap();
    assert_eq!(server.accountant().steps, reference.steps);
    assert_eq!(store.charges().unwrap().len(), ROUNDS);
}

/// The store's charge log outranks a stale model-snapshot accountant in
/// BOTH restore orders — the Snapshot-vs-WAL race can no longer fork ε
/// history.
#[test]
fn stale_snapshot_accountant_cannot_fork_the_ledger() {
    use feddart::fact::store::{FsObjectStore, ModelStore};

    // a finished 2-round session in the WAL...
    let dir = tmp_dir("snapshot-race");
    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    let (out, server) = run_session(store.clone());
    out.unwrap();
    assert_eq!(server.accountant().steps, 2);

    // ...and a STALE model snapshot carrying a 1-step accountant
    let snap_dir = tmp_dir("snapshot-race-snap");
    let model_store = ModelStore::new(FsObjectStore::new(&snap_dir).unwrap());
    {
        let sd = tmp_dir("snapshot-race-one");
        let one = Arc::new(WalRoundStore::open(&sd).unwrap());
        let wm =
            WorkflowManager::test_mode(CLIENTS, deterministic_registry(), 4);
        let mut s = FactServer::new(wm)
            .with_privacy(PrivacyConfig {
                mode: PrivacyMode::SecAggDp,
                clip_norm: 4.0,
                noise_multiplier: 0.05,
                weight_scale: 128.0,
                ..PrivacyConfig::default()
            })
            .with_round_store(one)
            .with_session_tag(SESSION_TAG);
        s.initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(1)),
            3,
        )
        .unwrap();
        s.learn().unwrap();
        assert_eq!(s.accountant().steps, 1);
        s.checkpoint(&model_store, 1).unwrap();
    }

    // restore-then-recover: the WAL's 2 charges beat the 1-step snapshot
    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    let mut server = server_with_store(store);
    assert!(server.restore_latest(&model_store, 0).unwrap());
    assert_eq!(server.accountant().steps, 1, "stale ledger restored");
    server.recover().unwrap();
    assert_eq!(server.accountant().steps, 2, "store must win");

    // recover-then-restore: never backwards
    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    let mut server = server_with_store(store);
    server.recover().unwrap();
    assert_eq!(server.accountant().steps, 2);
    assert!(server.restore_latest(&model_store, 0).unwrap());
    assert_eq!(server.accountant().steps, 2, "restore must not roll back ε");
}

/// A corrupt WAL tail (torn write, disk damage) is detected by the CRC
/// frame, truncated, and the wounded in-flight round is voided per
/// `RevealPolicy` — with `abort` the coordinator refuses to resume, with
/// `proceed` it burns the round index and keeps training.  Either way the
/// damaged round is never silently resumed.
#[test]
fn corrupt_wal_tail_voids_the_wounded_round_per_policy() {
    // round 0 closed (8 events), round 1 killed mid-flight at event 12
    let make_wounded = |tag: &str| -> PathBuf {
        let dir = tmp_dir(tag);
        let killed = Arc::new(KillStore::new(&dir, 12));
        let (out, _) = run_session(killed);
        out.unwrap_err();
        // torn write: garbage after the last intact record
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join("wal.jsonl"))
            .unwrap();
        f.write_all(b"FDW1 deadbeef {\"event\":{\"torn").unwrap();
        f.flush().unwrap();
        dir
    };

    // abort (default): recovery refuses to touch the tainted round
    let dir = make_wounded("corrupt-abort");
    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    assert!(store.recovery().corrupt_tail_events > 0, "tail not detected");
    let (out, _) = run_session(store);
    let err = out.unwrap_err().to_string();
    assert!(err.contains("corrupt WAL tail"), "{err}");

    // proceed: the wounded round is voided and training completes the
    // remaining schedule without it
    let dir = make_wounded("corrupt-proceed");
    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    let wm = WorkflowManager::test_mode(CLIENTS, deterministic_registry(), 4);
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig {
            mode: PrivacyMode::SecAggDp,
            clip_norm: 4.0,
            noise_multiplier: 0.05,
            weight_scale: 128.0,
            reveal_policy: RevealPolicy::Proceed,
            ..PrivacyConfig::default()
        })
        .with_round_store(store.clone())
        .with_session_tag(SESSION_TAG);
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(ROUNDS)),
            3,
        )
        .unwrap();
    let report = server.recover().unwrap();
    assert_eq!(report.voided, 1, "the wounded round must be voided");
    server.learn().unwrap();
    // round 0 replayed; round 1 burned — never re-run, never resumed
    assert_eq!(server.history().len(), 1);
    let voided: Vec<RoundState> = store
        .rounds()
        .unwrap()
        .into_iter()
        .filter(|r| r.phase == RoundPhase::Voided)
        .collect();
    assert_eq!(voided.len(), 1);
    assert_eq!(
        voided[0].void_reason.as_deref(),
        Some("corrupt WAL tail truncated mid-round")
    );
    assert_eq!(
        server.metrics().counter("fact.roundstore.voided").get(),
        1
    );
}

/// A stateful server optimizer survives the crash window the `Aggregated`
/// event exists for: kill the coordinator at EVERY durable write of a
/// clear-mode FedAvgM session — in particular right after `Aggregated`
/// hits disk and before `Closed` — and require the resumed session to end
/// with bit-identical parameters AND bit-identical momentum buffers.
/// Before the optimizer state rode inside `Aggregated`, this resume could
/// only restore parameters and silently reset the velocity to zero.
#[test]
fn fedavgm_killed_at_aggregated_resumes_with_exact_momentum() {
    use feddart::fact::rounds::optimizer::FedAvgM;

    let fedavgm = || FedAvgM { lr: 1.0, momentum: 0.9 };
    let run = |store: Arc<dyn RoundStore>| -> (feddart::Result<()>, FactServer) {
        let wm =
            WorkflowManager::test_mode(CLIENTS, deterministic_registry(), 4);
        let mut server = FactServer::new(wm)
            .with_server_opt(Arc::new(fedavgm()))
            .with_round_store(store)
            .with_session_tag(SESSION_TAG);
        server
            .initialization_by_model(
                Arc::new(TestModel),
                Arc::new(FixedRoundFl(ROUNDS)),
                3,
            )
            .unwrap();
        if let Err(e) = server.recover() {
            return (Err(e), server);
        }
        (server.learn(), server)
    };

    // uninterrupted reference, counting the session's durable writes
    let ref_dir = tmp_dir("avgm-ref");
    let counter = Arc::new(KillStore::new(&ref_dir, i64::MAX));
    let start = counter.remaining.load(Ordering::SeqCst);
    let (out, reference) = run(counter.clone());
    out.unwrap();
    let total_writes = start - counter.remaining.load(Ordering::SeqCst);
    // clear-mode rounds log Configured/LearnDispatched/LearnClosed/
    // Aggregated/Closed = 5 events each
    assert_eq!(total_writes, (ROUNDS * 5) as i64);
    let ref_cluster = &reference.container().clusters[0];
    assert_eq!(ref_cluster.opt_state.step, ROUNDS as u64);
    assert!(
        ref_cluster.opt_state.buffers.contains_key("momentum"),
        "FedAvgM must have accumulated a velocity buffer"
    );
    // momentum made the update visibly different from plain replacement:
    // a resume that silently reset the buffer could not stay identical
    assert!(ref_cluster.opt_state.buffers["momentum"].iter().any(|v| *v != 0.0));

    for k in 1..=total_writes {
        let dir = tmp_dir(&format!("avgm-kill-{k}"));
        let (out, _) = run(Arc::new(KillStore::new(&dir, k)));
        out.unwrap_err();
        let (out, resumed) = run(Arc::new(WalRoundStore::open(&dir).unwrap()));
        out.unwrap_or_else(|e| panic!("kill point {k}: resume failed: {e}"));
        let cluster = &resumed.container().clusters[0];
        assert_eq!(
            cluster.params, ref_cluster.params,
            "kill point {k}: resumed FedAvgM params diverged"
        );
        assert_eq!(
            cluster.opt_state, ref_cluster.opt_state,
            "kill point {k}: resumed momentum buffers diverged"
        );
        assert_eq!(resumed.history().len(), ROUNDS, "kill point {k}");
    }
}

/// Plain-mode sanity: the WAL also rides along without privacy — the
/// store sees the same Configured → Learn → Aggregated → Closed arc and a
/// restart resumes it (this is the path `feddart run --round-store` uses
/// without `--privacy`).
#[test]
fn plain_rounds_without_privacy_also_recover() {
    // reference: uninterrupted 2-round clear session
    let run_clear = |store: Arc<dyn RoundStore>| -> (feddart::Result<()>, FactServer) {
        let wm =
            WorkflowManager::test_mode(CLIENTS, deterministic_registry(), 4);
        let mut server = FactServer::new(wm)
            .with_round_store(store)
            .with_session_tag(SESSION_TAG);
        server
            .initialization_by_model(
                Arc::new(TestModel),
                Arc::new(FixedRoundFl(ROUNDS)),
                3,
            )
            .unwrap();
        if let Err(e) = server.recover() {
            return (Err(e), server);
        }
        (server.learn(), server)
    };

    let ref_dir = tmp_dir("clear-ref");
    let (out, reference) =
        run_clear(Arc::new(WalRoundStore::open(&ref_dir).unwrap()));
    out.unwrap();

    // clear rounds log Configured/LearnDispatched/LearnClosed/Aggregated/
    // Closed = 5 events each; kill at write 7 = mid round 1, right after
    // its LearnDispatched hit disk
    let dir = tmp_dir("clear-kill");
    let (out, _) = run_clear(Arc::new(KillStore::new(&dir, 7)));
    out.unwrap_err();
    let (out, resumed) =
        run_clear(Arc::new(WalRoundStore::open(&dir).unwrap()));
    out.unwrap();
    assert_eq!(
        resumed.container().clusters[0].params,
        reference.container().clusters[0].params
    );
    assert_eq!(resumed.history().len(), ROUNDS);
}
