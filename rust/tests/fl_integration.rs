//! End-to-end federated learning through the full stack in test mode:
//! FactServer (Alg 3-5) -> WorkflowManager -> Selector/Aggregator ->
//! TestModeDart (real Scheduler + Petri nets) -> FactClientRuntime ->
//! PJRT engine executing the AOT JAX/Pallas artifacts.

use std::sync::Arc;
use std::time::Duration;

use feddart::coordinator::WorkflowManager;
use feddart::dart::faults::{FaultInjector, FaultProfile};
use feddart::dart::testmode::SimClient;
use feddart::dart::TaskRegistry;
use feddart::fact::clustering::{ClusterContainer, KMeansClustering};
use feddart::fact::data::{synthesize, Partition, SyntheticConfig};
use feddart::fact::ensemble::{register_ensemble_tasks, EnsembleFlModel};
use feddart::fact::model::{FactModel, HloModel, Hyper};
use feddart::fact::stopping::{FixedClusteringRounds, FixedRoundFl, LossPlateauFl};
use feddart::fact::{Aggregation, FactClientRuntime, FactServer};
use feddart::runtime::{default_artifacts_dir, Engine};

fn have_artifacts() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

/// Build a complete test-mode FL stack over the mlp_default model.
fn mlp_stack(
    clients: usize,
    partition: Partition,
    seed: u64,
    parallelism: usize,
    agg: Aggregation,
) -> (FactServer, Arc<dyn FactModel>, Engine) {
    let engine = Engine::load(&default_artifacts_dir(), 1).unwrap();
    let registry = TaskRegistry::new();
    let rt = FactClientRuntime::new(engine.clone());
    let data = synthesize(&SyntheticConfig {
        clients,
        samples_per_client: 512,
        dim: 32,
        classes: 10,
        partition,
        seed,
    })
    .unwrap();
    for (name, d) in data {
        rt.add_supervised(&name, d);
    }
    rt.register(&registry);
    let wm = WorkflowManager::test_mode(clients, registry, parallelism);
    let model = HloModel::arc(&engine, "mlp_default", agg).unwrap();
    (FactServer::new(wm), model, engine)
}

#[test]
fn fedavg_mlp_converges_and_beats_chance() {
    if !have_artifacts() {
        return;
    }
    let (mut server, model, engine) =
        mlp_stack(6, Partition::Iid, 42, 4, Aggregation::WeightedFedAvg);
    server.hyper = Hyper { lr: 0.2, mu: 0.0, local_steps: 4, round: 0 };
    server
        .initialization_by_model(model, Arc::new(FixedRoundFl(15)), 42)
        .unwrap();
    server.learn().unwrap();
    let hist = server.history();
    assert_eq!(hist.len(), 15);
    let first = hist[0].mean_loss;
    let last = hist.last().unwrap().mean_loss;
    assert!(last < 0.8 * first, "no convergence: {first} -> {last}");
    let evals = server.evaluate().unwrap();
    assert!(evals[0].accuracy > 0.25, "accuracy {}", evals[0].accuracy);
    // every round heard from every client
    assert!(hist.iter().all(|r| r.n_clients == 6));
    engine.shutdown();
}

#[test]
fn loss_plateau_criterion_stops_early() {
    if !have_artifacts() {
        return;
    }
    let (mut server, model, engine) =
        mlp_stack(4, Partition::Iid, 7, 4, Aggregation::WeightedFedAvg);
    // tiny lr: loss barely moves, plateau should fire well before the cap
    server.hyper = Hyper { lr: 1e-5, mu: 0.0, local_steps: 1, round: 0 };
    server
        .initialization_by_model(
            model,
            Arc::new(LossPlateauFl { patience: 3, min_delta: 0.05, max_rounds: 40 }),
            7,
        )
        .unwrap();
    server.learn().unwrap();
    assert!(
        server.history().len() < 40,
        "plateau criterion never fired ({} rounds)",
        server.history().len()
    );
    engine.shutdown();
}

#[test]
fn fault_injection_does_not_stop_the_workflow() {
    if !have_artifacts() {
        return;
    }
    // E3 in miniature: flaky clients + stragglers, training still completes
    let engine = Engine::load(&default_artifacts_dir(), 1).unwrap();
    let registry = TaskRegistry::new();
    let rt = FactClientRuntime::new(engine.clone());
    let n = 6;
    let data = synthesize(&SyntheticConfig {
        clients: n,
        samples_per_client: 256,
        dim: 32,
        classes: 10,
        partition: Partition::Iid,
        seed: 3,
    })
    .unwrap();
    for (name, d) in data {
        rt.add_supervised(&name, d);
    }
    rt.register(&registry);
    let clients: Vec<SimClient> = (0..n)
        .map(|i| SimClient {
            name: format!("client-{i}"),
            hardware: Default::default(),
            faults: if i % 2 == 0 {
                FaultInjector::new(i as u64, FaultProfile::flaky(0.3))
            } else {
                FaultInjector::new(i as u64, FaultProfile::straggler(2.0, 5))
            },
            capacity: 1,
        })
        .collect();
    let wm = WorkflowManager::test_mode_with(clients, registry, 4);
    let mut server = FactServer::new(wm)
        .with_hyper(Hyper { lr: 0.2, mu: 0.0, local_steps: 2, round: 0 });
    server.round_timeout = Duration::from_secs(120);
    let model = HloModel::arc(&engine, "mlp_default", Aggregation::WeightedFedAvg).unwrap();
    server
        .initialization_by_model(model, Arc::new(FixedRoundFl(8)), 3)
        .unwrap();
    server.learn().unwrap();
    let hist = server.history();
    assert_eq!(hist.len(), 8, "rounds did not complete under churn");
    let first = hist[0].mean_loss;
    let last = hist.last().unwrap().mean_loss;
    assert!(last < first, "no progress under churn: {first} -> {last}");
    engine.shutdown();
}

#[test]
fn clustered_fl_beats_single_global_on_latent_groups() {
    if !have_artifacts() {
        return;
    }
    // E4 in miniature: 3 latent groups with permuted labels.
    let groups = 3;
    let clients = 6;
    let seed = 11;

    // --- single global model ---
    let (mut single, model, engine) = mlp_stack(
        clients,
        Partition::LatentGroups { groups },
        seed,
        4,
        Aggregation::WeightedFedAvg,
    );
    single.hyper = Hyper { lr: 0.2, mu: 0.0, local_steps: 4, round: 0 };
    single
        .initialization_by_model(Arc::clone(&model), Arc::new(FixedRoundFl(10)), 1)
        .unwrap();
    single.learn().unwrap();
    let acc_single = single.evaluate().unwrap()[0].accuracy;

    // --- clustered FL: warmup round then k-means reclustering ---
    let (mut clustered, model2, engine2) = mlp_stack(
        clients,
        Partition::LatentGroups { groups },
        seed,
        4,
        Aggregation::WeightedFedAvg,
    );
    clustered.hyper = Hyper { lr: 0.2, mu: 0.0, local_steps: 4, round: 0 };
    let names = clustered.workflow_manager().get_all_device_names().unwrap();
    let params = model2.init_params(1).unwrap();
    let container = ClusterContainer::single(Arc::clone(&model2), params, names);
    clustered
        .initialization_by_cluster_container(
            container,
            Box::new(KMeansClustering::new(groups)),
            Box::new(FixedClusteringRounds(2)),
            Arc::new(FixedRoundFl(5)),
        )
        .unwrap();
    clustered.learn().unwrap();
    let evals = clustered.evaluate().unwrap();
    let acc_clustered: f64 = evals
        .iter()
        .map(|e| e.accuracy * e.n_clients as f64)
        .sum::<f64>()
        / clients as f64;

    // k-means should recover the latent groups
    assert_eq!(clustered.container().clusters.len(), groups);
    assert!(
        acc_clustered > acc_single + 0.05,
        "clustering did not help: clustered {acc_clustered:.3} vs single {acc_single:.3}"
    );
    engine.shutdown();
    engine2.shutdown();
}

#[test]
fn ensemble_fl_stacking_runs_federated() {
    if !have_artifacts() {
        return;
    }
    // E8 in miniature: federated stacking head over local base learners.
    let engine = Engine::load(&default_artifacts_dir(), 1).unwrap();
    let registry = TaskRegistry::new();
    let rt = FactClientRuntime::new(engine.clone());
    let n = 4;
    let classes = 4;
    let data = synthesize(&SyntheticConfig {
        clients: n,
        samples_per_client: 400,
        dim: 8,
        classes,
        partition: Partition::Iid,
        seed: 5,
    })
    .unwrap();
    for (name, d) in data {
        rt.add_supervised(&name, d);
    }
    rt.register(&registry);
    register_ensemble_tasks(&rt, &registry);
    let wm = WorkflowManager::test_mode(n, registry, 2);
    let model = EnsembleFlModel::arc(classes, Aggregation::WeightedFedAvg);

    // drive the ensemble head through the generic task API
    let mut head = model.init_params(0).unwrap();
    for round in 0..12 {
        let hp = Hyper { lr: 0.3, mu: 0.0, local_steps: 5, round };
        let dict: std::collections::BTreeMap<String, feddart::json::Json> = wm
            .get_all_device_names()
            .unwrap()
            .into_iter()
            .map(|c| (c, model.learn_params(&head, &hp).set("classes", classes)))
            .collect();
        let results =
            wm.run_task(dict, "ensemble_learn", Duration::from_secs(60)).unwrap();
        let updates: Vec<_> = results
            .iter()
            .map(|r| model.parse_update(&r.device_name, r.duration, &r.result).unwrap())
            .collect();
        head = model.aggregate(&updates, None).unwrap();
    }
    // evaluate the federated head
    let dict: std::collections::BTreeMap<String, feddart::json::Json> = wm
        .get_all_device_names()
        .unwrap()
        .into_iter()
        .map(|c| (c, model.eval_params(&head).set("classes", classes)))
        .collect();
    let results = wm
        .run_task(dict, "ensemble_evaluate", Duration::from_secs(60))
        .unwrap();
    let (mut correct, mut total) = (0.0, 0.0);
    for r in &results {
        correct += r.result.get("correct").and_then(feddart::json::Json::as_f64).unwrap();
        total += r.result.get("n").and_then(feddart::json::Json::as_f64).unwrap();
    }
    let acc = correct / total;
    assert!(acc > 1.0 / classes as f64 + 0.1, "ensemble accuracy {acc}");
    engine.shutdown();
}

#[test]
fn fedprox_not_catastrophic_under_skew() {
    if !have_artifacts() {
        return;
    }
    // E5 in miniature: strong label skew + many local steps makes FedAvg
    // drift; FedProx (mu > 0) must stay in the same ballpark or better.
    let run = |mu: f32| -> f32 {
        let agg = if mu > 0.0 { Aggregation::FedProx } else { Aggregation::WeightedFedAvg };
        let (mut server, model, engine) =
            mlp_stack(6, Partition::LabelSkew { alpha: 0.1 }, 21, 4, agg);
        server.hyper = Hyper { lr: 0.3, mu, local_steps: 12, round: 0 };
        server
            .initialization_by_model(model, Arc::new(FixedRoundFl(12)), 21)
            .unwrap();
        server.learn().unwrap();
        let loss = server.history().last().unwrap().mean_loss;
        engine.shutdown();
        loss
    };
    let l_fedavg = run(0.0);
    let l_fedprox = run(0.1);
    assert!(
        l_fedprox < l_fedavg * 1.5,
        "fedprox {l_fedprox} catastrophically worse than fedavg {l_fedavg}"
    );
}
