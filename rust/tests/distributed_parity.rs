//! E6: the production path (DART-server + DART-clients over authenticated
//! TCP + the REST-API) must expose the *same workflow* as test mode —
//! "the conversion to a production system is then just a matter of
//! configuration changes" (paper §3).
//!
//! We run the identical federated workload (same seed, same data, same
//! hyperparameters) through both backends and require bit-identical global
//! parameters, plus churn behaviour on the real TCP path.

use std::sync::Arc;
use std::time::Duration;

use feddart::config::ServerConfig;
use feddart::coordinator::WorkflowManager;
use feddart::dart::client::{DartClient, DartClientConfig};
use feddart::dart::server::{DartServer, DartServerConfig};
use feddart::dart::TaskRegistry;
use feddart::fact::data::{synthesize, Partition, SyntheticConfig};
use feddart::fact::model::{HloModel, Hyper};
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::{Aggregation, FactClientRuntime, FactServer};
use feddart::runtime::{default_artifacts_dir, Engine};

fn have_artifacts() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

const N: usize = 4;
const ROUNDS: usize = 5;
const SEED: u64 = 77;

fn registry_with_data(engine: &Engine) -> TaskRegistry {
    let registry = TaskRegistry::new();
    let rt = FactClientRuntime::new(engine.clone());
    let data = synthesize(&SyntheticConfig {
        clients: N,
        samples_per_client: 256,
        dim: 32,
        classes: 10,
        partition: Partition::Iid,
        seed: SEED,
    })
    .unwrap();
    for (name, d) in data {
        rt.add_supervised(&name, d);
    }
    rt.register(&registry);
    registry
}

fn run_fl(wm: WorkflowManager, engine: &Engine) -> Vec<f32> {
    let mut server = FactServer::new(wm)
        .with_hyper(Hyper { lr: 0.2, mu: 0.0, local_steps: 3, round: 0 });
    server.round_timeout = Duration::from_secs(120);
    let model = HloModel::arc(engine, "mlp_default", Aggregation::WeightedFedAvg).unwrap();
    server
        .initialization_by_model(model, Arc::new(FixedRoundFl(ROUNDS)), SEED as i32)
        .unwrap();
    server.learn().unwrap();
    assert_eq!(server.history().len(), ROUNDS);
    server.container().clusters[0].params.clone()
}

#[test]
fn test_mode_and_tcp_mode_produce_identical_parameters() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::load(&default_artifacts_dir(), 2).unwrap();

    // --- test mode ---
    let wm_test = WorkflowManager::test_mode(N, registry_with_data(&engine), 2);
    let params_test = run_fl(wm_test, &engine);

    // --- production mode: real DART-server, TCP clients, REST-API ---
    let dart = DartServer::start(DartServerConfig::default()).unwrap();
    let key = b"feddart-demo-key";
    let registry = registry_with_data(&engine);
    let _clients: Vec<DartClient> = (0..N)
        .map(|i| {
            DartClient::spawn(
                DartClientConfig::new(
                    &format!("client-{i}"),
                    &dart.dart_addr().to_string(),
                    key,
                ),
                registry.clone(),
            )
        })
        .collect();
    let wm_prod = WorkflowManager::production(&ServerConfig {
        server: dart.rest_addr().to_string(),
        client_key: "000".into(),
    })
    .unwrap();
    wm_prod.start_fed_dart(N, Duration::from_secs(10)).unwrap();
    let params_prod = run_fl(wm_prod, &engine);

    assert_eq!(params_test.len(), params_prod.len());
    let max_diff = params_test
        .iter()
        .zip(&params_prod)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert_eq!(
        max_diff, 0.0,
        "test mode and production mode diverged by {max_diff}"
    );
    engine.shutdown();
}

#[test]
fn tcp_client_churn_mid_training_recovers() {
    if !have_artifacts() {
        return;
    }
    // Kill one TCP client mid-run; the unit re-queues and a re-joined
    // client finishes the round (the paper's connect/disconnect-any-time).
    let engine = Engine::load(&default_artifacts_dir(), 2).unwrap();
    let mut cfg = DartServerConfig::default();
    cfg.heartbeat_timeout_ms = 500;
    let dart = DartServer::start(cfg).unwrap();
    let key = b"feddart-demo-key";
    let registry = registry_with_data(&engine);
    let mut clients: Vec<DartClient> = (0..N)
        .map(|i| {
            DartClient::spawn(
                DartClientConfig::new(
                    &format!("client-{i}"),
                    &dart.dart_addr().to_string(),
                    key,
                ),
                registry.clone(),
            )
        })
        .collect();
    let wm = WorkflowManager::production(&ServerConfig {
        server: dart.rest_addr().to_string(),
        client_key: "000".into(),
    })
    .unwrap();
    wm.start_fed_dart(N, Duration::from_secs(10)).unwrap();

    // run training on a background thread while we churn a client
    let engine2 = engine.clone();
    let trainer = std::thread::spawn(move || run_fl(wm, &engine2));

    // churn: drop client-3 then bring it back
    std::thread::sleep(Duration::from_millis(150));
    clients.pop().unwrap().shutdown();
    std::thread::sleep(Duration::from_millis(300));
    clients.push(DartClient::spawn(
        DartClientConfig::new("client-3", &dart.dart_addr().to_string(), key),
        registry.clone(),
    ));

    let params = trainer.join().expect("training paniced under churn");
    assert_eq!(
        params.len(),
        engine.manifest().model("mlp_default").unwrap().param_count
    );
    engine.shutdown();
}
