//! Self-hosting gate for `feddart lint` (ISSUE 9 acceptance).
//!
//! Two halves:
//!
//! 1. **The repo lints itself clean.**  `lint_repo_is_clean` loads the
//!    real source tree (the parent of `CARGO_MANIFEST_DIR`) and asserts
//!    zero findings across every rule.  A change that introduces an
//!    `unwrap()` in transport code, a derived `Debug` over key material,
//!    a lock acquired against the declared hierarchy, or an undocumented
//!    metric fails *this test* — before CI even reaches the dedicated
//!    lint job.
//!
//! 2. **Every rule family still bites.**  A clean self-lint is only
//!    meaningful if the rules detect anything at all, so the fixture
//!    tests seed a temp-dir source tree with one violation per family
//!    and assert the engine flags each.  This guards against the
//!    classic linter failure mode: a refactor that silently turns every
//!    rule into a no-op keeps the repo "clean" forever.

use std::path::{Path, PathBuf};

use feddart::analysis::{report, Linter};

// ------------------------------------------------------------ self-host

#[test]
fn lint_repo_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf();
    let linter = Linter::load(&root).expect("load repo sources");
    let rep = linter.run(None).expect("run all rules");
    assert!(
        rep.findings.is_empty(),
        "repo must lint clean; findings:\n{}",
        report::render_text(&rep)
    );
    assert!(rep.files_scanned > 20, "expected to scan the real tree");
    assert_eq!(rep.rules_run.len(), feddart::analysis::ALL_RULES.len());
}

// ------------------------------------------------------------- fixtures

fn fixture_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("feddart-lint-fixture-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn put(root: &Path, rel: &str, src: &str) {
    let p = root.join(rel);
    std::fs::create_dir_all(p.parent().expect("parent")).expect("mkdir");
    std::fs::write(p, src).expect("write fixture");
}

fn run_family(root: &Path, family: &str) -> Vec<String> {
    let linter = Linter::load(root).expect("load fixtures");
    let rep = linter.run(Some(family)).expect("run family");
    rep.findings.iter().map(|f| f.rule.to_string()).collect()
}

#[test]
fn fixture_panic_family_bites() {
    let root = fixture_root("panic");
    put(
        &root,
        "rust/src/http/mod.rs",
        "pub fn handle(v: Vec<u8>, i: usize) -> u8 {\n\
         \x20   let first = v.first().unwrap();\n\
         \x20   let _ = first;\n\
         \x20   v[i]\n\
         }\n\
         pub fn boom() {\n\
         \x20   panic!(\"no\");\n\
         }\n",
    );
    let rules = run_family(&root, "panic");
    assert!(rules.iter().any(|r| r == "panic-unwrap"), "{rules:?}");
    assert!(rules.iter().any(|r| r == "panic-index"), "{rules:?}");
    assert!(rules.iter().any(|r| r == "panic-macro"), "{rules:?}");
}

#[test]
fn fixture_crypto_family_bites() {
    let root = fixture_root("crypto");
    put(
        &root,
        "rust/src/privacy/keys.rs",
        "#[derive(Debug, Clone)]\n\
         pub struct Keys {\n\
         \x20   pub secret_key: [u8; 32],\n\
         \x20   pub tag: u64,\n\
         }\n\
         pub fn check(expected: &[u8], secret: &[u8]) -> bool {\n\
         \x20   let r = Rng::new(7);\n\
         \x20   let _ = r;\n\
         \x20   println!(\"leak {:?}\", secret);\n\
         \x20   secret == expected\n\
         }\n",
    );
    let rules = run_family(&root, "crypto");
    assert!(rules.iter().any(|r| r == "crypto-secret-debug"), "{rules:?}");
    assert!(rules.iter().any(|r| r == "crypto-secret-leak"), "{rules:?}");
    assert!(rules.iter().any(|r| r == "crypto-ct-eq"), "{rules:?}");
    assert!(rules.iter().any(|r| r == "crypto-weak-rng"), "{rules:?}");
}

#[test]
fn fixture_lock_family_bites() {
    let root = fixture_root("lock");
    put(
        &root,
        "rust/src/dart/scheduler.rs",
        "pub fn bad(&self) {\n\
         \x20   let q = self.queue.lock().unwrap();\n\
         \x20   let w = self.workers.lock().unwrap();\n\
         \x20   let _ = (q, w);\n\
         \x20   self.file.sync_all().ok();\n\
         }\n",
    );
    let rules = run_family(&root, "lock");
    assert!(rules.iter().any(|r| r == "lock-order"), "{rules:?}");
    assert!(rules.iter().any(|r| r == "lock-io"), "{rules:?}");
}

#[test]
fn fixture_drift_family_bites() {
    let root = fixture_root("drift");
    put(
        &root,
        "rust/src/coordinator/round_store.rs",
        "pub enum EventKind { Opened, Closed, Voided }\n\
         pub fn transition(ev: &EventKind) {\n\
         \x20   match ev { EventKind::Opened => {}, _ => {} }\n\
         }\n\
         pub fn absorb(ev: &EventKind) {\n\
         \x20   match ev {\n\
         \x20       EventKind::Opened => {}\n\
         \x20       EventKind::Closed => {}\n\
         \x20       EventKind::Voided => {}\n\
         \x20   }\n\
         }\n\
         pub fn emit() {\n\
         \x20   bump(\"fact.fixture_counter\");\n\
         }\n",
    );
    put(
        &root,
        "rust/src/fact/server.rs",
        "pub fn settle(&mut self) {\n\
         \x20   self.ledger.append_charge(1);\n\
         \x20   self.trace.dump_round(1);\n\
         }\n",
    );
    put(&root, "docs/OPERATIONS.md", "# Operations\n\nNo counters yet.\n");
    let rules = run_family(&root, "drift");
    assert!(
        rules.iter().any(|r| r == "drift-event-coverage"),
        "{rules:?}"
    );
    assert!(rules.iter().any(|r| r == "drift-trace-order"), "{rules:?}");
    assert!(rules.iter().any(|r| r == "drift-metrics-doc"), "{rules:?}");
}

#[test]
fn fixture_drift_opt_state_replay_bites() {
    // the event schema persists server-optimizer state, but the absorb
    // replay path pattern-matches the field away: replay would silently
    // drop momentum/Adam buffers
    let root = fixture_root("drift-opt-state");
    put(
        &root,
        "rust/src/coordinator/round_store.rs",
        "pub enum EventKind { Aggregated { params: u64, opt_state: u64 } }\n\
         pub fn transition(ev: &EventKind) {\n\
         \x20   match ev { EventKind::Aggregated { .. } => {} }\n\
         }\n\
         pub fn absorb(ev: &EventKind) {\n\
         \x20   match ev { EventKind::Aggregated { .. } => {} }\n\
         }\n",
    );
    put(&root, "docs/OPERATIONS.md", "# Operations\n");
    let rules = run_family(&root, "drift");
    assert!(
        rules.iter().any(|r| r == "drift-event-coverage"),
        "absorb dropping opt_state must be flagged: {rules:?}"
    );
}

#[test]
fn fixture_pragma_suppresses_at_engine_level() {
    let root = fixture_root("pragma");
    put(
        &root,
        "rust/src/http/mod.rs",
        "pub fn boom() {\n\
         \x20   // feddart-lint: allow(panic-macro): fixture justification\n\
         \x20   panic!(\"covered by the pragma above\");\n\
         }\n",
    );
    let rules = run_family(&root, "panic");
    assert!(
        rules.is_empty(),
        "pragma should suppress the sole finding: {rules:?}"
    );
}
