//! End-to-end chaos soak (ISSUE 7 acceptance): a multi-round secagg+dp
//! session with partial participation driven through layered fault
//! profiles — flaky clients (drop-before / crash-during each unit),
//! 3x stragglers with injected network latency, and one injected
//! coordinator crash mid-session (`KillStore`) — must leave every round
//! in a terminal phase (`Closed` or `Voided`), never wedge a round in
//! flight, and keep the ε-ledger strictly monotone across the crash.
//!
//! A second, clear-view soak (dp only, no masking) additionally pins the
//! aggregate: the final cluster params equal the weighted FedAvg of
//! exactly the updates the server counted — chaos may shrink the
//! reporting subset, but never corrupt what is aggregated.
//!
//! The client side reuses the deterministic engine-free registry of the
//! recovery tests (keys/shares/masks/noise all pure in `(round_id,
//! device)`), so the resumed session reproduces byte-identical
//! contributions for re-run phases.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

use feddart::config::{DeadlineMode, HardwareConfig, ParticipationConfig, SamplingStrategy};
use feddart::coordinator::round_store::{
    EventKind, LedgerCharge, MemRoundStore, RecoveryStatus, RoundEvent,
    RoundPhase, RoundState, StoredUpdate,
};
use feddart::coordinator::workflow::WorkflowManager;
use feddart::coordinator::{RoundStore, WalRoundStore};
use feddart::dart::faults::{FaultInjector, FaultProfile};
use feddart::dart::testmode::SimClient;
use feddart::dart::TaskRegistry;
use feddart::error::FedError;
use feddart::fact::aggregation::Aggregation;
use feddart::fact::model::FactModel;
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::FactServer;
use feddart::json::Json;
use feddart::privacy::dp::DpAccountant;
use feddart::privacy::{
    dp, from_hex, keys, masking, round_id_from_hex, shamir, to_hex,
    PrivacyConfig, PrivacyMode,
};
use feddart::util::rng::{golden_f32, Rng};
use feddart::util::tensorbuf::TensorBuf;

const PARAMS: usize = 32;
const CLIENTS: usize = 8;
const ROUNDS: usize = 6;
const SESSION_TAG: u64 = 0xc4a0_5067_0000_0001;

// ------------------------------------------------------------ fixture

struct TestModel;

impl FactModel for TestModel {
    fn name(&self) -> &str {
        "chaosmodel"
    }
    fn param_count(&self) -> usize {
        PARAMS
    }
    fn init_params(&self, seed: i32) -> feddart::Result<Vec<f32>> {
        Ok(golden_f32(seed as u32, PARAMS))
    }
    fn aggregation(&self) -> &Aggregation {
        &Aggregation::WeightedFedAvg
    }
}

fn device_index(device: &str) -> usize {
    device.rsplit('-').next().unwrap().parse().unwrap()
}

fn client_secret(idx: usize) -> [u8; 32] {
    [idx as u8 + 1; 32]
}

fn sample_weight(device: &str) -> f32 {
    100.0 + 10.0 * device_index(device) as f32
}

fn round_keys_of(device: &str, round_id: u64) -> keys::RoundKeys {
    keys::keypair(&keys::derive_round_secret(
        &client_secret(device_index(device)),
        round_id,
        device,
    ))
}

fn keys_map_of(p: &Json) -> BTreeMap<String, String> {
    p.need("keys")
        .unwrap()
        .as_obj()
        .unwrap()
        .iter()
        .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
        .collect()
}

/// Deterministic privacy-aware clients (the recovery-test construction):
/// every derived quantity is a pure function of `(round_id, device)`, so
/// requeued units and resumed phases reproduce identical bytes.
fn deterministic_registry() -> TaskRegistry {
    let registry = TaskRegistry::new();
    registry.register("fact_init", |_| Ok(Json::Null));

    registry.register("fact_keys", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let kp = round_keys_of(&device, round_id);
        Ok(Json::obj().set("pubkey", keys::pubkey_hex(&kp.public)))
    });

    registry.register("fact_shares", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let threshold = p.need("threshold")?.as_usize().unwrap();
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let peers: Vec<(String, u8)> = keys_map
            .keys()
            .enumerate()
            .filter(|(_, n)| *n != &device)
            .map(|(i, n)| (n.clone(), i as u8 + 1))
            .collect();
        let xs: Vec<u8> = peers.iter().map(|(_, x)| *x).collect();
        let mut rng = Rng::new(round_id ^ device_index(&device) as u64);
        let split = shamir::split_at(&kp.secret, threshold, &xs, &mut rng)?;
        let mut shares = Json::obj();
        let mut commits = Json::obj();
        for (share, (peer, _)) in split.iter().zip(peers.iter()) {
            let their = keys::parse_pubkey_hex(&keys_map[peer])?;
            let sk = keys::shared_key(&kp.secret, &their);
            let ct =
                keys::encrypt_share(&sk, round_id, &device, peer, &share.to_bytes());
            shares = shares.set(peer, to_hex(&ct));
            commits = commits.set(peer, to_hex(&shamir::share_commitment(share)));
        }
        Ok(Json::obj().set("shares", shares).set("commits", commits))
    });

    registry.register("fact_learn", |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let idx = device_index(&device);
        let global = TensorBuf::from_json(p.need("params")?)
            .map_err(|e| FedError::Task(e.to_string()))?;
        let gs = global.as_f32_slice();
        let delta = golden_f32(idx as u32 + 1, gs.len());
        let mut params: Vec<f32> =
            gs.iter().zip(&delta).map(|(g, d)| g + 0.1 * d).collect();
        let n_samples = sample_weight(&device);

        let Some(pj) = p.get("privacy") else {
            return Ok(Json::obj()
                .set("params", TensorBuf::from_f32_vec(params))
                .set("n_samples", n_samples)
                .set("loss", 0.5));
        };
        let cfg = PrivacyConfig::from_json(pj)?;
        let round_id =
            round_id_from_hex(pj.need("round_id")?.as_str().unwrap_or_default())?;
        if cfg.mode.has_dp() {
            let mut rng = Rng::new(round_id ^ idx as u64);
            dp::privatize_update(
                &mut params,
                gs,
                cfg.clip_norm,
                cfg.noise_multiplier,
                &mut rng,
            )?;
        }
        if cfg.mode.has_secagg() {
            let keys_map: BTreeMap<String, String> = pj
                .need("keys")?
                .as_obj()
                .unwrap()
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect();
            let participants: Vec<String> = pj
                .need("participants")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|j| j.as_str().map(String::from))
                .collect();
            let kp = round_keys_of(&device, round_id);
            let seeds: Vec<(i64, [u8; 32])> = participants
                .iter()
                .filter(|c| *c != &device)
                .map(|peer| {
                    let their = keys::parse_pubkey_hex(&keys_map[peer]).unwrap();
                    let sk = keys::shared_key(&kp.secret, &their);
                    (
                        masking::pair_sign(&device, peer),
                        keys::pair_seed_from_shared(&sk, round_id, &device, peer),
                    )
                })
                .collect();
            let weighted =
                pj.get("weighted").and_then(Json::as_bool).unwrap_or(true);
            let weight = if weighted {
                n_samples as f64 / cfg.weight_scale as f64
            } else {
                1.0
            };
            params = masking::mask_update_with_seeds(
                &params,
                weight,
                &seeds,
                cfg.frac_bits,
            )?;
        }
        Ok(Json::obj()
            .set("params", TensorBuf::from_f32_vec(params))
            .set("n_samples", n_samples)
            .set("loss", 0.5))
    });

    registry.register("fact_reveal", |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let round_id =
            round_id_from_hex(p.need("round_id")?.as_str().unwrap_or_default())?;
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let mut seeds = Json::obj();
        let mut shares_out = Json::obj();
        for d in p.need("dropped")?.as_arr().unwrap_or(&[]) {
            let Some(name) = d.as_str() else { continue };
            if name == device {
                continue;
            }
            let Some(pub_hex) = keys_map.get(name) else { continue };
            let their = keys::parse_pubkey_hex(pub_hex)?;
            let sk = keys::shared_key(&kp.secret, &their);
            seeds = seeds.set(
                name,
                to_hex(&keys::pair_seed_from_shared(&sk, round_id, &device, name)),
            );
            if let Some(ct_hex) =
                p.get("shares").and_then(|s| s.get(name)).and_then(Json::as_str)
            {
                let plain = keys::decrypt_share(
                    &sk,
                    round_id,
                    name,
                    &device,
                    &from_hex(ct_hex)?,
                )?;
                shares_out = shares_out.set(name, to_hex(&plain));
            }
        }
        Ok(Json::obj().set("seeds", seeds).set("shares", shares_out))
    });
    registry
}

/// The chaos fleet: 2 flaky clients (p=0.2 split across drop-before and
/// crash-during), 2 3x stragglers with injected latency, 4 reliable.
fn chaos_clients() -> Vec<SimClient> {
    (0..CLIENTS)
        .map(|i| {
            let profile = match i {
                0 | 1 => FaultProfile::flaky(0.2),
                2 | 3 => FaultProfile::straggler(3.0, 40),
                _ => FaultProfile::reliable(),
            };
            SimClient {
                name: format!("client-{i}"),
                hardware: HardwareConfig::default(),
                faults: FaultInjector::new(0xc4a0_5000 + i as u64, profile),
                capacity: 1,
            }
        })
        .collect()
}

fn participation() -> ParticipationConfig {
    ParticipationConfig {
        sample_rate: 0.75, // cohort of 6 from 8
        quorum: 0.6,       // ceil(0.6 * 6) = 4
        deadline_ms: 2_000,
        late_grace_ms: 50,
        deadline: DeadlineMode::P90,
        deadline_margin: 2.0,
        deadline_min_ms: 300,
        deadline_max_ms: 3_000,
        min_cohort: 3,
        strategy: SamplingStrategy::Uniform,
        seed: 4_242,
        ..Default::default()
    }
}

// ---------------------------------------------------------- kill store

/// Delegates to a real [`WalRoundStore`] but injects a coordinator
/// crash: the `kill_after`-th durable write persists and then errors —
/// the moment a real process would die with the record already on disk —
/// and every later write fails like a dead process.
struct KillStore {
    inner: WalRoundStore,
    remaining: AtomicI64,
}

impl KillStore {
    fn new(dir: &std::path::Path, kill_after: i64) -> KillStore {
        KillStore {
            inner: WalRoundStore::open(dir).unwrap(),
            remaining: AtomicI64::new(kill_after),
        }
    }

    fn tick(&self) -> bool {
        self.remaining.fetch_sub(1, Ordering::SeqCst) <= 1
    }

    fn dead(&self) -> bool {
        self.remaining.load(Ordering::SeqCst) <= 0
    }

    fn crash<T>() -> feddart::Result<T> {
        Err(FedError::Fact("injected coordinator crash".into()))
    }
}

impl RoundStore for KillStore {
    fn append(&self, ev: RoundEvent) -> feddart::Result<RoundPhase> {
        if self.dead() {
            return Self::crash();
        }
        let phase = self.inner.append(ev)?;
        if self.tick() {
            return Self::crash();
        }
        Ok(phase)
    }
    fn append_charge(&self, charge: LedgerCharge) -> feddart::Result<()> {
        if self.dead() {
            return Self::crash();
        }
        self.inner.append_charge(charge)?;
        if self.tick() {
            return Self::crash();
        }
        Ok(())
    }
    fn charges(&self) -> feddart::Result<Vec<LedgerCharge>> {
        self.inner.charges()
    }
    fn round(&self, round_id: u64) -> feddart::Result<Option<RoundState>> {
        self.inner.round(round_id)
    }
    fn rounds(&self) -> feddart::Result<Vec<RoundState>> {
        self.inner.rounds()
    }
    fn session_tag(&self) -> feddart::Result<Option<u64>> {
        self.inner.session_tag()
    }
    fn set_session_tag(&self, tag: u64) -> feddart::Result<u64> {
        self.inner.set_session_tag(tag)
    }
    fn compact(&self) -> feddart::Result<()> {
        self.inner.compact()
    }
    fn recovery(&self) -> RecoveryStatus {
        self.inner.recovery()
    }
}

// ------------------------------------------------------ recording store

/// A [`MemRoundStore`] that additionally snapshots, per round, the
/// counted updates (`LearnClosed`) and the post-apply params
/// (`Aggregated`) *as they stream by* — terminal rounds trim both from
/// the store proper, so a post-hoc aggregate audit needs this tap.
#[derive(Default)]
struct RecordingStore {
    inner: MemRoundStore,
    taps: std::sync::Mutex<BTreeMap<u64, (Vec<StoredUpdate>, Option<Vec<f32>>)>>,
}

impl RoundStore for RecordingStore {
    fn append(&self, ev: RoundEvent) -> feddart::Result<RoundPhase> {
        match &ev.kind {
            EventKind::LearnClosed { updates, .. } => {
                self.taps
                    .lock()
                    .unwrap()
                    .entry(ev.round_id)
                    .or_default()
                    .0 = updates.clone();
            }
            EventKind::Aggregated { params, .. } => {
                self.taps
                    .lock()
                    .unwrap()
                    .entry(ev.round_id)
                    .or_default()
                    .1 = Some(params.as_f32_slice().to_vec());
            }
            _ => {}
        }
        self.inner.append(ev)
    }
    fn append_charge(&self, charge: LedgerCharge) -> feddart::Result<()> {
        self.inner.append_charge(charge)
    }
    fn charges(&self) -> feddart::Result<Vec<LedgerCharge>> {
        self.inner.charges()
    }
    fn round(&self, round_id: u64) -> feddart::Result<Option<RoundState>> {
        self.inner.round(round_id)
    }
    fn rounds(&self) -> feddart::Result<Vec<RoundState>> {
        self.inner.rounds()
    }
    fn session_tag(&self) -> feddart::Result<Option<u64>> {
        self.inner.session_tag()
    }
    fn set_session_tag(&self, tag: u64) -> feddart::Result<u64> {
        self.inner.set_session_tag(tag)
    }
    fn compact(&self) -> feddart::Result<()> {
        self.inner.compact()
    }
    fn recovery(&self) -> RecoveryStatus {
        self.inner.recovery()
    }
}

// ------------------------------------------------------------- drivers

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("feddart-chaos-soak-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn secagg_server(store: Arc<dyn RoundStore>) -> FactServer {
    let wm = WorkflowManager::test_mode_with(
        chaos_clients(),
        deterministic_registry(),
        CLIENTS,
    );
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig {
            mode: PrivacyMode::SecAggDp,
            clip_norm: 4.0,
            noise_multiplier: 0.05,
            weight_scale: 128.0,
            ..PrivacyConfig::default()
        })
        .with_participation(participation())
        .with_round_store(store)
        .with_session_tag(SESSION_TAG);
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(ROUNDS)),
            CLIENTS,
        )
        .unwrap();
    server
}

fn run_session(store: Arc<dyn RoundStore>) -> (feddart::Result<()>, FactServer) {
    let mut server = secagg_server(store);
    if let Err(e) = server.recover() {
        return (Err(e), server);
    }
    let out = server.learn();
    (out, server)
}

/// Replay `charges` in order and require ε to grow strictly with every
/// single charge — the ledger never flatlines or rolls back.
fn assert_epsilon_strictly_monotone(charges: &[LedgerCharge]) {
    assert!(!charges.is_empty(), "session charged nothing");
    let mut acct = DpAccountant::new(charges[0].noise_multiplier);
    let mut prev = 0.0_f64;
    for (i, c) in charges.iter().enumerate() {
        acct.add_round(c.q);
        let eps = acct.epsilon(1e-5);
        assert!(
            eps > prev,
            "ε not strictly monotone at charge {i}: {prev} -> {eps}"
        );
        prev = eps;
    }
}

// --------------------------------------------------------------- tests

/// THE soak: 6 secagg+dp rounds with sampled cohorts, flaky clients,
/// 3x stragglers, and one injected coordinator crash mid-session.  The
/// resumed session must drive every round to a terminal phase with
/// nothing left in flight, and the ε-ledger must be strictly monotone
/// across the crash with the pre-crash prefix preserved verbatim.
#[test]
fn chaos_soak_survives_faults_and_a_mid_session_coordinator_crash() {
    let dir = tmp_dir("secagg");

    // session 1: crash on the 20th durable write (inside round 2-3)
    let killed = Arc::new(KillStore::new(&dir, 20));
    let (out, server) = run_session(killed);
    out.unwrap_err(); // the injected crash must surface
    drop(server);

    // the ledger as the dying coordinator left it
    let pre_crash = WalRoundStore::open(&dir).unwrap().charges().unwrap();

    // session 2: a fresh coordinator restarts from the same WAL
    let store = Arc::new(WalRoundStore::open(&dir).unwrap());
    let (out, server) = run_session(store.clone());
    out.unwrap_or_else(|e| panic!("resumed chaos session failed: {e}"));

    // every round reached a terminal phase; none wedged in flight
    let rounds = store.rounds().unwrap();
    assert_eq!(rounds.len(), ROUNDS, "expected {ROUNDS} rounds");
    for r in &rounds {
        assert!(
            matches!(r.phase, RoundPhase::Closed | RoundPhase::Voided),
            "round {} wedged in {:?}",
            r.round,
            r.phase
        );
    }
    assert!(store.in_flight().unwrap().is_empty());

    // every closed round carries exactly one ε charge; a round voided
    // below the reveal threshold still charges (its clients added noise
    // and shipped data — discarding the aggregate refunds nothing), so
    // the charge count sits between the closed count and the round count
    let closed =
        rounds.iter().filter(|r| r.phase == RoundPhase::Closed).count();
    assert!(closed >= 1, "chaos voided every single round");
    assert!(server.history().len() >= closed);
    let charges = store.charges().unwrap();
    assert!(
        charges.len() >= closed && charges.len() <= ROUNDS,
        "{} charges for {closed} closed of {ROUNDS} rounds",
        charges.len()
    );
    assert_eq!(server.accountant().steps, charges.len() as u64);

    // strict ε monotonicity, and the crash never rewrote the prefix
    assert_epsilon_strictly_monotone(&charges);
    assert!(
        pre_crash.len() <= charges.len(),
        "charges vanished across the crash"
    );
    for (i, (a, b)) in pre_crash.iter().zip(charges.iter()).enumerate() {
        assert_eq!(a.key(), b.key(), "charge {i} reordered across the crash");
        assert!(
            (a.q - b.q).abs() < 1e-12,
            "charge {i} rewritten across the crash"
        );
    }

    // quorum guarantees: every closed round counted at least quorum-many
    // clients or closed at the deadline with what arrived
    for rec in server.history() {
        assert!(rec.n_clients >= 1, "round {} aggregated nothing", rec.round);
        assert!(
            rec.n_clients + rec.late + rec.dropped == rec.sampled,
            "round {} lost count of its cohort",
            rec.round
        );
    }
}

/// Clear-view soak (dp only — updates visible to the server): the same
/// fault fleet over 6 sampled rounds, asserting after the fact that the
/// final cluster params equal the weighted FedAvg of exactly the counted
/// reporting subset of the last round.
#[test]
fn chaos_dp_rounds_aggregate_exactly_the_reporting_subset() {
    let wm = WorkflowManager::test_mode_with(
        chaos_clients(),
        deterministic_registry(),
        CLIENTS,
    );
    let store = Arc::new(RecordingStore::default());
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig {
            mode: PrivacyMode::Dp,
            clip_norm: 4.0,
            noise_multiplier: 0.05,
            ..PrivacyConfig::default()
        })
        .with_participation(participation())
        .with_round_store(store.clone() as Arc<dyn RoundStore>)
        .with_session_tag(SESSION_TAG ^ 1);
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(ROUNDS)),
            CLIENTS,
        )
        .unwrap();
    server.learn().unwrap();

    // all rounds terminal in the (in-memory) store, ε strictly monotone
    let rounds = server.round_store().rounds().unwrap();
    assert_eq!(rounds.len(), ROUNDS);
    for r in &rounds {
        assert!(
            matches!(r.phase, RoundPhase::Closed | RoundPhase::Voided),
            "round {} wedged in {:?}",
            r.round,
            r.phase
        );
    }
    assert!(server.round_store().in_flight().unwrap().is_empty());
    assert_epsilon_strictly_monotone(&server.round_store().charges().unwrap());

    // every aggregated round's post-apply params equal the weighted mean
    // of EXACTLY the updates the server counted at close — late/dropped
    // results never leak into the aggregate
    let taps = store.taps.lock().unwrap();
    let mut audited = 0usize;
    for (round_id, (updates, applied)) in taps.iter() {
        let Some(applied) = applied else { continue };
        audited += 1;
        assert!(
            !updates.is_empty(),
            "round {round_id:#x} aggregated without counted updates"
        );
        let total_w: f64 =
            updates.iter().map(|u| u.n_samples as f64).sum();
        for i in 0..PARAMS {
            let want: f64 = updates
                .iter()
                .map(|u| u.n_samples as f64 * u.params.as_f32_slice()[i] as f64)
                .sum::<f64>()
                / total_w;
            assert!(
                (applied[i] as f64 - want).abs() < 1e-4,
                "round {round_id:#x} param {i}: aggregate {} != weighted \
                 mean {want} of the reporting subset",
                applied[i]
            );
        }
    }
    assert_eq!(
        audited,
        server.history().len(),
        "an aggregated round escaped the audit tap"
    );
}
