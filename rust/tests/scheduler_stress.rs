//! Concurrency stress tests for the sharded scheduler.
//!
//! The sharded design (per-worker queues, task shards, atomic worker
//! registry) replaces a single global mutex, so these tests drive it from
//! many threads at once and assert the two invariants that matter:
//!
//! 1. **No unit is dispatched twice** while running (exactly-once dispatch
//!    when no worker is ever lost).
//! 2. **No task is lost**: every submitted task settles with every unit
//!    accounted for.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use feddart::config::HardwareConfig;
use feddart::dart::scheduler::{Scheduler, TaskSpec, TaskStatus, UnitReport};
use feddart::json::Json;

fn hw() -> HardwareConfig {
    HardwareConfig::default()
}

fn broadcast_spec(workers: &[String], max_retries: u32) -> TaskSpec {
    let params = workers
        .iter()
        .map(|w| (w.clone(), Json::obj().set("x", 1)))
        .collect();
    let mut spec = TaskSpec::new("stress", params);
    spec.max_retries = max_retries;
    spec
}

/// ≥8 worker threads + 2 submitters + heartbeat hammer + reaper, no worker
/// churn: every unit must be dispatched exactly once and every task must
/// finish with a full result set.
#[test]
fn stress_exactly_once_dispatch_no_churn() {
    const WORKERS: usize = 8;
    const TASKS_PER_SUBMITTER: usize = 150;
    const SUBMITTERS: usize = 2;
    let total_tasks = TASKS_PER_SUBMITTER * SUBMITTERS;
    let expected_units = total_tasks * WORKERS;

    let sched = Arc::new(Scheduler::new());
    let names: Vec<String> = (0..WORKERS).map(|i| format!("w{i}")).collect();
    for n in &names {
        sched.add_worker(n, hw(), 4);
    }

    // (task, client) -> dispatch count; must end at exactly 1 everywhere
    let dispatched: Arc<Mutex<HashMap<(u64, String), usize>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let completed = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let task_ids: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));

    let mut handles = Vec::new();

    // worker threads (8): batched poll + batched complete
    for name in &names {
        let sched = Arc::clone(&sched);
        let dispatched = Arc::clone(&dispatched);
        let completed = Arc::clone(&completed);
        let stop = Arc::clone(&stop);
        let name = name.clone();
        handles.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let units = sched.next_units(&name, 4);
                if units.is_empty() {
                    std::thread::yield_now();
                    continue;
                }
                {
                    let mut d = dispatched.lock().unwrap();
                    for u in &units {
                        *d.entry((u.task_id, u.client.clone())).or_insert(0) += 1;
                    }
                }
                let n = units.len();
                let reports = units
                    .into_iter()
                    .map(|u| UnitReport::Done {
                        task_id: u.task_id,
                        client: u.client,
                        duration: 0.0,
                        result: Json::obj().set("ok", true),
                    })
                    .collect();
                assert_eq!(sched.complete_units(reports), n, "completion rejected");
                completed.fetch_add(n, Ordering::Relaxed);
            }
        }));
    }

    // submitter threads (2)
    for _ in 0..SUBMITTERS {
        let sched = Arc::clone(&sched);
        let names = names.clone();
        let task_ids = Arc::clone(&task_ids);
        handles.push(std::thread::spawn(move || {
            for _ in 0..TASKS_PER_SUBMITTER {
                let id = sched.submit(broadcast_spec(&names, 2)).unwrap();
                task_ids.lock().unwrap().push(id);
            }
        }));
    }

    // heartbeat hammer
    {
        let sched = Arc::clone(&sched);
        let names = names.clone();
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                for n in &names {
                    sched.heartbeat(n);
                }
                std::thread::yield_now();
            }
        }));
    }

    // reaper with a huge timeout: scans concurrently, never fires
    {
        let sched = Arc::clone(&sched);
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                assert!(sched.reap_stale_workers(3_600_000).is_empty());
                std::thread::sleep(Duration::from_millis(1));
            }
        }));
    }

    // wait for the full drain (bounded)
    let t0 = Instant::now();
    while completed.load(Ordering::Relaxed) < expected_units {
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "stress drain stuck: {}/{} units",
            completed.load(Ordering::Relaxed),
            expected_units
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }

    // invariant 1: exactly-once dispatch
    let d = dispatched.lock().unwrap();
    assert_eq!(d.len(), expected_units, "not every unit dispatched");
    for ((tid, client), count) in d.iter() {
        assert_eq!(*count, 1, "unit ({tid}, {client}) dispatched {count} times");
    }

    // invariant 2: no task lost, full result sets
    let ids = task_ids.lock().unwrap();
    assert_eq!(ids.len(), total_tasks);
    for id in ids.iter() {
        assert_eq!(sched.status(*id).unwrap(), TaskStatus::Finished, "task {id}");
        assert_eq!(sched.results(*id).unwrap().len(), WORKERS);
    }
    assert_eq!(sched.task_count(), total_tasks);
}

/// Worker churn from a dedicated thread (remove_worker/add_worker racing
/// dispatch and completion): every task must still settle — nothing may be
/// stranded Running on a dead worker or lost from the queues.
#[test]
fn stress_settles_under_concurrent_churn() {
    const WORKERS: usize = 6;
    const TASKS: usize = 60;

    let sched = Arc::new(Scheduler::new());
    let names: Vec<String> = (0..WORKERS).map(|i| format!("w{i}")).collect();
    for n in &names {
        sched.add_worker(n, hw(), 2);
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();

    // worker threads: poll, complete (sometimes fail a unit)
    for (wi, name) in names.iter().enumerate() {
        let sched = Arc::clone(&sched);
        let stop = Arc::clone(&stop);
        let name = name.clone();
        handles.push(std::thread::spawn(move || {
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let units = sched.next_units(&name, 2);
                if units.is_empty() {
                    std::thread::yield_now();
                    continue;
                }
                let reports = units
                    .into_iter()
                    .map(|u| {
                        i += 1;
                        if (i + wi) % 17 == 0 {
                            UnitReport::Failed {
                                task_id: u.task_id,
                                client: u.client,
                                reason: "injected".into(),
                            }
                        } else {
                            UnitReport::Done {
                                task_id: u.task_id,
                                client: u.client,
                                duration: 0.0,
                                result: Json::Null,
                            }
                        }
                    })
                    .collect();
                sched.complete_units(reports);
            }
        }));
    }

    // churn thread: rip workers out and bring them back, racing everything
    {
        let sched = Arc::clone(&sched);
        let names = names.clone();
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut k = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let n = &names[k % names.len()];
                sched.remove_worker(n);
                std::thread::sleep(Duration::from_micros(200));
                sched.add_worker(n, hw(), 2);
                k += 1;
                std::thread::sleep(Duration::from_micros(200));
            }
        }));
    }

    // submit with a huge retry budget so churn cannot exhaust retries; a
    // submit can race a churn-induced dead window ("not connected"), which
    // is a valid rejection — retry until accepted
    let submit_deadline = Instant::now() + Duration::from_secs(30);
    let ids: Vec<u64> = (0..TASKS)
        .map(|_| loop {
            match sched.submit(broadcast_spec(&names, 10_000)) {
                Ok(id) => break id,
                Err(_) => {
                    assert!(
                        Instant::now() < submit_deadline,
                        "submit kept racing churn rejections"
                    );
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        })
        .collect();

    // every task must settle
    let t0 = Instant::now();
    for id in &ids {
        loop {
            let st = sched.status(*id).unwrap();
            if st != TaskStatus::InProgress {
                assert!(
                    st == TaskStatus::Finished || st == TaskStatus::PartiallyFailed,
                    "task {id} ended {st:?}"
                );
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(60),
                "task {id} stuck under churn"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }
}
