//! Partial-participation integration: sampled cohorts, straggler
//! deadlines, quorum closes, and DP amplification through the FACT
//! server.
//!
//! Acceptance (ISSUE 4): a sampled round (q < 1, quorum enforced)
//! completes end-to-end through the FACT server with stragglers dropped,
//! the aggregate matches the reporting subset, and the accountant
//! reports a strictly smaller ε than full participation at the same
//! noise multiplier.
//!
//! The tests run engine-free (the `privacy_secagg.rs` pattern): a custom
//! task registry plays the client side with deterministic per-device
//! updates, scripted stragglers (sleeps past the round close) and
//! mid-round dropouts (task errors), so the full server-side path —
//! cohort sampling, quorum/deadline close, late sweeps, secagg dropout
//! recovery, ε accounting — runs without compiled artifacts.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

use feddart::config::{DeadlineMode, ParticipationConfig, SamplingStrategy};
use feddart::coordinator::participation::{
    participation_round_key, Candidate, CohortSampler,
};
use feddart::coordinator::round_store::RoundPhase;
use feddart::coordinator::workflow::WorkflowManager;
use feddart::dart::scheduler::{TaskId, TaskResult, TaskSpec, TaskStatus};
use feddart::dart::testmode::TestModeDart;
use feddart::dart::{DartApi, DeviceInfo, TaskRegistry};
use feddart::error::FedError;
use feddart::fact::aggregation::Aggregation;
use feddart::fact::model::FactModel;
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::FactServer;
use feddart::json::Json;
use feddart::privacy::dp::DpAccountant;
use feddart::privacy::{
    keys, masking, round_id_from_hex, shamir, to_hex, PrivacyConfig,
    PrivacyMode,
};
use feddart::util::rng::{golden_f32, Rng};
use feddart::util::tensorbuf::TensorBuf;

const PARAMS: usize = 32;

/// Minimal engine-free model with a uniform (secure-sum-capable) rule.
struct TestModel;

impl FactModel for TestModel {
    fn name(&self) -> &str {
        "partmodel"
    }
    fn param_count(&self) -> usize {
        PARAMS
    }
    fn init_params(&self, seed: i32) -> feddart::Result<Vec<f32>> {
        Ok(golden_f32(seed as u32, PARAMS))
    }
    fn aggregation(&self) -> &Aggregation {
        &Aggregation::FedAvg
    }
}

fn device_index(device: &str) -> usize {
    device.rsplit('-').next().unwrap().parse().unwrap()
}

/// The deterministic per-device "local training" delta.
fn bump(device: &str) -> f32 {
    0.01 * (device_index(device) + 1) as f32
}

/// Precompute the cohort the server will draw for (clustering round 0,
/// cluster 0, `round`) — the sampler is a pure function of (config, key,
/// pool), which is exactly what lets the test script stragglers inside
/// the real cohort.
fn expected_cohort(
    part: &ParticipationConfig,
    n: usize,
    round: usize,
) -> Vec<String> {
    let sampler = CohortSampler::new(part.clone());
    let pool: Vec<Candidate> = (0..n)
        .map(|i| Candidate::uniform(&format!("client-{i}")))
        .collect();
    sampler.sample(participation_round_key(part.seed, 0, 0, round), &pool)
}

/// Client registry: `fact_learn` returns `global + bump(device)`, sleeps
/// for scripted stragglers (keyed by (round, device)), and errors for
/// scripted dropouts.
fn scripted_registry(
    stragglers: Arc<BTreeSet<(usize, String)>>,
    dropouts: Arc<BTreeSet<String>>,
    straggle: Duration,
) -> TaskRegistry {
    let reg = TaskRegistry::new();
    reg.register("fact_init", |_| Ok(Json::Null));
    reg.register("fact_learn", move |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        if dropouts.contains(&device) {
            return Err(FedError::Task(format!("'{device}' crashed mid-round")));
        }
        let round =
            p.get("round").and_then(Json::as_usize).unwrap_or(0);
        if stragglers.contains(&(round, device.clone())) {
            std::thread::sleep(straggle);
        }
        let global = TensorBuf::from_json(p.need("params")?)
            .map_err(|e| FedError::Task(e.to_string()))?;
        let b = bump(&device);
        let out: Vec<f32> =
            global.as_f32_slice().iter().map(|g| g + b).collect();
        Ok(Json::obj()
            .set("params", TensorBuf::from_f32_vec(out))
            .set("n_samples", 16.0)
            .set("loss", 1.0))
    });
    reg
}

/// ISSUE satellite scenario: N=12, cohort of 8, 2 stragglers past the
/// deadline, 1 mid-round dropout — the round closes at quorum and the
/// aggregate matches the reporting subset exactly.
#[test]
fn round_closes_at_quorum_and_aggregates_the_reporting_subset() {
    let n = 12;
    let part = ParticipationConfig {
        sample_rate: 0.65, // ceil(0.65 * 12) = 8
        quorum: 0.6,       // ceil(0.6 * 8) = 5
        deadline_ms: 10_000,
        strategy: SamplingStrategy::Uniform,
        seed: 2024,
        ..Default::default()
    };
    let cohort = expected_cohort(&part, n, 0);
    assert_eq!(cohort.len(), 8, "cohort {cohort:?}");

    // 2 stragglers + 1 dropout leave exactly quorum (5) reporters
    let stragglers: Arc<BTreeSet<(usize, String)>> = Arc::new(
        [(0usize, cohort[0].clone()), (0usize, cohort[1].clone())].into(),
    );
    let dropouts: Arc<BTreeSet<String>> =
        Arc::new([cohort[2].clone()].into());
    let reporting: Vec<String> = cohort[3..].to_vec();

    let reg = scripted_registry(
        Arc::clone(&stragglers),
        Arc::clone(&dropouts),
        Duration::from_millis(2_000),
    );
    let wm = WorkflowManager::test_mode(n, reg, n);
    let mut server =
        FactServer::new(wm).with_participation(part.clone());
    server
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(1)), 5)
        .unwrap();
    let global0 = server.container().clusters[0].params.clone();
    server.learn().unwrap();

    // the round closed at quorum, long before the stragglers woke up
    let r = &server.history()[0];
    assert_eq!(r.sampled, 8);
    assert_eq!(r.n_clients, 5);
    assert_eq!(r.late, 0, "no grace window — stragglers count as dropped");
    assert_eq!(r.dropped, 3);
    assert!((r.sample_rate - 8.0 / 12.0).abs() < 1e-9);
    assert!(
        r.round_ms < 1_800.0,
        "round waited for the stragglers: {} ms",
        r.round_ms
    );

    // aggregate == uniform mean over exactly the reporting subset
    let mean_bump: f32 =
        reporting.iter().map(|d| bump(d)).sum::<f32>() / reporting.len() as f32;
    for (got, g0) in
        server.container().clusters[0].params.iter().zip(global0.iter())
    {
        assert!(
            (got - (g0 + mean_bump)).abs() < 1e-5,
            "aggregate drifted from the reporting subset: {got} vs {}",
            g0 + mean_bump
        );
    }

    // participation metrics recorded the round
    let m = server.metrics();
    assert_eq!(m.counter("fact.participation.sampled").get(), 8);
    assert_eq!(m.counter("fact.participation.reported").get(), 5);
    assert_eq!(m.counter("fact.participation.dropped").get(), 3);
    assert_eq!(m.counter("fact.participation.quorum_closes").get(), 1);
}

/// Late results arriving inside the grace window are observed (counted)
/// and still excluded from the aggregate.
#[test]
fn late_stragglers_are_counted_then_discarded() {
    let n = 6;
    let part = ParticipationConfig {
        sample_rate: 1.0,
        quorum: 0.5, // ceil(0.5 * 6) = 3
        deadline_ms: 10_000,
        late_grace_ms: 1_500,
        strategy: SamplingStrategy::Uniform,
        seed: 9,
        ..Default::default()
    };
    let stragglers: Arc<BTreeSet<(usize, String)>> = Arc::new(
        [(0usize, "client-4".to_string()), (0usize, "client-5".to_string())]
            .into(),
    );
    let dropouts: Arc<BTreeSet<String>> = Arc::new(BTreeSet::new());
    let reg = scripted_registry(
        stragglers,
        dropouts,
        Duration::from_millis(300),
    );
    let wm = WorkflowManager::test_mode(n, reg, n);
    let mut server = FactServer::new(wm).with_participation(part);
    server
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(1)), 3)
        .unwrap();
    server.learn().unwrap();

    let r = &server.history()[0];
    assert_eq!(r.sampled, 6);
    assert!(r.n_clients >= 3, "closed below quorum: {}", r.n_clients);
    assert!(
        r.late >= 2,
        "stragglers settling in the grace window must be counted late \
         (late={}, reported={}, dropped={})",
        r.late,
        r.n_clients,
        r.dropped
    );
    assert_eq!(r.n_clients + r.late + r.dropped, 6);
    assert!(
        r.n_clients + r.late >= 5,
        "grace sweep missed settled stragglers"
    );
}

/// Acceptance: a q=0.25 sampled session (quorum 0.75, deadline enforced)
/// runs end-to-end with one straggler per round dropped at the quorum
/// close, and the accountant's ε is STRICTLY below full participation at
/// the same noise multiplier.
#[test]
fn dp_amplification_of_sampled_rounds_end_to_end() {
    let n = 16;
    let rounds = 3;
    let part = ParticipationConfig {
        sample_rate: 0.25, // cohort 4
        quorum: 0.75,      // ceil(0.75 * 4) = 3
        deadline_ms: 8_000,
        strategy: SamplingStrategy::Uniform,
        seed: 31,
        ..Default::default()
    };
    // one scripted straggler per round, always a real cohort member
    let mut stragglers = BTreeSet::new();
    for r in 0..rounds {
        let cohort = expected_cohort(&part, n, r);
        assert_eq!(cohort.len(), 4);
        stragglers.insert((r, cohort[0].clone()));
    }
    let reg = scripted_registry(
        Arc::new(stragglers),
        Arc::new(BTreeSet::new()),
        Duration::from_millis(1_000),
    );
    let wm = WorkflowManager::test_mode(n, reg, n);
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig::with_mode(PrivacyMode::Dp))
        .with_participation(part);
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(rounds)),
            11,
        )
        .unwrap();
    server.learn().unwrap();

    assert_eq!(server.history().len(), rounds);
    for r in server.history() {
        assert_eq!(r.sampled, 4);
        assert_eq!(r.n_clients, 3, "round {} kept its straggler", r.round);
        assert_eq!(r.dropped, 1);
        assert!((r.sample_rate - 0.25).abs() < 1e-9);
    }

    // the pinned amplification claim: ε(q=0.25) < ε(q=1) at equal σ, T
    assert_eq!(server.accountant().steps, rounds as u64);
    let eps = server.accountant().epsilon(1e-5);
    let mut full = DpAccountant::new(1.0);
    full.add_steps(rounds as u64);
    let full_eps = full.epsilon(1e-5);
    assert!(eps > 0.0 && eps.is_finite());
    assert!(
        eps < full_eps,
        "subsampled ε {eps} not strictly below full-participation ε {full_eps}"
    );
}

/// Secagg under partial participation: a sampled cohort with one
/// deadline-dropped straggler and one mid-round crash — both are
/// recovered through the `fact_reveal` path and the unmasked aggregate
/// equals the clear mean of the reporting subset.
#[test]
fn secagg_cohort_recovers_straggler_and_dropout_masks() {
    let n = 8;
    let part = ParticipationConfig {
        sample_rate: 0.75, // cohort 6
        quorum: 0.65,      // ceil(0.65 * 6) = 4
        deadline_ms: 10_000,
        min_cohort: 2,
        strategy: SamplingStrategy::Uniform,
        seed: 77,
        ..Default::default()
    };
    let cohort = expected_cohort(&part, n, 0);
    assert_eq!(cohort.len(), 6);
    let straggler = cohort[0].clone();
    let dropout = cohort[1].clone();
    let reporting: Vec<String> = cohort[2..].to_vec();

    let reg = TaskRegistry::new();
    reg.register("fact_init", |_| Ok(Json::Null));
    // per-pair key agreement helpers (deterministic client secrets)
    fn round_keys_of(device: &str, round_id: u64) -> keys::RoundKeys {
        keys::keypair(&keys::derive_round_secret(
            &[device_index(device) as u8 + 1; 32],
            round_id,
            device,
        ))
    }
    fn keys_map_of(p: &Json) -> std::collections::BTreeMap<String, String> {
        p.need("keys")
            .unwrap()
            .as_obj()
            .unwrap()
            .iter()
            .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
            .collect()
    }
    reg.register("fact_keys", |p| {
        let device =
            p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id = round_id_from_hex(
            p.need("round_id")?.as_str().unwrap_or_default(),
        )?;
        let kp = round_keys_of(&device, round_id);
        Ok(Json::obj().set("pubkey", keys::pubkey_hex(&kp.public)))
    });
    reg.register("fact_shares", |p| {
        let device =
            p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id = round_id_from_hex(
            p.need("round_id")?.as_str().unwrap_or_default(),
        )?;
        let threshold = p.need("threshold")?.as_usize().unwrap();
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let peers: Vec<(String, u8)> = keys_map
            .keys()
            .enumerate()
            .filter(|(_, n)| *n != &device)
            .map(|(i, n)| (n.clone(), i as u8 + 1))
            .collect();
        let xs: Vec<u8> = peers.iter().map(|(_, x)| *x).collect();
        let mut rng = Rng::new(round_id ^ device_index(&device) as u64);
        let split = shamir::split_at(&kp.secret, threshold, &xs, &mut rng)?;
        let mut shares = Json::obj();
        let mut commits = Json::obj();
        for (share, (peer, _)) in split.iter().zip(peers.iter()) {
            let their = keys::parse_pubkey_hex(&keys_map[peer])?;
            let sk = keys::shared_key(&kp.secret, &their);
            let ct = keys::encrypt_share(
                &sk, round_id, &device, peer, &share.to_bytes(),
            );
            shares = shares.set(peer, to_hex(&ct));
            commits =
                commits.set(peer, to_hex(&shamir::share_commitment(share)));
        }
        Ok(Json::obj().set("shares", shares).set("commits", commits))
    });
    {
        let straggler = straggler.clone();
        let dropout = dropout.clone();
        reg.register("fact_learn", move |p| {
            let device = p
                .get("_device")
                .and_then(Json::as_str)
                .ok_or_else(|| FedError::Task("missing _device".into()))?
                .to_string();
            if device == dropout {
                return Err(FedError::Task(format!(
                    "'{device}' crashed mid-round"
                )));
            }
            if device == straggler {
                std::thread::sleep(Duration::from_millis(1_200));
            }
            let pj = p.need("privacy")?;
            let cfg = PrivacyConfig::from_json(pj)?;
            let round_id = round_id_from_hex(
                pj.need("round_id")?.as_str().unwrap_or_default(),
            )?;
            let participants: Vec<String> = pj
                .need("participants")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|j| j.as_str().map(String::from))
                .collect();
            if !participants.contains(&device) {
                return Err(FedError::Task(format!(
                    "'{device}' dispatched outside the cohort"
                )));
            }
            let keys_map = keys_map_of(pj);
            let kp = round_keys_of(&device, round_id);
            let seeds: Vec<(i64, [u8; 32])> = participants
                .iter()
                .filter(|c| *c != &device)
                .map(|peer| {
                    let their =
                        keys::parse_pubkey_hex(&keys_map[peer]).unwrap();
                    let sk = keys::shared_key(&kp.secret, &their);
                    (
                        masking::pair_sign(&device, peer),
                        keys::pair_seed_from_shared(
                            &sk, round_id, &device, peer,
                        ),
                    )
                })
                .collect();
            let update = vec![bump(&device); PARAMS];
            let masked = masking::mask_update_with_seeds(
                &update,
                1.0, // uniform rule -> weighted=false
                &seeds,
                cfg.frac_bits,
            )?;
            Ok(Json::obj()
                .set("params", TensorBuf::from_f32_vec(masked))
                .set("n_samples", 1.0)
                .set("loss", 1.0))
        });
    }
    reg.register("fact_reveal", move |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string();
        let round_id = round_id_from_hex(
            p.need("round_id")?.as_str().unwrap_or_default(),
        )?;
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let mut seeds = Json::obj();
        for d in p.need("dropped")?.as_arr().unwrap_or(&[]) {
            let Some(name) = d.as_str() else { continue };
            if name == device {
                continue;
            }
            let Some(pub_hex) = keys_map.get(name) else { continue };
            let their = keys::parse_pubkey_hex(pub_hex)?;
            let sk = keys::shared_key(&kp.secret, &their);
            seeds = seeds.set(
                name,
                to_hex(&keys::pair_seed_from_shared(
                    &sk, round_id, &device, name,
                )),
            );
        }
        Ok(Json::obj().set("seeds", seeds))
    });

    let wm = WorkflowManager::test_mode(n, reg, n);
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig::with_mode(PrivacyMode::SecAgg))
        .with_participation(part);
    server
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(1)), 2)
        .unwrap();
    server.learn().unwrap();

    let r = &server.history()[0];
    assert_eq!(r.sampled, 6);
    assert_eq!(r.n_clients, 4);
    assert_eq!(r.dropped, 2, "straggler + crash both recovered as dropouts");

    // unmasked aggregate == clear mean over exactly the reporting subset
    let mean_bump: f32 =
        reporting.iter().map(|d| bump(d)).sum::<f32>() / reporting.len() as f32;
    for got in server.container().clusters[0].params.iter() {
        assert!(
            (got - mean_bump).abs() < 1e-3,
            "unmasked aggregate {got} vs clear {mean_bump}"
        );
    }
}

/// Config-level guardrail: secagg + participation demands min_cohort >= 2.
#[test]
fn secagg_participation_requires_min_cohort_of_two() {
    let reg = TaskRegistry::new();
    reg.register("fact_init", |_| Ok(Json::Null));
    let wm = WorkflowManager::test_mode(4, reg, 2);
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig::with_mode(PrivacyMode::SecAgg))
        .with_participation(ParticipationConfig {
            sample_rate: 0.25,
            min_cohort: 1,
            ..Default::default()
        });
    server
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(1)), 1)
        .unwrap();
    let err = server.learn().unwrap_err();
    assert!(err.to_string().contains("min_cohort"), "{err}");
}

/// The deadline path: a round whose whole cohort straggles closes at the
/// deadline with whatever reported and errors only when nothing did.
#[test]
fn deadline_close_with_zero_reports_is_an_error() {
    let n = 4;
    let part = ParticipationConfig {
        sample_rate: 1.0,
        quorum: 1.0,
        deadline_ms: 120,
        strategy: SamplingStrategy::Uniform,
        ..Default::default()
    };
    let stragglers: Arc<BTreeSet<(usize, String)>> = Arc::new(
        (0..n).map(|i| (0usize, format!("client-{i}"))).collect(),
    );
    let reg = scripted_registry(
        stragglers,
        Arc::new(BTreeSet::new()),
        Duration::from_millis(700),
    );
    let wm = WorkflowManager::test_mode(n, reg, n);
    let mut server = FactServer::new(wm).with_participation(part);
    server
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(1)), 1)
        .unwrap();
    let err = server.learn().unwrap_err();
    assert!(
        err.to_string().contains("no client returned a result"),
        "{err}"
    );
    assert_eq!(
        server
            .metrics()
            .counter("fact.participation.deadline_closes")
            .get(),
        1
    );
}

/// Deadline edge case (ISSUE 7 satellite): `deadline_ms = 0` disables
/// the deadline entirely (the legacy "wait for quorum or completion"
/// behaviour) — it must never be read as "close immediately", which
/// would void every round with zero reports.
#[test]
fn deadline_zero_means_no_deadline_not_instant_close() {
    let n = 4;
    let part = ParticipationConfig {
        sample_rate: 1.0,
        quorum: 0.5, // ceil(0.5 * 4) = 2
        deadline_ms: 0,
        strategy: SamplingStrategy::Uniform,
        seed: 5,
        ..Default::default()
    };
    let stragglers: Arc<BTreeSet<(usize, String)>> =
        Arc::new([(0usize, "client-3".to_string())].into());
    let reg = scripted_registry(
        stragglers,
        Arc::new(BTreeSet::new()),
        Duration::from_millis(300),
    );
    let wm = WorkflowManager::test_mode(n, reg, n);
    let mut server = FactServer::new(wm).with_participation(part);
    server
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(1)), 1)
        .unwrap();
    // an instant close would surface "no client returned a result"
    server.learn().unwrap();

    let r = &server.history()[0];
    assert!(r.n_clients >= 2, "quorum close still applies: {}", r.n_clients);
    let m = server.metrics();
    assert_eq!(m.counter("fact.participation.deadline_closes").get(), 0);
    assert_eq!(m.counter("fact.round.adaptive_closes").get(), 0);
}

/// Adaptive deadlines end-to-end (ISSUE 7 tentpole): round 0 runs with a
/// cold tracker — static fallback, and with `deadline_ms = 0` that means
/// *no* deadline, so a full-quorum round waits out its straggler.  Round
/// 0's close data warms the tracker; round 1 then closes at the adaptive
/// percentile deadline and drops the same straggler.
#[test]
fn adaptive_deadline_cold_falls_back_static_then_warm_drops_stragglers() {
    let n = 10;
    let part = ParticipationConfig {
        sample_rate: 1.0,
        quorum: 1.0, // only a deadline can close below n
        deadline_ms: 0,
        deadline: DeadlineMode::P90,
        deadline_margin: 1.5,
        deadline_min_ms: 150,
        deadline_max_ms: 200,
        strategy: SamplingStrategy::Uniform,
        seed: 6,
        ..Default::default()
    };
    let stragglers: Arc<BTreeSet<(usize, String)>> = Arc::new(
        [(0usize, "client-9".to_string()), (1usize, "client-9".to_string())]
            .into(),
    );
    let reg = scripted_registry(
        stragglers,
        Arc::new(BTreeSet::new()),
        Duration::from_millis(400),
    );
    let wm = WorkflowManager::test_mode(n, reg, n);
    let mut server = FactServer::new(wm).with_participation(part);
    server
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(2)), n)
        .unwrap();
    assert!(!server.latency_tracker().is_warm());
    server.learn().unwrap();

    let h = server.history();
    // round 0: cold tracker -> static fallback -> no deadline -> the
    // full-quorum round waited for the straggler
    assert_eq!(h[0].n_clients, 10);
    assert_eq!(h[0].dropped, 0);
    // round 0 fed the tracker past min_samples
    assert!(server.latency_tracker().is_warm());
    // round 1: p90 x margin, clamped into [150, 200] ms — far below the
    // 400 ms straggle, so the straggler is dropped at the adaptive close
    assert_eq!(h[1].n_clients, 9);
    assert_eq!(h[1].dropped, 1);
    assert!(
        h[1].round_ms < 390.0,
        "adaptive deadline did not shorten the round: {} ms",
        h[1].round_ms
    );
    let m = server.metrics();
    assert_eq!(m.counter("fact.round.adaptive_closes").get(), 1);
    let adaptive_ms = m.counter("fact.round.deadline_adaptive_ms").get();
    assert!(
        (150..=200).contains(&adaptive_ms),
        "adaptive deadline outside the clamp: {adaptive_ms} ms"
    );
    assert_eq!(m.counter("fact.participation.deadline_closes").get(), 1);
}

/// A [`TestModeDart`] decorator that masks chosen devices as dead at the
/// `DartApi` level (the liveness view the repair pass consults) while
/// the simulated client underneath keeps running.
struct DeadMask {
    inner: Arc<TestModeDart>,
    dead: Arc<std::sync::Mutex<BTreeSet<String>>>,
}

impl DartApi for DeadMask {
    fn devices(&self) -> feddart::Result<Vec<DeviceInfo>> {
        let dead = self.dead.lock().unwrap();
        Ok(self
            .inner
            .devices()?
            .into_iter()
            .map(|mut d| {
                if dead.contains(&d.name) {
                    d.alive = false;
                }
                d
            })
            .collect())
    }
    fn submit(&self, spec: TaskSpec) -> feddart::Result<TaskId> {
        self.inner.submit(spec)
    }
    fn status(&self, id: TaskId) -> feddart::Result<TaskStatus> {
        self.inner.status(id)
    }
    fn results(&self, id: TaskId) -> feddart::Result<Vec<TaskResult>> {
        self.inner.results(id)
    }
    fn result_count(&self, id: TaskId) -> feddart::Result<usize> {
        self.inner.result_count(id)
    }
    fn progress(&self, id: TaskId) -> feddart::Result<(TaskStatus, usize)> {
        self.inner.progress(id)
    }
    fn stop_task(&self, id: TaskId) -> feddart::Result<()> {
        self.inner.stop_task(id)
    }
}

/// In-round cohort repair + late-grace interplay (ISSUE 7 tentpole +
/// satellite): one sampled member is dead before dispatch — the repair
/// pass drops it and draws a replacement inside the same round, records
/// a `cohort_repaired` event, and charges the conservative union
/// sampling rate.  A second member straggles past the deadline and
/// reports inside the grace window: counted `late`, never aggregated —
/// every contributing device enters the aggregate exactly once.
#[test]
fn dead_cohort_member_is_repaired_in_round_and_straggler_counts_late() {
    let n = 8;
    let part = ParticipationConfig {
        sample_rate: 0.5, // cohort of 4
        quorum: 1.0,      // only the deadline closes the round
        deadline_ms: 350,
        late_grace_ms: 1_500,
        strategy: SamplingStrategy::Uniform,
        seed: 77,
        ..Default::default()
    };
    let cohort = expected_cohort(&part, n, 0);
    assert_eq!(cohort.len(), 4, "cohort {cohort:?}");
    let dead_member = cohort[0].clone();
    let straggler = cohort[1].clone();

    let stragglers: Arc<BTreeSet<(usize, String)>> =
        Arc::new([(0usize, straggler.clone())].into());
    let reg = scripted_registry(
        stragglers,
        Arc::new(BTreeSet::new()),
        Duration::from_millis(900),
    );
    let dead = Arc::new(std::sync::Mutex::new(BTreeSet::new()));
    let sim = Arc::new(TestModeDart::start_reliable(n, reg, n));
    let wm = WorkflowManager::with_backend(Arc::new(DeadMask {
        inner: sim,
        dead: Arc::clone(&dead),
    }));
    let mut server = FactServer::new(wm).with_participation(part.clone());
    // init while everyone is alive, so the cluster holds all n clients
    server
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(1)), n)
        .unwrap();
    let global0 = server.container().clusters[0].params.clone();
    // the sampled member dies between the draw's pool snapshot and learn
    dead.lock().unwrap().insert(dead_member.clone());
    server.learn().unwrap();

    // the repair pass swapped the dead member for one replacement
    let m = server.metrics();
    assert_eq!(m.counter("fact.round.repaired").get(), 1);
    assert_eq!(m.counter("fact.round.replacements").get(), 1);

    // the round store holds the repaired cohort and the repair audit
    let rounds = server.round_store().rounds().unwrap();
    assert_eq!(rounds.len(), 1);
    let rs = &rounds[0];
    assert_eq!(rs.phase, RoundPhase::Closed);
    assert_eq!(rs.repaired, 1);
    assert_eq!(rs.cohort.len(), 4, "repair preserves cohort size");
    assert!(
        !rs.cohort.contains(&dead_member),
        "dead member must leave the addressed cohort: {:?}",
        rs.cohort
    );
    let replacement: Vec<&String> =
        rs.cohort.iter().filter(|c| !cohort.contains(c)).collect();
    assert_eq!(replacement.len(), 1, "exactly one replacement drawn");

    // union of both draws (4 + 1 = 5 of 8) is the conservative DP charge
    let sampler = CohortSampler::new(part);
    let want_q = sampler.amplification_rate(5, n);
    let r = &server.history()[0];
    assert!((r.sample_rate - want_q).abs() < 1e-9, "q {}", r.sample_rate);
    assert!((rs.sample_rate - want_q).abs() < 1e-9);

    // deadline close at 350 ms with 3 reporters; the straggler settles
    // inside the grace window: counted late, excluded from the aggregate
    assert_eq!(r.sampled, 4);
    assert_eq!(r.n_clients, 3);
    assert_eq!(r.late, 1, "straggler must be observed in the grace sweep");
    assert_eq!(r.dropped, 0);
    assert_eq!(r.n_clients + r.late + r.dropped, r.sampled);

    // aggregate == mean over exactly the three in-time reporters — the
    // late original is never folded in, nobody is counted twice
    let reporters: Vec<&String> =
        rs.cohort.iter().filter(|c| **c != straggler).collect();
    assert_eq!(reporters.len(), 3);
    let mean_bump: f32 =
        reporters.iter().map(|d| bump(d)).sum::<f32>() / reporters.len() as f32;
    for (got, g0) in
        server.container().clusters[0].params.iter().zip(global0.iter())
    {
        assert!(
            (got - (g0 + mean_bump)).abs() < 1e-5,
            "aggregate drifted from the reporting subset: {got} vs {}",
            g0 + mean_bump
        );
    }
}
