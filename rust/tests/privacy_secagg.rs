//! Privacy subsystem integration: the FACT round pipeline under secure
//! aggregation with mid-round client dropouts.
//!
//! Acceptance: a secagg round with 8 clients and 2 mid-round dropouts
//! produces an aggregate bitwise-close (≤ 1e-5 relative) to the
//! clear-mode aggregate of the survivors.
//!
//! The tests run engine-free: a custom task registry plays the client
//! side (computing deterministic local updates and applying the privacy
//! transform with the same `privacy::masking` primitives the real
//! `FactClientRuntime` uses), so they exercise the full
//! server-side path — privacy negotiation in the learn task, dropout
//! detection, the `fact_reveal` recovery task, and the lattice unmasking
//! — without needing compiled artifacts.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use feddart::dart::TaskRegistry;
use feddart::error::FedError;
use feddart::fact::aggregation::Aggregation;
use feddart::fact::model::FactModel;
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::store::{FsObjectStore, ModelStore};
use feddart::fact::FactServer;
use feddart::coordinator::workflow::WorkflowManager;
use feddart::json::Json;
use feddart::privacy::{
    dp, masking, round_id_from_hex, to_hex, PrivacyConfig, PrivacyMode,
};
use feddart::util::rng::{golden_f32, Rng};
use feddart::util::tensorbuf::TensorBuf;

const COHORT_KEY: &[u8] = b"integration-cohort-key";
const PARAMS: usize = 512;

/// Minimal engine-free model: fixed params, weighted FedAvg.
struct TestModel;

impl FactModel for TestModel {
    fn name(&self) -> &str {
        "testmodel"
    }
    fn param_count(&self) -> usize {
        PARAMS
    }
    fn init_params(&self, seed: i32) -> feddart::Result<Vec<f32>> {
        Ok(golden_f32(seed as u32, PARAMS))
    }
    fn aggregation(&self) -> &Aggregation {
        &Aggregation::WeightedFedAvg
    }
}

fn device_index(device: &str) -> usize {
    device.rsplit('-').next().unwrap().parse().unwrap()
}

fn samples_of(idx: usize) -> f32 {
    100.0 + 10.0 * idx as f32
}

/// Client-side registry: deterministic local updates, the round's privacy
/// transform, and deterministic mid-round dropouts.  Captures every
/// survivor's *clear* (post-DP, pre-mask) update so the test can compute
/// the reference aggregate.
fn registry_with_privacy_clients(
    dropped_idx: &'static [usize],
    captured: Arc<Mutex<BTreeMap<String, (Vec<f32>, f32)>>>,
) -> TaskRegistry {
    let registry = TaskRegistry::new();
    registry.register("fact_init", |_| Ok(Json::Null));

    registry.register("fact_learn", move |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let idx = device_index(&device);
        if dropped_idx.contains(&idx) {
            // the client computed nothing visible: it crashed mid-round,
            // after advertising (it is in the participant set) but before
            // uploading its masked update
            return Err(FedError::Task(format!("'{device}' crashed mid-round")));
        }
        let global = TensorBuf::from_json(p.need("params")?)
            .map_err(|e| FedError::Task(e.to_string()))?;
        let gs = global.as_f32_slice();
        // deterministic local training: global + a per-device delta
        let delta = golden_f32(idx as u32 + 1, gs.len());
        let mut params: Vec<f32> =
            gs.iter().zip(&delta).map(|(g, d)| g + 0.1 * d).collect();
        let n_samples = samples_of(idx);

        let pj = p.need("privacy")?;
        let cfg = PrivacyConfig::from_json(pj)?;
        let round_id = round_id_from_hex(
            pj.need("round_id")?.as_str().unwrap_or_default(),
        )?;
        if cfg.mode.has_dp() {
            let mut rng = Rng::new(round_id ^ idx as u64);
            dp::privatize_update(
                &mut params,
                gs,
                cfg.clip_norm,
                cfg.noise_multiplier,
                &mut rng,
            )?;
        }
        // the clear update as the reference aggregate will see it
        captured
            .lock()
            .unwrap()
            .insert(device.clone(), (params.clone(), n_samples));
        if cfg.mode.has_secagg() {
            let participants: Vec<String> = pj
                .need("participants")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|j| j.as_str().map(String::from))
                .collect();
            let peers: Vec<String> =
                participants.into_iter().filter(|c| *c != device).collect();
            let weighted = pj.get("weighted").and_then(Json::as_bool).unwrap_or(true);
            let weight = if weighted {
                n_samples as f64 / cfg.weight_scale as f64
            } else {
                1.0
            };
            params = masking::mask_update(
                &params,
                weight,
                &device,
                &peers,
                COHORT_KEY,
                round_id,
                cfg.frac_bits,
            )?;
        }
        Ok(Json::obj()
            .set("params", TensorBuf::from_f32_vec(params))
            .set("n_samples", n_samples)
            .set("loss", 0.5))
    });

    registry.register("fact_reveal", |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let round_id = round_id_from_hex(
            p.need("round_id")?.as_str().unwrap_or_default(),
        )?;
        let mut seeds = Json::obj();
        for d in p.need("dropped")?.as_arr().unwrap_or(&[]) {
            let Some(name) = d.as_str() else { continue };
            seeds = seeds.set(
                name,
                to_hex(&masking::pair_seed(COHORT_KEY, round_id, &device, name)),
            );
        }
        Ok(Json::obj().set("seeds", seeds))
    });
    registry
}

/// Weighted average of the captured survivor updates (f64 reference).
fn reference_aggregate(
    captured: &BTreeMap<String, (Vec<f32>, f32)>,
) -> Vec<f32> {
    let total: f64 = captured.values().map(|(_, n)| *n as f64).sum();
    let p = captured.values().next().unwrap().0.len();
    (0..p)
        .map(|j| {
            (captured
                .values()
                .map(|(v, n)| v[j] as f64 * *n as f64)
                .sum::<f64>()
                / total) as f32
        })
        .collect()
}

fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let num = a
        .iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let den = b.iter().map(|&y| (y as f64).powi(2)).sum::<f64>().sqrt();
    num / den.max(1e-12)
}

fn run_private_session(
    mode: PrivacyMode,
    dropped: &'static [usize],
    rounds: usize,
) -> (FactServer, Arc<Mutex<BTreeMap<String, (Vec<f32>, f32)>>>) {
    let captured = Arc::new(Mutex::new(BTreeMap::new()));
    let registry = registry_with_privacy_clients(dropped, Arc::clone(&captured));
    let wm = WorkflowManager::test_mode(8, registry, 4);
    let mut server = FactServer::new(wm).with_privacy(PrivacyConfig {
        mode,
        clip_norm: 4.0,
        noise_multiplier: 0.05,
        weight_scale: 128.0,
        ..PrivacyConfig::default()
    });
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(rounds)),
            3,
        )
        .unwrap();
    server.learn().unwrap();
    (server, captured)
}

#[test]
fn secagg_8_clients_2_dropouts_matches_clear_survivor_aggregate() {
    let (server, captured) = run_private_session(PrivacyMode::SecAgg, &[6, 7], 1);

    // 6 survivors contributed; 2 dropped mid-round
    let hist = server.history();
    assert_eq!(hist.len(), 1);
    assert_eq!(hist[0].n_clients, 6);

    let captured = captured.lock().unwrap();
    assert_eq!(captured.len(), 6);
    assert!(!captured.contains_key("client-6"));
    assert!(!captured.contains_key("client-7"));

    let expect = reference_aggregate(&captured);
    let got = &server.container().clusters[0].params;
    let e = rel_err(got, &expect);
    assert!(e <= 1e-5, "secagg aggregate off by {e} (rel)");

    // masked per-client vectors must NOT be recorded as latest updates
    assert!(server.latest_updates().is_empty());
}

#[test]
fn secagg_without_dropouts_matches_clear() {
    let (server, captured) = run_private_session(PrivacyMode::SecAgg, &[], 1);
    assert_eq!(server.history()[0].n_clients, 8);
    let captured = captured.lock().unwrap();
    let expect = reference_aggregate(&captured);
    let e = rel_err(&server.container().clusters[0].params, &expect);
    assert!(e <= 1e-5, "rel err {e}");
}

#[test]
fn secagg_dp_combined_round_recovers_the_noised_aggregate() {
    // with DP stacked on top, the aggregate must equal the weighted
    // average of the *privatized* survivor updates — masking must not
    // interfere with the noise, and vice versa
    let (server, captured) =
        run_private_session(PrivacyMode::SecAggDp, &[2], 1);
    assert_eq!(server.history()[0].n_clients, 7);
    let captured = captured.lock().unwrap();
    let expect = reference_aggregate(&captured);
    let got = &server.container().clusters[0].params;
    let e = rel_err(got, &expect);
    assert!(e <= 1e-5, "rel err {e}");
    // and the DP ledger advanced
    assert_eq!(server.accountant().steps, 1);
    assert!(server.accountant().epsilon(1e-5) > 0.0);
}

#[test]
fn dp_only_mode_steps_accountant_and_persists_with_snapshots() {
    let (server, _) = run_private_session(PrivacyMode::Dp, &[], 3);
    assert_eq!(server.accountant().steps, 3);
    let eps = server.accountant().epsilon(1e-5);
    assert!(eps.is_finite() && eps > 0.0);

    // checkpoint carries the accountant; restore resumes the ledger
    let dir = std::env::temp_dir().join(format!(
        "feddart-privacy-int-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = ModelStore::new(FsObjectStore::new(&dir).unwrap());
    server.checkpoint(&store, 3).unwrap();

    let snap = store.load_latest("testmodel-c0").unwrap().unwrap();
    assert_eq!(
        snap.privacy.get("mode").and_then(Json::as_str),
        Some("dp")
    );
    let acct = dp::DpAccountant::from_json(
        snap.privacy.get("accountant").unwrap(),
    )
    .unwrap();
    assert_eq!(acct.steps, 3);

    // a fresh server restoring the snapshot adopts the ε ledger
    let captured = Arc::new(Mutex::new(BTreeMap::new()));
    let registry = registry_with_privacy_clients(&[], captured);
    let wm = WorkflowManager::test_mode(8, registry, 4);
    let mut resumed = FactServer::new(wm)
        .with_privacy(PrivacyConfig::with_mode(PrivacyMode::Dp));
    resumed
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(1)), 3)
        .unwrap();
    assert_eq!(resumed.accountant().steps, 0);
    assert!(resumed.restore_latest(&store, 0).unwrap());
    assert_eq!(resumed.accountant().steps, 3);
}

#[test]
fn secagg_rejects_order_statistic_aggregation() {
    struct MedianModel;
    impl FactModel for MedianModel {
        fn name(&self) -> &str {
            "medianmodel"
        }
        fn param_count(&self) -> usize {
            PARAMS
        }
        fn init_params(&self, seed: i32) -> feddart::Result<Vec<f32>> {
            Ok(golden_f32(seed as u32, PARAMS))
        }
        fn aggregation(&self) -> &Aggregation {
            &Aggregation::Median
        }
    }
    let captured = Arc::new(Mutex::new(BTreeMap::new()));
    let registry = registry_with_privacy_clients(&[], captured);
    let wm = WorkflowManager::test_mode(4, registry, 2);
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig::with_mode(PrivacyMode::SecAgg));
    server
        .initialization_by_model(Arc::new(MedianModel), Arc::new(FixedRoundFl(1)), 1)
        .unwrap();
    let err = server.learn().unwrap_err();
    assert!(
        err.to_string().contains("incompatible with secure"),
        "{err}"
    );
}
