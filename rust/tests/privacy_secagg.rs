//! Privacy subsystem integration: the FACT round pipeline under secure
//! aggregation with mid-round client dropouts and threshold recovery.
//!
//! Acceptance: an 8-client secagg round with 2 mid-round dropouts
//! recovers via any 4-of-6 survivor share subset — the masked aggregate
//! stays ≤ 1e-5 relative of the clear survivor aggregate with only 4 of
//! the 6 survivors answering the recovery task — and a round left below
//! the reveal threshold follows the configured abort/proceed policy with
//! an audit record.
//!
//! The tests run engine-free: a custom task registry plays the client
//! side (deterministic local updates, per-pair DH key agreement,
//! encrypted Shamir share dealing, and the privacy transform — all with
//! the same `privacy::{keys, shamir, masking}` primitives the real
//! `FactClientRuntime` uses), so they exercise the full server-side path
//! — key/share setup phases, dropout detection, threshold
//! reconstruction, the reveal policy — without compiled artifacts.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use feddart::coordinator::workflow::WorkflowManager;
use feddart::dart::TaskRegistry;
use feddart::error::FedError;
use feddart::fact::aggregation::Aggregation;
use feddart::fact::model::FactModel;
use feddart::fact::stopping::FixedRoundFl;
use feddart::fact::store::{FsObjectStore, ModelStore};
use feddart::fact::FactServer;
use feddart::json::Json;
use feddart::privacy::{
    dp, from_hex, keys, masking, round_id_from_hex, shamir, to_hex,
    PrivacyConfig, PrivacyMode, RevealPolicy,
};
use feddart::util::rng::{golden_f32, Rng};
use feddart::util::tensorbuf::TensorBuf;

const PARAMS: usize = 512;

/// Minimal engine-free model: fixed params, weighted FedAvg.
struct TestModel;

impl FactModel for TestModel {
    fn name(&self) -> &str {
        "testmodel"
    }
    fn param_count(&self) -> usize {
        PARAMS
    }
    fn init_params(&self, seed: i32) -> feddart::Result<Vec<f32>> {
        Ok(golden_f32(seed as u32, PARAMS))
    }
    fn aggregation(&self) -> &Aggregation {
        &Aggregation::WeightedFedAvg
    }
}

fn device_index(device: &str) -> usize {
    device.rsplit('-').next().unwrap().parse().unwrap()
}

fn samples_of(idx: usize) -> f32 {
    100.0 + 10.0 * idx as f32
}

/// Deterministic per-device client secret (the runtime draws these from
/// the OS CSPRNG; the test pins them for reproducibility).
fn client_secret(idx: usize) -> [u8; 32] {
    [idx as u8 + 1; 32]
}

fn round_keys_of(device: &str, round_id: u64) -> keys::RoundKeys {
    keys::keypair(&keys::derive_round_secret(
        &client_secret(device_index(device)),
        round_id,
        device,
    ))
}

fn keys_map_of(p: &Json) -> BTreeMap<String, String> {
    p.need("keys")
        .unwrap()
        .as_obj()
        .unwrap()
        .iter()
        .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
        .collect()
}

/// What the client registry does when the recovery task reaches it.
#[derive(Clone)]
struct RevealBehaviour {
    /// device indices that ANSWER the fact_reveal task (everyone else
    /// errors, simulating unreachable survivors); None = all answer
    responders: Option<&'static [usize]>,
}

/// Client-side registry: per-pair DH keys, encrypted Shamir shares,
/// deterministic local updates with the round's privacy transform, and
/// deterministic mid-round dropouts.  Captures every survivor's *clear*
/// (post-DP, pre-mask) update so the test can compute the reference
/// aggregate.
fn registry_with_privacy_clients(
    dropped_idx: &'static [usize],
    reveal: RevealBehaviour,
    captured: Arc<Mutex<BTreeMap<String, (Vec<f32>, f32)>>>,
) -> TaskRegistry {
    let registry = TaskRegistry::new();
    registry.register("fact_init", |_| Ok(Json::Null));

    registry.register("fact_keys", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id = round_id_from_hex(
            p.need("round_id")?.as_str().unwrap_or_default(),
        )?;
        let kp = round_keys_of(&device, round_id);
        Ok(Json::obj().set("pubkey", keys::pubkey_hex(&kp.public)))
    });

    registry.register("fact_shares", |p| {
        let device = p.get("_device").and_then(Json::as_str).unwrap().to_string();
        let round_id = round_id_from_hex(
            p.need("round_id")?.as_str().unwrap_or_default(),
        )?;
        let threshold = p.need("threshold")?.as_usize().unwrap();
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let peers: Vec<(String, u8)> = keys_map
            .keys()
            .enumerate()
            .filter(|(_, n)| *n != &device)
            .map(|(i, n)| (n.clone(), i as u8 + 1))
            .collect();
        let xs: Vec<u8> = peers.iter().map(|(_, x)| *x).collect();
        let mut rng = Rng::new(round_id ^ device_index(&device) as u64);
        let split = shamir::split_at(&kp.secret, threshold, &xs, &mut rng)?;
        let mut shares = Json::obj();
        let mut commits = Json::obj();
        for (share, (peer, _)) in split.iter().zip(peers.iter()) {
            let their = keys::parse_pubkey_hex(&keys_map[peer])?;
            let sk = keys::shared_key(&kp.secret, &their);
            let ct =
                keys::encrypt_share(&sk, round_id, &device, peer, &share.to_bytes());
            shares = shares.set(peer, to_hex(&ct));
            commits = commits.set(peer, to_hex(&shamir::share_commitment(share)));
        }
        Ok(Json::obj().set("shares", shares).set("commits", commits))
    });

    registry.register("fact_learn", move |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let idx = device_index(&device);
        if dropped_idx.contains(&idx) {
            // the client computed nothing visible: it crashed mid-round,
            // after key agreement + share dealing (it is in the masking
            // participant set) but before uploading its masked update
            return Err(FedError::Task(format!("'{device}' crashed mid-round")));
        }
        let global = TensorBuf::from_json(p.need("params")?)
            .map_err(|e| FedError::Task(e.to_string()))?;
        let gs = global.as_f32_slice();
        // deterministic local training: global + a per-device delta
        let delta = golden_f32(idx as u32 + 1, gs.len());
        let mut params: Vec<f32> =
            gs.iter().zip(&delta).map(|(g, d)| g + 0.1 * d).collect();
        let n_samples = samples_of(idx);

        let pj = p.need("privacy")?;
        let cfg = PrivacyConfig::from_json(pj)?;
        let round_id = round_id_from_hex(
            pj.need("round_id")?.as_str().unwrap_or_default(),
        )?;
        if cfg.mode.has_dp() {
            let mut rng = Rng::new(round_id ^ idx as u64);
            dp::privatize_update(
                &mut params,
                gs,
                cfg.clip_norm,
                cfg.noise_multiplier,
                &mut rng,
            )?;
        }
        // the clear update as the reference aggregate will see it
        captured
            .lock()
            .unwrap()
            .insert(device.clone(), (params.clone(), n_samples));
        if cfg.mode.has_secagg() {
            let keys_map: BTreeMap<String, String> = pj
                .need("keys")?
                .as_obj()
                .unwrap()
                .iter()
                .filter_map(|(k, v)| {
                    v.as_str().map(|s| (k.clone(), s.to_string()))
                })
                .collect();
            let participants: Vec<String> = pj
                .need("participants")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|j| j.as_str().map(String::from))
                .collect();
            let kp = round_keys_of(&device, round_id);
            assert_eq!(
                keys_map[&device],
                keys::pubkey_hex(&kp.public),
                "coordinator echoed a different key"
            );
            let seeds: Vec<(i64, [u8; 32])> = participants
                .iter()
                .filter(|c| *c != &device)
                .map(|peer| {
                    let their =
                        keys::parse_pubkey_hex(&keys_map[peer]).unwrap();
                    let sk = keys::shared_key(&kp.secret, &their);
                    (
                        masking::pair_sign(&device, peer),
                        keys::pair_seed_from_shared(&sk, round_id, &device, peer),
                    )
                })
                .collect();
            let weighted = pj.get("weighted").and_then(Json::as_bool).unwrap_or(true);
            let weight = if weighted {
                n_samples as f64 / cfg.weight_scale as f64
            } else {
                1.0
            };
            params = masking::mask_update_with_seeds(
                &params,
                weight,
                &seeds,
                cfg.frac_bits,
            )?;
        }
        Ok(Json::obj()
            .set("params", TensorBuf::from_f32_vec(params))
            .set("n_samples", n_samples)
            .set("loss", 0.5))
    });

    registry.register("fact_reveal", move |p| {
        let device = p
            .get("_device")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Task("missing _device".into()))?
            .to_string();
        let idx = device_index(&device);
        if let Some(responders) = reveal.responders {
            if !responders.contains(&idx) {
                return Err(FedError::Task(format!(
                    "'{device}' unreachable during recovery"
                )));
            }
        }
        let round_id = round_id_from_hex(
            p.need("round_id")?.as_str().unwrap_or_default(),
        )?;
        let keys_map = keys_map_of(p);
        let kp = round_keys_of(&device, round_id);
        let mut seeds = Json::obj();
        let mut shares_out = Json::obj();
        for d in p.need("dropped")?.as_arr().unwrap_or(&[]) {
            let Some(name) = d.as_str() else { continue };
            if name == device {
                continue;
            }
            let Some(pub_hex) = keys_map.get(name) else { continue };
            let their = keys::parse_pubkey_hex(pub_hex)?;
            let sk = keys::shared_key(&kp.secret, &their);
            seeds = seeds.set(
                name,
                to_hex(&keys::pair_seed_from_shared(&sk, round_id, &device, name)),
            );
            if let Some(ct_hex) =
                p.get("shares").and_then(|s| s.get(name)).and_then(Json::as_str)
            {
                let plain = keys::decrypt_share(
                    &sk,
                    round_id,
                    name,
                    &device,
                    &from_hex(ct_hex)?,
                )?;
                shares_out = shares_out.set(name, to_hex(&plain));
            }
        }
        Ok(Json::obj().set("seeds", seeds).set("shares", shares_out))
    });
    registry
}

/// Weighted average of the captured survivor updates (f64 reference).
fn reference_aggregate(
    captured: &BTreeMap<String, (Vec<f32>, f32)>,
) -> Vec<f32> {
    let total: f64 = captured.values().map(|(_, n)| *n as f64).sum();
    let p = captured.values().next().unwrap().0.len();
    (0..p)
        .map(|j| {
            (captured
                .values()
                .map(|(v, n)| v[j] as f64 * *n as f64)
                .sum::<f64>()
                / total) as f32
        })
        .collect()
}

fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let num = a
        .iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let den = b.iter().map(|&y| (y as f64).powi(2)).sum::<f64>().sqrt();
    num / den.max(1e-12)
}

fn private_server(
    mode: PrivacyMode,
    dropped: &'static [usize],
    reveal: RevealBehaviour,
    privacy_overrides: impl FnOnce(PrivacyConfig) -> PrivacyConfig,
    clients: usize,
    rounds: usize,
) -> (
    feddart::Result<()>,
    FactServer,
    Arc<Mutex<BTreeMap<String, (Vec<f32>, f32)>>>,
) {
    let captured = Arc::new(Mutex::new(BTreeMap::new()));
    let registry =
        registry_with_privacy_clients(dropped, reveal, Arc::clone(&captured));
    let wm = WorkflowManager::test_mode(clients, registry, 4);
    let cfg = privacy_overrides(PrivacyConfig {
        mode,
        clip_norm: 4.0,
        noise_multiplier: 0.05,
        weight_scale: 128.0,
        ..PrivacyConfig::default()
    });
    let mut server = FactServer::new(wm).with_privacy(cfg);
    server
        .initialization_by_model(
            Arc::new(TestModel),
            Arc::new(FixedRoundFl(rounds)),
            3,
        )
        .unwrap();
    let out = server.learn();
    (out, server, captured)
}

fn run_private_session(
    mode: PrivacyMode,
    dropped: &'static [usize],
    rounds: usize,
) -> (FactServer, Arc<Mutex<BTreeMap<String, (Vec<f32>, f32)>>>) {
    let (out, server, captured) = private_server(
        mode,
        dropped,
        RevealBehaviour { responders: None },
        |c| c,
        8,
        rounds,
    );
    out.unwrap();
    (server, captured)
}

#[test]
fn secagg_8_clients_2_dropouts_matches_clear_survivor_aggregate() {
    let (server, captured) = run_private_session(PrivacyMode::SecAgg, &[6, 7], 1);

    // 6 survivors contributed; 2 dropped mid-round
    let hist = server.history();
    assert_eq!(hist.len(), 1);
    assert_eq!(hist[0].n_clients, 6);
    // the secagg audit rides on the round record
    let audit = hist[0].secagg.as_ref().unwrap();
    assert_eq!(audit.participants, 8);
    assert_eq!(audit.threshold, 4); // auto: (8+1)/2
    assert_eq!(audit.dropped.len(), 2);
    assert!(audit.unrecovered.is_empty());

    let captured = captured.lock().unwrap();
    assert_eq!(captured.len(), 6);
    assert!(!captured.contains_key("client-6"));
    assert!(!captured.contains_key("client-7"));

    let expect = reference_aggregate(&captured);
    let got = &server.container().clusters[0].params;
    let e = rel_err(got, &expect);
    assert!(e <= 1e-5, "secagg aggregate off by {e} (rel)");

    // masked per-client vectors must NOT be recorded as latest updates
    assert!(server.latest_updates().is_empty());
}

/// Acceptance: the same 8-client / 2-dropout round recovers when only
/// FOUR of the six survivors answer the recovery task — any 4-of-6
/// subset reconstructs both dropped clients' mask secrets, covering the
/// non-responsive survivors' pairs too.
#[test]
fn threshold_recovery_any_4_of_6_survivor_subset() {
    for responders in [
        &[0usize, 1, 2, 3] as &'static [usize],
        &[2, 3, 4, 5],
        &[0, 2, 3, 5],
    ] {
        let (out, server, captured) = private_server(
            PrivacyMode::SecAgg,
            &[6, 7],
            RevealBehaviour { responders: Some(responders) },
            |c| c,
            8,
            1,
        );
        out.unwrap();
        let hist = server.history();
        let audit = hist[0].secagg.as_ref().unwrap();
        assert_eq!(audit.threshold, 4);
        assert_eq!(audit.reconstructed.len(), 2, "subset {responders:?}");
        assert_eq!(audit.outcome, "recovered");
        let captured = captured.lock().unwrap();
        let expect = reference_aggregate(&captured);
        let e = rel_err(&server.container().clusters[0].params, &expect);
        assert!(e <= 1e-5, "subset {responders:?}: rel err {e}");
    }
}

/// Below the threshold with the default abort policy, the session fails
/// loudly and names the policy.
#[test]
fn below_threshold_abort_policy_fails_the_session() {
    // 3 responders < t=4: both dropped clients stay unrecoverable
    let (out, server, _captured) = private_server(
        PrivacyMode::SecAgg,
        &[6, 7],
        RevealBehaviour { responders: Some(&[0, 1, 2]) },
        |c| c,
        8,
        1,
    );
    let err = out.unwrap_err().to_string();
    assert!(err.contains("below reveal threshold"), "{err}");
    assert!(err.contains("abort"), "{err}");
    // the failed round was never applied
    let init = TestModel.init_params(3).unwrap();
    assert_eq!(server.container().clusters[0].params, init);
}

/// Below the threshold with the proceed policy, the round is voided
/// (parameters unchanged), audited, and training continues.
#[test]
fn below_threshold_proceed_policy_voids_the_round() {
    let (out, server, _captured) = private_server(
        PrivacyMode::SecAgg,
        &[6, 7],
        RevealBehaviour { responders: Some(&[0, 1, 2]) },
        |c| PrivacyConfig { reveal_policy: RevealPolicy::Proceed, ..c },
        8,
        2,
    );
    out.unwrap(); // the session survives
    let hist = server.history();
    assert_eq!(hist.len(), 2);
    for r in hist {
        let audit = r.secagg.as_ref().unwrap();
        assert_eq!(audit.outcome, "skipped");
        assert_eq!(audit.unrecovered.len(), 2);
        assert_eq!(audit.policy, RevealPolicy::Proceed);
    }
    // voided rounds leave the global parameters untouched
    let init = TestModel.init_params(3).unwrap();
    assert_eq!(server.container().clusters[0].params, init);
    assert_eq!(
        server.metrics().counter("fact.secagg.rounds_voided").get(),
        2
    );
}

/// Regression: a 2-client secagg round must still work — share dealing is
/// skipped (one holder per dealer can never meet t >= 2) and recovery
/// falls back to direct reveals, the pre-threshold behavior.
#[test]
fn two_client_secagg_round_recovers_via_direct_reveal() {
    // no dropouts: plain 2-party masked round
    let (out, server, captured) = private_server(
        PrivacyMode::SecAgg,
        &[],
        RevealBehaviour { responders: None },
        |c| c,
        2,
        1,
    );
    out.unwrap();
    {
        let captured = captured.lock().unwrap();
        assert_eq!(captured.len(), 2);
        let expect = reference_aggregate(&captured);
        let e = rel_err(&server.container().clusters[0].params, &expect);
        assert!(e <= 1e-5, "rel err {e}");
    }

    // one dropout: the lone survivor's direct reveal recovers the round
    let (out, server, captured) = private_server(
        PrivacyMode::SecAgg,
        &[1],
        RevealBehaviour { responders: None },
        |c| c,
        2,
        1,
    );
    out.unwrap();
    let hist = server.history();
    assert_eq!(hist[0].n_clients, 1);
    let audit = hist[0].secagg.as_ref().unwrap();
    assert_eq!(audit.dropped.len(), 1);
    assert!(audit.reconstructed.is_empty(), "no shares exist at n=2");
    assert_eq!(audit.direct_reveals, 1);
    let captured = captured.lock().unwrap();
    let expect = reference_aggregate(&captured);
    let e = rel_err(&server.container().clusters[0].params, &expect);
    assert!(e <= 1e-5, "rel err {e}");
}

#[test]
fn secagg_without_dropouts_matches_clear() {
    let (server, captured) = run_private_session(PrivacyMode::SecAgg, &[], 1);
    assert_eq!(server.history()[0].n_clients, 8);
    let audit = server.history()[0].secagg.as_ref().unwrap();
    assert_eq!(audit.outcome, "ok");
    assert!(audit.dropped.is_empty());
    let captured = captured.lock().unwrap();
    let expect = reference_aggregate(&captured);
    let e = rel_err(&server.container().clusters[0].params, &expect);
    assert!(e <= 1e-5, "rel err {e}");
}

#[test]
fn secagg_dp_combined_round_recovers_the_noised_aggregate() {
    // with DP stacked on top, the aggregate must equal the weighted
    // average of the *privatized* survivor updates — masking must not
    // interfere with the noise, and vice versa
    let (server, captured) =
        run_private_session(PrivacyMode::SecAggDp, &[2], 1);
    assert_eq!(server.history()[0].n_clients, 7);
    let captured = captured.lock().unwrap();
    let expect = reference_aggregate(&captured);
    let got = &server.container().clusters[0].params;
    let e = rel_err(got, &expect);
    assert!(e <= 1e-5, "rel err {e}");
    // and the DP ledger advanced
    assert_eq!(server.accountant().steps, 1);
    assert!(server.accountant().epsilon(1e-5) > 0.0);
}

#[test]
fn dp_only_mode_steps_accountant_and_persists_with_snapshots() {
    let (server, _) = run_private_session(PrivacyMode::Dp, &[], 3);
    assert_eq!(server.accountant().steps, 3);
    let eps = server.accountant().epsilon(1e-5);
    assert!(eps.is_finite() && eps > 0.0);

    // checkpoint carries the accountant; restore resumes the ledger
    let dir = std::env::temp_dir().join(format!(
        "feddart-privacy-int-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = ModelStore::new(FsObjectStore::new(&dir).unwrap());
    server.checkpoint(&store, 3).unwrap();

    let snap = store.load_latest("testmodel-c0").unwrap().unwrap();
    assert_eq!(
        snap.privacy.get("mode").and_then(Json::as_str),
        Some("dp")
    );
    let acct = dp::DpAccountant::from_json(
        snap.privacy.get("accountant").unwrap(),
    )
    .unwrap();
    assert_eq!(acct.steps, 3);

    // a fresh server restoring the snapshot adopts the ε ledger
    let captured = Arc::new(Mutex::new(BTreeMap::new()));
    let registry = registry_with_privacy_clients(
        &[],
        RevealBehaviour { responders: None },
        captured,
    );
    let wm = WorkflowManager::test_mode(8, registry, 4);
    let mut resumed = FactServer::new(wm)
        .with_privacy(PrivacyConfig::with_mode(PrivacyMode::Dp));
    resumed
        .initialization_by_model(Arc::new(TestModel), Arc::new(FixedRoundFl(1)), 3)
        .unwrap();
    assert_eq!(resumed.accountant().steps, 0);
    assert!(resumed.restore_latest(&store, 0).unwrap());
    assert_eq!(resumed.accountant().steps, 3);
}

#[test]
fn secagg_rejects_order_statistic_aggregation() {
    struct MedianModel;
    impl FactModel for MedianModel {
        fn name(&self) -> &str {
            "medianmodel"
        }
        fn param_count(&self) -> usize {
            PARAMS
        }
        fn init_params(&self, seed: i32) -> feddart::Result<Vec<f32>> {
            Ok(golden_f32(seed as u32, PARAMS))
        }
        fn aggregation(&self) -> &Aggregation {
            &Aggregation::Median
        }
    }
    let captured = Arc::new(Mutex::new(BTreeMap::new()));
    let registry = registry_with_privacy_clients(
        &[],
        RevealBehaviour { responders: None },
        captured,
    );
    let wm = WorkflowManager::test_mode(4, registry, 2);
    let mut server = FactServer::new(wm)
        .with_privacy(PrivacyConfig::with_mode(PrivacyMode::SecAgg));
    server
        .initialization_by_model(Arc::new(MedianModel), Arc::new(FixedRoundFl(1)), 1)
        .unwrap();
    let err = server.learn().unwrap_err();
    assert!(
        err.to_string().contains("incompatible with secure"),
        "{err}"
    );
}
