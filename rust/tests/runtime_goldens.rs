//! Numeric pinning: every AOT entry executed from Rust must reproduce the
//! outputs `python/compile/aot.py` recorded in `artifacts/goldens.json`.
//!
//! Inputs are regenerated with the splitmix64 scheme mirrored between
//! `aot.golden_f32/golden_i32` and `util::rng::golden_f32/golden_i32`; a
//! cross-language drift in either the RNG mirror or the HLO execution
//! fails loudly here.

use feddart::json::Json;
use feddart::runtime::{default_artifacts_dir, Engine, Tensor};
use feddart::util::rng::{golden_f32, golden_i32};

struct Checksum {
    mean: f64,
    l2: f64,
    first: Vec<f64>,
    len: usize,
}

fn checksum_of(j: &Json) -> Checksum {
    Checksum {
        mean: j.get("mean").and_then(Json::as_f64).unwrap(),
        l2: j.get("l2").and_then(Json::as_f64).unwrap(),
        first: j
            .get("first")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect(),
        len: j.get("len").and_then(Json::as_usize).unwrap(),
    }
}

fn compute_checksum(v: &[f32]) -> Checksum {
    let flat: Vec<f64> = v.iter().map(|&x| x as f64).collect();
    Checksum {
        mean: flat.iter().sum::<f64>() / flat.len() as f64,
        l2: flat.iter().map(|x| x * x).sum::<f64>().sqrt(),
        first: flat.iter().take(8).copied().collect(),
        len: flat.len(),
    }
}

fn assert_close(name: &str, got: &Checksum, want: &Checksum) {
    assert_eq!(got.len, want.len, "{name}: length");
    let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-6);
    assert!(
        rel(got.l2, want.l2) < 2e-4,
        "{name}: l2 {} vs {}",
        got.l2,
        want.l2
    );
    assert!(
        (got.mean - want.mean).abs() < 1e-5 + 1e-3 * want.mean.abs(),
        "{name}: mean {} vs {}",
        got.mean,
        want.mean
    );
    for (i, (g, w)) in got.first.iter().zip(&want.first).enumerate() {
        assert!(
            (g - w).abs() < 1e-4 + 1e-3 * w.abs(),
            "{name}: first[{i}] {g} vs {w}"
        );
    }
}

fn load() -> Option<(Engine, Json)> {
    let dir = default_artifacts_dir();
    if !dir.join("goldens.json").exists() {
        eprintln!("skipping golden tests: run `make artifacts` first");
        return None;
    }
    let goldens = Json::parse(&std::fs::read_to_string(dir.join("goldens.json")).unwrap())
        .unwrap();
    Some((Engine::load(&dir, 1).unwrap(), goldens))
}

#[test]
fn mlp_goldens() {
    let Some((engine, goldens)) = load() else { return };
    for model in ["mlp_tiny", "mlp_default"] {
        let g = goldens.need(model).unwrap();
        let meta = engine.manifest().model(model).unwrap().clone();
        let bt = meta.field_usize("train_batch").unwrap();
        let be = meta.field_usize("eval_batch").unwrap();
        let d = meta.field_usize("in_dim").unwrap();
        let c = meta.field_usize("classes").unwrap() as u32;

        // init
        let seed = g.need("init_seed").unwrap().as_i64().unwrap() as i32;
        let params = engine
            .execute(&format!("{model}_init"), vec![Tensor::scalar_i32(seed)])
            .unwrap()
            .remove(0);
        assert_close(
            &format!("{model}.init"),
            &compute_checksum(params.f32s().unwrap()),
            &checksum_of(g.need("init_params").unwrap()),
        );

        // train
        let tr = g.need("train").unwrap();
        let x = golden_f32(tr.need("x_seed").unwrap().as_i64().unwrap() as u32, bt * d);
        let y = golden_i32(tr.need("y_seed").unwrap().as_i64().unwrap() as u32, bt, c);
        let out = engine
            .execute(
                &format!("{model}_train"),
                vec![
                    params.clone(),
                    Tensor::with_shape_f32(vec![bt, d], x).unwrap(),
                    Tensor::with_shape_i32(vec![bt], y).unwrap(),
                    Tensor::scalar_f32(
                        tr.need("lr").unwrap().as_f64().unwrap() as f32
                    ),
                    Tensor::scalar_f32(
                        tr.need("mu").unwrap().as_f64().unwrap() as f32
                    ),
                    params.clone(),
                ],
            )
            .unwrap();
        let want_loss = tr.need("loss").unwrap().as_f64().unwrap();
        let got_loss = out[1].scalar().unwrap() as f64;
        assert!(
            (got_loss - want_loss).abs() < 1e-4 + 1e-3 * want_loss.abs(),
            "{model}.train loss {got_loss} vs {want_loss}"
        );
        assert_close(
            &format!("{model}.train.params"),
            &compute_checksum(out[0].f32s().unwrap()),
            &checksum_of(tr.need("new_params").unwrap()),
        );

        // eval
        let ev = g.need("eval").unwrap();
        let xe = golden_f32(ev.need("x_seed").unwrap().as_i64().unwrap() as u32, be * d);
        let ye = golden_i32(ev.need("y_seed").unwrap().as_i64().unwrap() as u32, be, c);
        let out = engine
            .execute(
                &format!("{model}_eval"),
                vec![
                    params.clone(),
                    Tensor::with_shape_f32(vec![be, d], xe).unwrap(),
                    Tensor::with_shape_i32(vec![be], ye).unwrap(),
                ],
            )
            .unwrap();
        let want_ls = ev.need("loss_sum").unwrap().as_f64().unwrap();
        let got_ls = out[0].scalar().unwrap() as f64;
        assert!(
            (got_ls - want_ls).abs() < 1e-3 + 1e-3 * want_ls.abs(),
            "{model}.eval loss_sum {got_ls} vs {want_ls}"
        );
        // correct-count must match exactly
        assert_eq!(
            out[1].scalar().unwrap() as f64,
            ev.need("ncorrect").unwrap().as_f64().unwrap(),
            "{model}.eval ncorrect"
        );
    }
    engine.shutdown();
}

#[test]
fn transformer_goldens() {
    let Some((engine, goldens)) = load() else { return };
    let model = "tfm_tiny";
    let g = goldens.need(model).unwrap();
    let meta = engine.manifest().model(model).unwrap().clone();
    let bt = meta.field_usize("train_batch").unwrap();
    let s = meta.field_usize("seq").unwrap();
    let v = meta.field_usize("vocab").unwrap() as u32;

    let params = engine
        .execute(&format!("{model}_init"), vec![Tensor::scalar_i32(42)])
        .unwrap()
        .remove(0);
    assert_close(
        "tfm.init",
        &compute_checksum(params.f32s().unwrap()),
        &checksum_of(g.need("init_params").unwrap()),
    );

    let tr = g.need("train").unwrap();
    let toks = golden_i32(
        tr.need("tok_seed").unwrap().as_i64().unwrap() as u32,
        bt * (s + 1),
        v,
    );
    let out = engine
        .execute(
            &format!("{model}_train"),
            vec![
                params.clone(),
                Tensor::with_shape_i32(vec![bt, s + 1], toks.clone()).unwrap(),
                Tensor::scalar_f32(tr.need("lr").unwrap().as_f64().unwrap() as f32),
                Tensor::scalar_f32(tr.need("mu").unwrap().as_f64().unwrap() as f32),
                params.clone(),
            ],
        )
        .unwrap();
    let want_loss = tr.need("loss").unwrap().as_f64().unwrap();
    let got_loss = out[1].scalar().unwrap() as f64;
    assert!(
        (got_loss - want_loss).abs() < 1e-3 + 1e-3 * want_loss.abs(),
        "tfm.train loss {got_loss} vs {want_loss}"
    );
    assert_close(
        "tfm.train.params",
        &compute_checksum(out[0].f32s().unwrap()),
        &checksum_of(tr.need("new_params").unwrap()),
    );

    let ev = g.need("eval").unwrap();
    let out = engine
        .execute(
            &format!("{model}_eval"),
            vec![
                params.clone(),
                Tensor::with_shape_i32(vec![bt, s + 1], toks).unwrap(),
            ],
        )
        .unwrap();
    let want_ls = ev.need("loss_sum").unwrap().as_f64().unwrap();
    let got_ls = out[0].scalar().unwrap() as f64;
    assert!(
        (got_ls - want_ls).abs() < 0.05 + 1e-3 * want_ls.abs(),
        "tfm.eval loss_sum {got_ls} vs {want_ls}"
    );
    assert_eq!(
        out[1].scalar().unwrap() as f64,
        ev.need("ntok").unwrap().as_f64().unwrap()
    );
    engine.shutdown();
}

#[test]
fn fedavg_kernel_goldens() {
    let Some((engine, goldens)) = load() else { return };
    for (name, (k, p)) in engine.manifest().aggregators.clone() {
        let g = goldens.need(&name).unwrap();
        let stacked = golden_f32(
            g.need("stacked_seed").unwrap().as_i64().unwrap() as u32,
            k * p,
        );
        let weights: Vec<f32> = golden_f32(
            g.need("weights_seed").unwrap().as_i64().unwrap() as u32,
            k,
        )
        .iter()
        .map(|v| v.abs() + 0.1)
        .collect();
        let out = engine
            .execute(
                &name,
                vec![
                    Tensor::with_shape_f32(vec![k, p], stacked).unwrap(),
                    Tensor::with_shape_f32(vec![k], weights).unwrap(),
                ],
            )
            .unwrap();
        assert_close(
            &name,
            &compute_checksum(out[0].f32s().unwrap()),
            &checksum_of(g.need("out").unwrap()),
        );
    }
    engine.shutdown();
}
