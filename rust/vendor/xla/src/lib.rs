//! Vendored offline stub of the `xla` PJRT bindings.
//!
//! The feddart runtime executes AOT-compiled HLO through a PJRT CPU client.
//! That native runtime is not available in this offline environment, so this
//! crate ships the API surface the engine programs against:
//!
//! * [`Literal`] is a **real** host-side container (type + dims + bytes) —
//!   tensor<->literal round-trips work and are unit-tested in `feddart`.
//! * [`PjRtClient::cpu`] returns an error, so engine threads report the
//!   runtime as unavailable instead of executing.  Everything artifact-gated
//!   (golden tests, FL integration, HLO benches) skips cleanly.
//!
//! Swapping in a linked PJRT build is a dependency change only; no feddart
//! source changes are required.

use std::fmt;

/// Error type of the bindings.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable (offline stub build) — HLO execution disabled";

/// Element types used by the shipped artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_width(&self) -> usize {
        4
    }
}

/// Sealed-ish trait mapping native element types onto [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_ne_bytes4(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_ne_bytes4(b: [u8; 4]) -> Self {
        f32::from_ne_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_ne_bytes4(b: [u8; 4]) -> Self {
        i32::from_ne_bytes(b)
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side literal: either an array (type + dims + raw bytes) or a tuple.
#[derive(Debug, Clone)]
pub enum Literal {
    Array {
        ty: ElementType,
        dims: Vec<usize>,
        bytes: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build an array literal from untyped bytes (the engine's upload path).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        bytes: &[u8],
    ) -> Result<Literal> {
        let expect = dims.iter().product::<usize>() * ty.byte_width();
        if bytes.len() != expect {
            return Err(Error(format!(
                "literal data mismatch: {} dims need {} bytes, got {}",
                dims.len(),
                expect,
                bytes.len()
            )));
        }
        Ok(Literal::Array { ty, dims: dims.to_vec(), bytes: bytes.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => Ok(ArrayShape {
                ty: *ty,
                dims: dims.iter().map(|&d| d as i64).collect(),
            }),
            Literal::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
        }
    }

    /// Decode the element data (native endianness; same-process round-trip).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, bytes, .. } => {
                if *ty != T::TY {
                    return Err(Error(format!(
                        "literal type mismatch: {ty:?} vs requested {:?}",
                        T::TY
                    )));
                }
                Ok(bytes
                    .chunks_exact(4)
                    .map(|c| T::from_ne_bytes4([c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            Literal::Tuple(_) => Err(Error("cannot decode a tuple literal".into())),
        }
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            Literal::Array { .. } => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// An HLO module parsed from text.  Stub: retains the path only.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("no such HLO file: {path}")));
        }
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// An XLA computation.  Stub: carries the proto through.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }
}

/// The PJRT client.  Stub: construction fails with a clear message.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// A compiled executable.  Stub: never constructible (client construction
/// fails), but the type checks the engine's cache/execute code paths.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<f32> = vec![1.0, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
                .unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.to_tuple().is_err());
    }

    #[test]
    fn literal_shape_validation() {
        let err = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[2, 2],
            &[0u8; 4],
        );
        assert!(err.is_err());
    }

    #[test]
    fn tuple_literals() {
        let a = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[1],
            &7i32.to_ne_bytes(),
        )
        .unwrap();
        let t = Literal::Tuple(vec![a]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("unavailable"));
    }
}
