//! Vendored minimal logging facade.
//!
//! API-compatible subset of the `log` crate (macros with `target:` syntax,
//! `Level`/`LevelFilter`, the `Log` trait, `set_logger`/`set_max_level`)
//! implemented with zero dependencies so the workspace builds offline.
//! `feddart::metrics::logserver::LogServer` is the crate's one `Log`
//! implementation.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log verbosity levels, most severe first (matches the real facade:
/// `Error < Warn < Info < Debug < Trace`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn to_level_filter(&self) -> LevelFilter {
        match self {
            Level::Error => LevelFilter::Error,
            Level::Warn => LevelFilter::Warn,
            Level::Info => LevelFilter::Info,
            Level::Debug => LevelFilter::Debug,
            Level::Trace => LevelFilter::Trace,
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Level filters: `Off` plus one filter per level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a log record (level + target).
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record passed to the installed logger.
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
}

/// The trait a logger implements.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger.  The first call wins; later calls error.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// The installed logger, if any.
pub fn logger() -> Option<&'static dyn Log> {
    LOGGER.get().copied()
}

// Called by the macros; not part of the public API contract.
#[doc(hidden)]
pub fn __private_api_log(args: fmt::Arguments, level: Level, target: &str) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    (target: $target:expr, $lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log(format_args!($($arg)+), $lvl, $target)
    };
    ($lvl:expr, $($arg:tt)+) => {
        $crate::log!(target: module_path!(), $lvl, $($arg)+)
    };
}

#[macro_export]
macro_rules! error {
    (target: $target:expr, $($arg:tt)+) => {
        $crate::log!(target: $target, $crate::Level::Error, $($arg)+)
    };
    ($($arg:tt)+) => {
        $crate::log!($crate::Level::Error, $($arg)+)
    };
}

#[macro_export]
macro_rules! warn {
    (target: $target:expr, $($arg:tt)+) => {
        $crate::log!(target: $target, $crate::Level::Warn, $($arg)+)
    };
    ($($arg:tt)+) => {
        $crate::log!($crate::Level::Warn, $($arg)+)
    };
}

#[macro_export]
macro_rules! info {
    (target: $target:expr, $($arg:tt)+) => {
        $crate::log!(target: $target, $crate::Level::Info, $($arg)+)
    };
    ($($arg:tt)+) => {
        $crate::log!($crate::Level::Info, $($arg)+)
    };
}

#[macro_export]
macro_rules! debug {
    (target: $target:expr, $($arg:tt)+) => {
        $crate::log!(target: $target, $crate::Level::Debug, $($arg)+)
    };
    ($($arg:tt)+) => {
        $crate::log!($crate::Level::Debug, $($arg)+)
    };
}

#[macro_export]
macro_rules! trace {
    (target: $target:expr, $($arg:tt)+) => {
        $crate::log!(target: $target, $crate::Level::Trace, $($arg)+)
    };
    ($($arg:tt)+) => {
        $crate::log!($crate::Level::Trace, $($arg)+)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_facade() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Warn <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(!(Level::Error <= LevelFilter::Off));
        assert_eq!(Level::Warn.as_str(), "WARN");
        assert_eq!(format!("{}", Level::Info), "INFO");
        assert_eq!(format!("{:>5}", Level::Warn), " WARN");
    }

    #[test]
    fn max_level_gate() {
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
        // Debug record is gated out before touching the (absent) logger.
        __private_api_log(format_args!("dropped"), Level::Debug, "t");
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
    }
}
