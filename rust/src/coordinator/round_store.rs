//! Durable round state: an explicit, serializable state machine for the
//! federated round lifecycle, persisted behind the [`RoundStore`] trait.
//!
//! Before this module existed the round lifecycle was implicit — smeared
//! across the FACT server round loop (`fact/server.rs`), the secure
//! aggregation board (`privacy/secagg.rs`) and the participation quorum
//! loop (`coordinator/workflow.rs`), all of it living in one process's
//! memory.  A coordinator crash mid-round lost every in-flight round,
//! every pending reveal, and any ε-ledger charge that had not yet made it
//! into a model snapshot.
//!
//! This module makes the lifecycle explicit:
//!
//! ```text
//!                        ┌──────────── recovery re-entry ────────────┐
//!                        ▼                                           │
//! Configured ──▶ Keys ──▶ Shares ──▶ Learn ──▶ Reveal ──▶ Aggregated ─▶ Closed
//!     │            │        │          │  ▲       │            │
//!     │            └────────┼──────────┤  │(re-dispatch)       │
//!     └─────────────────────┴──────────┘  │                    │
//!     (skip edges: no secagg / 2-client)  │                    │
//!                        any non-terminal phase ──────────▶ Voided
//! ```
//!
//! Every transition is produced by appending a [`RoundEvent`] through the
//! single typed transition function ([`transition`]); illegal sequences
//! are rejected before anything is persisted.  Two backends implement
//! [`RoundStore`]:
//!
//! * [`MemRoundStore`] — the pre-existing in-memory maps, now behind the
//!   trait.  This is the default: every round always runs through the
//!   state machine, durable or not.
//! * [`WalRoundStore`] — a write-ahead-logged directory: JSON-line
//!   events CRC-framed like the `.tensor` sidecars (see
//!   [`crate::util::tensorbuf`]), fsynced on phase boundaries, with
//!   periodic compacted snapshots.  On reopen the WAL is replayed; a
//!   corrupt tail is detected by the CRC frame, truncated, and every
//!   round it may have touched is marked *tainted* so the coordinator
//!   can void it under its [`RevealPolicy`] instead of silently
//!   resuming from a half-written record.
//!
//! The DP ε-ledger is persisted here too ([`LedgerCharge`]), *not* in
//! model snapshots: a charge and the round that caused it land in the
//! same log, so a crash between "round closed" and "ε charged" can no
//! longer fork the privacy accounting (the coordinator re-derives the
//! missing charge from the closed round on recovery).
//!
//! Threat-model note: the WAL stores exactly what the coordinator
//! already holds in memory — relayed *encrypted* Shamir shares, clear
//! commitments, public DH keys, and (DP-noised, still pair-masked or
//! aggregated) update tensors.  It never stores client secrets or pair
//! seeds, so disk compromise grants nothing beyond coordinator-memory
//! compromise.  See the "Privacy" section of the crate README for the
//! full threat model.
//!
//! [`RevealPolicy`]: crate::privacy::RevealPolicy

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{FedError, Result};
use crate::json::Json;
use crate::privacy::{round_id_from_hex, round_id_to_hex};
use crate::util::tensorbuf::{crc32, TensorBuf};

/// Magic prefix of one CRC-framed WAL line: `FDW1 <8-hex-crc> <json>`.
const WAL_MAGIC: &str = "FDW1";
/// Magic prefix of the compacted snapshot file: `FDWS1 <8-hex-crc> <json>`.
const SNAP_MAGIC: &str = "FDWS1";
/// Appends between automatic compactions of a [`WalRoundStore`].
const COMPACT_EVERY: usize = 4096;

/// Wall-clock milliseconds since the unix epoch — the timestamp stamped
/// on every [`RoundEvent`] at append time.
pub fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

// ====================================================================
// phases
// ====================================================================

/// The phase a round is in.  Terminal phases ([`RoundPhase::Closed`],
/// [`RoundPhase::Voided`]) never transition again.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundPhase {
    /// Cohort drawn, round id derived, broadcast params pinned.
    Configured,
    /// Secagg phase 1 done: per-round DH public keys collected.
    Keys,
    /// Secagg phase 2 done: encrypted Shamir shares + commitments relayed.
    Shares,
    /// Learn tasks dispatched (and possibly closed) — updates pending or
    /// collected, aggregate not yet recovered.
    Learn,
    /// Dropout recovery ran: reveals collected, audit recorded.
    Reveal,
    /// Aggregate applied to the cluster model; post-apply params pinned.
    Aggregated,
    /// Terminal: round fully accounted (record + ε charge replayable).
    Closed,
    /// Terminal: round abandoned (unrecoverable dropout, elapsed
    /// deadline, corrupt WAL tail, …) — audited, never applied.
    Voided,
}

impl RoundPhase {
    /// Stable lowercase name used in the serialized form and the REST
    /// `GET /rounds` listing.
    pub fn as_str(&self) -> &'static str {
        match self {
            RoundPhase::Configured => "configured",
            RoundPhase::Keys => "keys",
            RoundPhase::Shares => "shares",
            RoundPhase::Learn => "learn",
            RoundPhase::Reveal => "reveal",
            RoundPhase::Aggregated => "aggregated",
            RoundPhase::Closed => "closed",
            RoundPhase::Voided => "voided",
        }
    }

    /// Parse the serialized phase name back.
    pub fn from_str(s: &str) -> Result<RoundPhase> {
        Ok(match s {
            "configured" => RoundPhase::Configured,
            "keys" => RoundPhase::Keys,
            "shares" => RoundPhase::Shares,
            "learn" => RoundPhase::Learn,
            "reveal" => RoundPhase::Reveal,
            "aggregated" => RoundPhase::Aggregated,
            "closed" => RoundPhase::Closed,
            "voided" => RoundPhase::Voided,
            other => {
                return Err(FedError::Json(format!("unknown round phase '{other}'")))
            }
        })
    }

    /// Whether the phase is final ([`Closed`](RoundPhase::Closed) or
    /// [`Voided`](RoundPhase::Voided)).
    pub fn is_terminal(&self) -> bool {
        matches!(self, RoundPhase::Closed | RoundPhase::Voided)
    }
}

// ====================================================================
// events
// ====================================================================

/// One client update as persisted in a [`EventKind::LearnClosed`] event.
///
/// Mirrors the FACT layer's `ClientUpdate` field-for-field; redeclared
/// here so the store stays a coordinator-layer concern with no FACT
/// import.  Under secure aggregation `params` is still pair-masked —
/// persisting it leaks nothing the coordinator did not already hold.
#[derive(Debug, Clone)]
pub struct StoredUpdate {
    /// Reporting device name.
    pub device: String,
    /// The (possibly masked, possibly DP-noised) update tensor.
    pub params: TensorBuf,
    /// Client-reported sample count (aggregation weight).
    pub n_samples: f32,
    /// Client-reported training loss.
    pub loss: f32,
    /// Client-side wall-clock seconds spent on the task.
    pub duration: f64,
    /// Client-reported effective local step count (FedNova; 0 = not
    /// reported / not a FedNova round).
    pub tau: f32,
}

impl StoredUpdate {
    /// Serialize to the WAL JSON form.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("device", self.device.as_str())
            .set("params", self.params.clone())
            .set("n_samples", self.n_samples)
            .set("loss", self.loss)
            .set("duration", self.duration);
        if self.tau != 0.0 {
            o = o.set("tau", self.tau);
        }
        o
    }

    /// Parse the WAL JSON form back.
    pub fn from_json(j: &Json) -> Result<StoredUpdate> {
        let params = TensorBuf::from_json(j.need("params")?)?;
        Ok(StoredUpdate {
            device: j
                .get("device")
                .and_then(Json::as_str)
                .ok_or_else(|| FedError::Json("update missing 'device'".into()))?
                .to_string(),
            params,
            n_samples: j.get("n_samples").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            loss: j.get("loss").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            duration: j.get("duration").and_then(Json::as_f64).unwrap_or(0.0),
            tau: j.get("tau").and_then(Json::as_f64).unwrap_or(0.0) as f32,
        })
    }
}

/// What happened to a round — the payload of a [`RoundEvent`].
///
/// Each variant carries everything needed to *re-enter* the round at
/// that point after a crash, so the WAL alone (no process memory)
/// reconstructs an in-flight round exactly.
#[derive(Debug, Clone)]
pub enum EventKind {
    /// Round opened: cohort drawn, broadcast params pinned.
    Configured {
        /// Outer clustering-iteration index.
        clustering_round: usize,
        /// Cluster the round trains.
        cluster_id: usize,
        /// Federated round index within the cluster.
        round: usize,
        /// Sampled cohort (sorted device names).
        cohort: Vec<String>,
        /// Realized sampling rate `q` of the cohort draw (for DP).
        sample_rate: f64,
        /// Privacy mode string (`"none"`, `"dp"`, `"secagg"`, `"secagg+dp"`).
        mode: String,
        /// Cluster params broadcast this round (pre-update).
        params: TensorBuf,
        /// Configured participation deadline (0 = none).
        deadline_ms: u64,
        /// Session tag the round id was derived from.
        session_tag: u64,
    },
    /// Cohort repaired in-flight: members detected dead before learn
    /// dispatch were replaced from the over-provisioned candidate pool
    /// *inside the same round*.  Legal any time before dispatch in
    /// clear/dp rounds and only before [`EventKind::SharesDealt`] under
    /// secagg (after share dealing the threshold-reveal path is the
    /// recovery mechanism) — the transition table enforces exactly that.
    CohortRepaired {
        /// Members detected dead at repair time.  They leave the
        /// addressed cohort (a disconnected client rejects the whole
        /// task at submit) but the accountant still charges the union
        /// of both draws; one that revives mid-round simply waits for
        /// the next draw.
        presumed_dead: Vec<String>,
        /// Replacements drawn from the candidate pool (sorted).
        replacements: Vec<String>,
        /// The full post-repair cohort (sorted) — resume/replay uses
        /// this, not the original draw.
        cohort: Vec<String>,
        /// Conservative effective inclusion probability after repair
        /// (the DP accountant charges this, never the original draw's).
        sample_rate: f64,
    },
    /// Secagg phase 1 closed: validated per-round DH public keys.
    KeysCollected {
        /// participant → lowercase hex DH public key.
        pubkeys: BTreeMap<String, String>,
        /// Resolved `t` of the t-of-n share recovery.
        threshold: usize,
    },
    /// Secagg phase 2 closed: encrypted shares + commitments relayed.
    SharesDealt {
        /// Sorted clients that completed both setup phases.
        participants: Vec<String>,
        /// dealer → recipient → hex ciphertext (end-to-end encrypted).
        enc_shares: BTreeMap<String, BTreeMap<String, String>>,
        /// dealer → recipient → hex share commitment (clear).
        commits: BTreeMap<String, BTreeMap<String, String>>,
    },
    /// Learn tasks handed to the scheduler.
    LearnDispatched {
        /// Devices the learn task was addressed to.
        addressed: Vec<String>,
        /// Wall-clock dispatch time (ms since epoch) — recovery measures
        /// elapsed deadline from here.
        dispatched_at_ms: u64,
        /// Effective deadline for this dispatch (0 = none).
        deadline_ms: u64,
    },
    /// Learn phase closed: updates collected (still masked under secagg).
    LearnClosed {
        /// Updates received before close, sorted by device.
        updates: Vec<StoredUpdate>,
        /// Stragglers that arrived in the late-grace window.
        late: usize,
        /// Participants that never reported.
        dropped: Vec<String>,
    },
    /// Dropout recovery ran; the secagg audit trail for the round.
    Revealed {
        /// Serialized `SecAggAudit` (see `fact::server`).
        audit: Json,
    },
    /// Aggregate applied to the cluster model.
    Aggregated {
        /// Post-apply cluster params — makes resuming at this phase an
        /// idempotent replacement even under a momentum optimizer.
        params: TensorBuf,
        /// Serialized `RoundRecord` audit entry.
        record: Json,
        /// Post-apply server-optimizer state (serialized `OptState`;
        /// `Null` under a stateless rule) — resuming at this phase
        /// restores the optimizer's buffers exactly, not just params.
        opt_state: Json,
    },
    /// Round fully accounted; terminal.
    Closed,
    /// Round abandoned; terminal.
    Voided {
        /// Human-readable reason (policy void, elapsed deadline, taint…).
        reason: String,
        /// Serialized `RoundRecord` when one could be produced.
        record: Json,
    },
}

impl EventKind {
    /// Stable lowercase tag used as the serialized `"kind"` field.
    pub fn tag(&self) -> &'static str {
        match self {
            EventKind::Configured { .. } => "configured",
            EventKind::CohortRepaired { .. } => "cohort_repaired",
            EventKind::KeysCollected { .. } => "keys_collected",
            EventKind::SharesDealt { .. } => "shares_dealt",
            EventKind::LearnDispatched { .. } => "learn_dispatched",
            EventKind::LearnClosed { .. } => "learn_closed",
            EventKind::Revealed { .. } => "revealed",
            EventKind::Aggregated { .. } => "aggregated",
            EventKind::Closed => "closed",
            EventKind::Voided { .. } => "voided",
        }
    }
}

/// One serializable state-machine transition of one round.
#[derive(Debug, Clone)]
pub struct RoundEvent {
    /// Round the event belongs to (the FACT server's derived round id).
    pub round_id: u64,
    /// Wall-clock append time, ms since the unix epoch.
    pub at_ms: u64,
    /// What happened.
    pub kind: EventKind,
}

fn str_map_json(m: &BTreeMap<String, String>) -> Json {
    let mut o = Json::obj();
    for (k, v) in m {
        o = o.set(k, v.as_str());
    }
    o
}

fn nested_map_json(m: &BTreeMap<String, BTreeMap<String, String>>) -> Json {
    let mut o = Json::obj();
    for (k, v) in m {
        o = o.set(k, str_map_json(v));
    }
    o
}

fn str_vec_json(v: &[String]) -> Json {
    Json::Arr(v.iter().map(|s| Json::Str(s.clone())).collect())
}

fn parse_str_vec(j: Option<&Json>) -> Vec<String> {
    j.and_then(Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect()
        })
        .unwrap_or_default()
}

fn parse_str_map(j: Option<&Json>) -> BTreeMap<String, String> {
    j.and_then(Json::as_obj)
        .map(|o| {
            o.iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect()
        })
        .unwrap_or_default()
}

fn parse_nested_map(j: Option<&Json>) -> BTreeMap<String, BTreeMap<String, String>> {
    j.and_then(Json::as_obj)
        .map(|o| {
            o.iter()
                .map(|(k, v)| (k.clone(), parse_str_map(Some(v))))
                .collect()
        })
        .unwrap_or_default()
}

fn need_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| FedError::Json(format!("event missing usize '{key}'")))
}

fn need_hex_u64(j: &Json, key: &str) -> Result<u64> {
    let s = j
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| FedError::Json(format!("event missing hex '{key}'")))?;
    round_id_from_hex(s)
}

impl RoundEvent {
    /// Build an event stamped with the current wall clock.
    pub fn new(round_id: u64, kind: EventKind) -> RoundEvent {
        RoundEvent {
            round_id,
            at_ms: now_ms(),
            kind,
        }
    }

    /// Serialize to the WAL JSON form.  `u64` identifiers are hex
    /// strings — `f64` JSON numbers lose integer precision past 2⁵³.
    pub fn to_json(&self) -> Json {
        let base = Json::obj()
            .set("round_id", round_id_to_hex(self.round_id).as_str())
            .set("at_ms", self.at_ms as f64)
            .set("kind", self.kind.tag());
        match &self.kind {
            EventKind::Configured {
                clustering_round,
                cluster_id,
                round,
                cohort,
                sample_rate,
                mode,
                params,
                deadline_ms,
                session_tag,
            } => base
                .set("clustering_round", *clustering_round)
                .set("cluster_id", *cluster_id)
                .set("round", *round)
                .set("cohort", str_vec_json(cohort))
                .set("sample_rate", *sample_rate)
                .set("mode", mode.as_str())
                .set("params", params.clone())
                .set("deadline_ms", *deadline_ms as f64)
                .set("session_tag", round_id_to_hex(*session_tag).as_str()),
            EventKind::CohortRepaired {
                presumed_dead,
                replacements,
                cohort,
                sample_rate,
            } => base
                .set("presumed_dead", str_vec_json(presumed_dead))
                .set("replacements", str_vec_json(replacements))
                .set("cohort", str_vec_json(cohort))
                .set("sample_rate", *sample_rate),
            EventKind::KeysCollected { pubkeys, threshold } => base
                .set("pubkeys", str_map_json(pubkeys))
                .set("threshold", *threshold),
            EventKind::SharesDealt {
                participants,
                enc_shares,
                commits,
            } => base
                .set("participants", str_vec_json(participants))
                .set("enc_shares", nested_map_json(enc_shares))
                .set("commits", nested_map_json(commits)),
            EventKind::LearnDispatched {
                addressed,
                dispatched_at_ms,
                deadline_ms,
            } => base
                .set("addressed", str_vec_json(addressed))
                .set("dispatched_at_ms", *dispatched_at_ms as f64)
                .set("deadline_ms", *deadline_ms as f64),
            EventKind::LearnClosed {
                updates,
                late,
                dropped,
            } => base
                .set(
                    "updates",
                    Json::Arr(updates.iter().map(StoredUpdate::to_json).collect()),
                )
                .set("late", *late)
                .set("dropped", str_vec_json(dropped)),
            EventKind::Revealed { audit } => base.set("audit", audit.clone()),
            EventKind::Aggregated { params, record, opt_state } => {
                let mut o = base
                    .set("params", params.clone())
                    .set("record", record.clone());
                // omitted when Null: a stateless optimizer's WAL stays
                // byte-identical to the pre-seam format
                if !matches!(opt_state, Json::Null) {
                    o = o.set("opt_state", opt_state.clone());
                }
                o
            }
            EventKind::Closed => base,
            EventKind::Voided { reason, record } => base
                .set("reason", reason.as_str())
                .set("record", record.clone()),
        }
    }

    /// Parse the WAL JSON form back.
    pub fn from_json(j: &Json) -> Result<RoundEvent> {
        let round_id = need_hex_u64(j, "round_id")?;
        let at_ms = j.get("at_ms").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let tag = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| FedError::Json("event missing 'kind'".into()))?;
        let kind = match tag {
            "configured" => EventKind::Configured {
                clustering_round: need_usize(j, "clustering_round")?,
                cluster_id: need_usize(j, "cluster_id")?,
                round: need_usize(j, "round")?,
                cohort: parse_str_vec(j.get("cohort")),
                sample_rate: j.get("sample_rate").and_then(Json::as_f64).unwrap_or(1.0),
                mode: j
                    .get("mode")
                    .and_then(Json::as_str)
                    .unwrap_or("none")
                    .to_string(),
                params: TensorBuf::from_json(j.need("params")?)?,
                deadline_ms: j.get("deadline_ms").and_then(Json::as_f64).unwrap_or(0.0)
                    as u64,
                session_tag: need_hex_u64(j, "session_tag")?,
            },
            "cohort_repaired" => EventKind::CohortRepaired {
                presumed_dead: parse_str_vec(j.get("presumed_dead")),
                replacements: parse_str_vec(j.get("replacements")),
                cohort: parse_str_vec(j.get("cohort")),
                sample_rate: j.get("sample_rate").and_then(Json::as_f64).unwrap_or(1.0),
            },
            "keys_collected" => EventKind::KeysCollected {
                pubkeys: parse_str_map(j.get("pubkeys")),
                threshold: need_usize(j, "threshold")?,
            },
            "shares_dealt" => EventKind::SharesDealt {
                participants: parse_str_vec(j.get("participants")),
                enc_shares: parse_nested_map(j.get("enc_shares")),
                commits: parse_nested_map(j.get("commits")),
            },
            "learn_dispatched" => EventKind::LearnDispatched {
                addressed: parse_str_vec(j.get("addressed")),
                dispatched_at_ms: j
                    .get("dispatched_at_ms")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as u64,
                deadline_ms: j.get("deadline_ms").and_then(Json::as_f64).unwrap_or(0.0)
                    as u64,
            },
            "learn_closed" => {
                let updates = j
                    .get("updates")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().map(StoredUpdate::from_json).collect())
                    .transpose()?
                    .unwrap_or_default();
                EventKind::LearnClosed {
                    updates,
                    late: j.get("late").and_then(Json::as_usize).unwrap_or(0),
                    dropped: parse_str_vec(j.get("dropped")),
                }
            }
            "revealed" => EventKind::Revealed {
                audit: j.get("audit").cloned().unwrap_or(Json::Null),
            },
            "aggregated" => EventKind::Aggregated {
                params: TensorBuf::from_json(j.need("params")?)?,
                record: j.get("record").cloned().unwrap_or(Json::Null),
                opt_state: j.get("opt_state").cloned().unwrap_or(Json::Null),
            },
            "closed" => EventKind::Closed,
            "voided" => EventKind::Voided {
                reason: j
                    .get("reason")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                record: j.get("record").cloned().unwrap_or(Json::Null),
            },
            other => {
                return Err(FedError::Json(format!("unknown event kind '{other}'")))
            }
        };
        Ok(RoundEvent {
            round_id,
            at_ms,
            kind,
        })
    }
}

// ====================================================================
// the transition function
// ====================================================================

/// THE typed transition function: given the round's current phase
/// (`None` = round does not exist yet) and an incoming event, return the
/// next phase or reject the sequence.
///
/// Skip and re-entry edges are deliberate parts of the machine, not
/// leniency:
///
/// * `Configured → Learn` — non-secagg rounds have no setup phases;
/// * `Keys → Learn` — a 2-client secagg round skips share dealing
///   (below any meaningful threshold, direct reveals only);
/// * `Keys → Keys`, `Shares → Shares` via `KeysCollected`/`SharesDealt`,
///   and `Learn → Learn` via `LearnDispatched` — recovery re-entry: a
///   coordinator that crashed after persisting a phase re-runs it and
///   re-appends the (deterministically equal) result;
/// * any non-terminal phase `→ Voided` — abandonment is always legal.
pub fn transition(cur: Option<RoundPhase>, kind: &EventKind) -> Result<RoundPhase> {
    use RoundPhase as P;
    let next = match (cur, kind) {
        (None, EventKind::Configured { .. }) => P::Configured,
        // in-round repair stays in phase; legal only before share dealing
        // (clear/dp rounds never leave Configured before dispatch, and a
        // secagg round past SharesDealt must use the threshold-reveal
        // path instead)
        (Some(P::Configured), EventKind::CohortRepaired { .. }) => P::Configured,
        (Some(P::Keys), EventKind::CohortRepaired { .. }) => P::Keys,
        (Some(P::Configured) | Some(P::Keys) | Some(P::Shares), EventKind::KeysCollected { .. }) => {
            P::Keys
        }
        (Some(P::Keys) | Some(P::Shares), EventKind::SharesDealt { .. }) => P::Shares,
        (
            Some(P::Configured) | Some(P::Keys) | Some(P::Shares) | Some(P::Learn),
            EventKind::LearnDispatched { .. },
        ) => P::Learn,
        (Some(P::Learn), EventKind::LearnClosed { .. }) => P::Learn,
        // Reveal -> Reveal: a resumed round re-runs its (deterministic)
        // reveal and re-appends the audit
        (Some(P::Learn) | Some(P::Reveal), EventKind::Revealed { .. }) => P::Reveal,
        (Some(P::Learn) | Some(P::Reveal), EventKind::Aggregated { .. }) => P::Aggregated,
        (Some(P::Aggregated), EventKind::Closed) => P::Closed,
        (Some(p), EventKind::Voided { .. }) if !p.is_terminal() => P::Voided,
        (cur, kind) => {
            return Err(FedError::Fact(format!(
                "illegal round transition: {} in phase {}",
                kind.tag(),
                cur.map(|p| p.as_str()).unwrap_or("<none>")
            )))
        }
    };
    Ok(next)
}

// ====================================================================
// accumulated round state
// ====================================================================

/// Everything known about one round — the fold of its event sequence.
///
/// This is what [`RoundStore::round`] returns and what the recovery path
/// resumes from; every field is reconstructed from the WAL alone.
#[derive(Debug, Clone)]
pub struct RoundState {
    /// Derived round id (see the FACT server's round-id derivation).
    pub round_id: u64,
    /// Current phase.
    pub phase: RoundPhase,
    /// Set when a corrupt WAL tail was truncated while this round was
    /// in flight — its last persisted events may be missing, so it must
    /// be voided (per `RevealPolicy`), never silently resumed.
    pub tainted: bool,
    /// Outer clustering-iteration index.
    pub clustering_round: usize,
    /// Cluster the round trains.
    pub cluster_id: usize,
    /// Federated round index within the cluster.
    pub round: usize,
    /// Sampled cohort (post-repair when the round was repaired).
    pub cohort: Vec<String>,
    /// Realized sampling rate of the cohort draw (conservatively raised
    /// by in-round repair).
    pub sample_rate: f64,
    /// Replacements folded in by in-round cohort repair (0 = untouched).
    pub repaired: usize,
    /// Privacy mode string at configure time.
    pub mode: String,
    /// Broadcast (pre-update) params; trimmed once terminal.
    pub params: Option<TensorBuf>,
    /// Configured participation deadline (0 = none).
    pub deadline_ms: u64,
    /// Session tag the round id was derived from.
    pub session_tag: u64,
    /// participant → hex DH public key (secagg phase 1).
    pub pubkeys: BTreeMap<String, String>,
    /// Resolved reveal threshold `t`.
    pub threshold: usize,
    /// Masking participant set (secagg phase 2, or key posters if share
    /// dealing was skipped).
    pub participants: Vec<String>,
    /// dealer → recipient → hex encrypted share; trimmed once terminal.
    pub enc_shares: BTreeMap<String, BTreeMap<String, String>>,
    /// dealer → recipient → hex share commitment; trimmed once terminal.
    pub commits: BTreeMap<String, BTreeMap<String, String>>,
    /// Devices the learn task was addressed to.
    pub addressed: Vec<String>,
    /// Wall-clock ms of the last learn dispatch (0 = never dispatched).
    pub dispatched_at_ms: u64,
    /// Deadline of the last learn dispatch (0 = none).
    pub learn_deadline_ms: u64,
    /// Collected updates; trimmed once terminal.
    pub updates: Vec<StoredUpdate>,
    /// Late arrivals counted at learn close.
    pub late: usize,
    /// Participants that never reported to the learn phase.
    pub dropped: Vec<String>,
    /// Secagg audit (serialized `SecAggAudit`), if recovery ran.
    pub audit: Option<Json>,
    /// Post-apply cluster params — kept through `Closed` so recovery can
    /// fast-forward the cluster model exactly.
    pub params_after: Option<TensorBuf>,
    /// Post-apply server-optimizer state (serialized `OptState`) — kept
    /// through `Closed` like `params_after`, so recovery under a
    /// stateful optimizer restores the exact momentum buffers.
    pub opt_state: Option<Json>,
    /// Serialized `RoundRecord`, once aggregated or voided with one.
    pub record: Option<Json>,
    /// Why the round was voided, if it was.
    pub void_reason: Option<String>,
}

impl RoundState {
    fn new(round_id: u64) -> RoundState {
        RoundState {
            round_id,
            phase: RoundPhase::Configured,
            tainted: false,
            clustering_round: 0,
            cluster_id: 0,
            round: 0,
            cohort: Vec::new(),
            sample_rate: 1.0,
            repaired: 0,
            mode: String::new(),
            params: None,
            deadline_ms: 0,
            session_tag: 0,
            pubkeys: BTreeMap::new(),
            threshold: 0,
            participants: Vec::new(),
            enc_shares: BTreeMap::new(),
            commits: BTreeMap::new(),
            addressed: Vec::new(),
            dispatched_at_ms: 0,
            learn_deadline_ms: 0,
            updates: Vec::new(),
            late: 0,
            dropped: Vec::new(),
            audit: None,
            params_after: None,
            opt_state: None,
            record: None,
            void_reason: None,
        }
    }

    /// Fold one event into the state (the caller has already validated
    /// the transition).
    fn absorb(&mut self, ev: &RoundEvent, next: RoundPhase) {
        match &ev.kind {
            EventKind::Configured {
                clustering_round,
                cluster_id,
                round,
                cohort,
                sample_rate,
                mode,
                params,
                deadline_ms,
                session_tag,
            } => {
                self.clustering_round = *clustering_round;
                self.cluster_id = *cluster_id;
                self.round = *round;
                self.cohort = cohort.clone();
                self.sample_rate = *sample_rate;
                self.mode = mode.clone();
                self.params = Some(params.clone());
                self.deadline_ms = *deadline_ms;
                self.session_tag = *session_tag;
            }
            EventKind::CohortRepaired {
                replacements,
                cohort,
                sample_rate,
                ..
            } => {
                self.cohort = cohort.clone();
                self.sample_rate = *sample_rate;
                self.repaired += replacements.len();
            }
            EventKind::KeysCollected { pubkeys, threshold } => {
                self.pubkeys = pubkeys.clone();
                self.threshold = *threshold;
                // share dealing may be skipped (2-client round): until
                // SharesDealt lands, the key posters ARE the participants
                self.participants = pubkeys.keys().cloned().collect();
            }
            EventKind::SharesDealt {
                participants,
                enc_shares,
                commits,
            } => {
                self.participants = participants.clone();
                self.enc_shares = enc_shares.clone();
                self.commits = commits.clone();
            }
            EventKind::LearnDispatched {
                addressed,
                dispatched_at_ms,
                deadline_ms,
            } => {
                self.addressed = addressed.clone();
                self.dispatched_at_ms = *dispatched_at_ms;
                self.learn_deadline_ms = *deadline_ms;
            }
            EventKind::LearnClosed {
                updates,
                late,
                dropped,
            } => {
                self.updates = updates.clone();
                self.late = *late;
                self.dropped = dropped.clone();
            }
            EventKind::Revealed { audit } => {
                self.audit = Some(audit.clone());
            }
            EventKind::Aggregated { params, record, opt_state } => {
                self.params_after = Some(params.clone());
                self.record = Some(record.clone());
                self.opt_state = if opt_state.is_null() {
                    None
                } else {
                    Some(opt_state.clone())
                };
            }
            EventKind::Closed => {}
            EventKind::Voided { reason, record } => {
                self.void_reason = Some(reason.clone());
                if !record.is_null() {
                    self.record = Some(record.clone());
                }
            }
        }
        self.phase = next;
        if next.is_terminal() {
            // trim bulk payloads a terminal round no longer needs;
            // params_after stays (cluster fast-forward) and so does the
            // record (audit history replay)
            self.params = None;
            self.updates.clear();
            self.enc_shares.clear();
            self.commits.clear();
        }
    }

    /// Serialize the full state (snapshot form).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("round_id", round_id_to_hex(self.round_id).as_str())
            .set("phase", self.phase.as_str())
            .set("tainted", self.tainted)
            .set("clustering_round", self.clustering_round)
            .set("cluster_id", self.cluster_id)
            .set("round", self.round)
            .set("cohort", str_vec_json(&self.cohort))
            .set("sample_rate", self.sample_rate)
            .set("repaired", self.repaired)
            .set("mode", self.mode.as_str())
            .set("deadline_ms", self.deadline_ms as f64)
            .set("session_tag", round_id_to_hex(self.session_tag).as_str())
            .set("pubkeys", str_map_json(&self.pubkeys))
            .set("threshold", self.threshold)
            .set("participants", str_vec_json(&self.participants))
            .set("enc_shares", nested_map_json(&self.enc_shares))
            .set("commits", nested_map_json(&self.commits))
            .set("addressed", str_vec_json(&self.addressed))
            .set("dispatched_at_ms", self.dispatched_at_ms as f64)
            .set("learn_deadline_ms", self.learn_deadline_ms as f64)
            .set(
                "updates",
                Json::Arr(self.updates.iter().map(StoredUpdate::to_json).collect()),
            )
            .set("late", self.late)
            .set("dropped", str_vec_json(&self.dropped));
        if let Some(p) = &self.params {
            o = o.set("params", p.clone());
        }
        if let Some(a) = &self.audit {
            o = o.set("audit", a.clone());
        }
        if let Some(p) = &self.params_after {
            o = o.set("params_after", p.clone());
        }
        if let Some(s) = &self.opt_state {
            o = o.set("opt_state", s.clone());
        }
        if let Some(r) = &self.record {
            o = o.set("record", r.clone());
        }
        if let Some(r) = &self.void_reason {
            o = o.set("void_reason", r.as_str());
        }
        o
    }

    /// Parse the snapshot form back.
    pub fn from_json(j: &Json) -> Result<RoundState> {
        let mut s = RoundState::new(need_hex_u64(j, "round_id")?);
        s.phase = RoundPhase::from_str(
            j.get("phase")
                .and_then(Json::as_str)
                .ok_or_else(|| FedError::Json("round state missing 'phase'".into()))?,
        )?;
        s.tainted = j.get("tainted").and_then(Json::as_bool).unwrap_or(false);
        s.clustering_round = need_usize(j, "clustering_round")?;
        s.cluster_id = need_usize(j, "cluster_id")?;
        s.round = need_usize(j, "round")?;
        s.cohort = parse_str_vec(j.get("cohort"));
        s.sample_rate = j.get("sample_rate").and_then(Json::as_f64).unwrap_or(1.0);
        s.repaired = j.get("repaired").and_then(Json::as_usize).unwrap_or(0);
        s.mode = j
            .get("mode")
            .and_then(Json::as_str)
            .unwrap_or("none")
            .to_string();
        s.deadline_ms = j.get("deadline_ms").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        s.session_tag = need_hex_u64(j, "session_tag")?;
        s.pubkeys = parse_str_map(j.get("pubkeys"));
        s.threshold = j.get("threshold").and_then(Json::as_usize).unwrap_or(0);
        s.participants = parse_str_vec(j.get("participants"));
        s.enc_shares = parse_nested_map(j.get("enc_shares"));
        s.commits = parse_nested_map(j.get("commits"));
        s.addressed = parse_str_vec(j.get("addressed"));
        s.dispatched_at_ms = j
            .get("dispatched_at_ms")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64;
        s.learn_deadline_ms = j
            .get("learn_deadline_ms")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64;
        s.updates = j
            .get("updates")
            .and_then(Json::as_arr)
            .map(|a| a.iter().map(StoredUpdate::from_json).collect())
            .transpose()?
            .unwrap_or_default();
        s.late = j.get("late").and_then(Json::as_usize).unwrap_or(0);
        s.dropped = parse_str_vec(j.get("dropped"));
        if let Some(p) = j.get("params") {
            s.params = Some(TensorBuf::from_json(p)?);
        }
        s.audit = j.get("audit").cloned();
        if let Some(p) = j.get("params_after") {
            s.params_after = Some(TensorBuf::from_json(p)?);
        }
        s.opt_state = j.get("opt_state").cloned();
        s.record = j.get("record").cloned();
        s.void_reason = j
            .get("void_reason")
            .and_then(Json::as_str)
            .map(str::to_string);
        Ok(s)
    }

    /// Compact single-line summary for listings and logs.
    pub fn summary_json(&self) -> Json {
        let mut o = Json::obj()
            .set("round_id", round_id_to_hex(self.round_id).as_str())
            .set("phase", self.phase.as_str())
            .set("tainted", self.tainted)
            .set("clustering_round", self.clustering_round)
            .set("cluster_id", self.cluster_id)
            .set("round", self.round)
            .set("cohort_size", self.cohort.len())
            .set("repaired", self.repaired)
            .set("mode", self.mode.as_str())
            .set("updates", self.updates.len())
            .set(
                "void_reason",
                self.void_reason
                    .as_deref()
                    .map(Json::from)
                    .unwrap_or(Json::Null),
            );
        // echo the negotiated seams once the round's record carries them
        if let Some(rec) = &self.record {
            if let Some(s) = rec.get("server_opt").and_then(Json::as_str) {
                o = o.set("server_opt", s);
            }
            if let Some(s) = rec.get("local_strategy").and_then(Json::as_str) {
                o = o.set("local_strategy", s);
            }
        }
        o
    }
}

// ====================================================================
// ε-ledger charges
// ====================================================================

/// One DP ε-ledger charge, persisted in the same log as the rounds that
/// caused it.
///
/// The accountant charges once per federated round *index* (the max
/// sampling rate across clusters training that index), so the dedup key
/// is `(clustering_round, round)` — replaying the WAL can never
/// double-charge a round, and a crash between "round closed" and
/// "charge appended" is healed by re-deriving the charge from the closed
/// round on recovery.
#[derive(Debug, Clone)]
pub struct LedgerCharge {
    /// Outer clustering-iteration index.
    pub clustering_round: usize,
    /// Federated round index charged.
    pub round: usize,
    /// Sampling rate charged (max across clusters for this index).
    pub q: f64,
    /// Noise multiplier the accountant ran with at charge time.
    pub noise_multiplier: f64,
}

impl LedgerCharge {
    /// Dedup key: one charge per federated round index.
    pub fn key(&self) -> (usize, usize) {
        (self.clustering_round, self.round)
    }

    /// Serialize to the WAL JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("clustering_round", self.clustering_round)
            .set("round", self.round)
            .set("q", self.q)
            .set("noise_multiplier", self.noise_multiplier)
    }

    /// Parse the WAL JSON form back.
    pub fn from_json(j: &Json) -> Result<LedgerCharge> {
        Ok(LedgerCharge {
            clustering_round: need_usize(j, "clustering_round")?,
            round: need_usize(j, "round")?,
            q: j.get("q").and_then(Json::as_f64).unwrap_or(0.0),
            noise_multiplier: j
                .get("noise_multiplier")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
        })
    }
}

// ====================================================================
// the store trait
// ====================================================================

/// What a store reopen found — surfaced through `GET /rounds/recovery`
/// and the `feddart rounds` CLI.
#[derive(Debug, Clone, Default)]
pub struct RecoveryStatus {
    /// WAL event/charge/meta records replayed on open.
    pub events_replayed: usize,
    /// Rounds materialized (snapshot + WAL).
    pub rounds_loaded: usize,
    /// Rounds that were non-terminal at open time.
    pub in_flight: usize,
    /// WAL records discarded from a corrupt tail (0 = clean log).
    pub corrupt_tail_events: usize,
    /// Whether a compacted snapshot was loaded before WAL replay.
    pub snapshot_loaded: bool,
}

impl RecoveryStatus {
    /// Serialize for the REST recovery endpoint.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("events_replayed", self.events_replayed)
            .set("rounds_loaded", self.rounds_loaded)
            .set("in_flight", self.in_flight)
            .set("corrupt_tail_events", self.corrupt_tail_events)
            .set("snapshot_loaded", self.snapshot_loaded)
    }
}

/// Durable (or not) home of all round state and the ε-ledger.
///
/// Every mutation is an event append validated by [`transition`]; the
/// store is the single source of truth the coordinator resumes from
/// after a crash.  Implementations must be safe to share across the
/// FACT server's cluster worker threads.
pub trait RoundStore: Send + Sync {
    /// Validate and apply one event; persist it; return the new phase.
    fn append(&self, ev: RoundEvent) -> Result<RoundPhase>;

    /// Persist one ε-ledger charge (idempotent on [`LedgerCharge::key`]).
    fn append_charge(&self, charge: LedgerCharge) -> Result<()>;

    /// All persisted charges, in append order (deduped by key).
    fn charges(&self) -> Result<Vec<LedgerCharge>>;

    /// Look up one round by id.
    fn round(&self, round_id: u64) -> Result<Option<RoundState>>;

    /// All known rounds, in first-seen order.
    fn rounds(&self) -> Result<Vec<RoundState>>;

    /// The session tag persisted in the store, if any.
    fn session_tag(&self) -> Result<Option<u64>>;

    /// Adopt-or-persist a session tag: if the store already holds one
    /// (a previous coordinator run), the stored tag wins and is
    /// returned — fresh rounds after a resume then derive the same
    /// round ids the dead coordinator would have.
    fn set_session_tag(&self, tag: u64) -> Result<u64>;

    /// Fold the log into a compacted snapshot and truncate it.
    fn compact(&self) -> Result<()>;

    /// What the last open replayed (all-zero for a fresh store).
    fn recovery(&self) -> RecoveryStatus;

    /// Directory where trace dumps (`trace.jsonl`) should live, for
    /// durable stores — `None` for in-memory backends, the WAL directory
    /// for [`WalRoundStore`].  The FACT server dumps each closed round's
    /// flight-recorder trace there and replays it on `recover()`.
    fn trace_dir(&self) -> Option<std::path::PathBuf> {
        None
    }

    /// Rounds that are still in flight (non-terminal).
    fn in_flight(&self) -> Result<Vec<RoundState>> {
        Ok(self
            .rounds()?
            .into_iter()
            .filter(|r| !r.phase.is_terminal())
            .collect())
    }

    /// Round listing for `GET /rounds`: summaries plus recovery status.
    fn status_json(&self) -> Result<Json> {
        let rounds = self.rounds()?;
        let in_flight = rounds.iter().filter(|r| !r.phase.is_terminal()).count();
        Ok(Json::obj()
            .set("attached", true)
            .set("total", rounds.len())
            .set("in_flight", in_flight)
            .set(
                "rounds",
                Json::Arr(rounds.iter().map(RoundState::summary_json).collect()),
            )
            .set("recovery", self.recovery().to_json()))
    }

    /// Paginated round listing for `GET /rounds?offset=&limit=`: one
    /// page of summaries in first-seen order, with `total`/`offset`/
    /// `limit` echoed so clients can walk a long-lived store without
    /// pulling every round on each poll.  Counters (`total`,
    /// `in_flight`) always describe the WHOLE store, not the page.
    fn status_json_page(&self, offset: usize, limit: usize) -> Result<Json> {
        let rounds = self.rounds()?;
        let in_flight = rounds.iter().filter(|r| !r.phase.is_terminal()).count();
        let page: Vec<Json> = rounds
            .iter()
            .skip(offset)
            .take(limit)
            .map(RoundState::summary_json)
            .collect();
        Ok(Json::obj()
            .set("attached", true)
            .set("total", rounds.len())
            .set("in_flight", in_flight)
            .set("offset", offset)
            .set("limit", limit)
            .set("rounds", Json::Arr(page))
            .set("recovery", self.recovery().to_json()))
    }
}

// ====================================================================
// shared fold (both backends)
// ====================================================================

#[derive(Default)]
struct StoreInner {
    order: Vec<u64>,
    states: BTreeMap<u64, RoundState>,
    charges: Vec<LedgerCharge>,
    session_tag: Option<u64>,
}

impl StoreInner {
    /// Validate + fold one event.  Validation happens before any
    /// mutation, so a rejected event leaves the fold untouched.
    fn apply_event(&mut self, ev: &RoundEvent) -> Result<RoundPhase> {
        let cur = self.states.get(&ev.round_id).map(|s| s.phase);
        let next = transition(cur, &ev.kind)?;
        if !self.states.contains_key(&ev.round_id) {
            self.order.push(ev.round_id);
        }
        self.states
            .entry(ev.round_id)
            .or_insert_with(|| RoundState::new(ev.round_id))
            .absorb(ev, next);
        Ok(next)
    }

    fn apply_charge(&mut self, charge: LedgerCharge) {
        if !self.charges.iter().any(|c| c.key() == charge.key()) {
            self.charges.push(charge);
        }
    }

    fn rounds(&self) -> Vec<RoundState> {
        self.order
            .iter()
            .filter_map(|id| self.states.get(id))
            .cloned()
            .collect()
    }

    fn snapshot_json(&self) -> Json {
        Json::obj()
            .set(
                "session_tag",
                self.session_tag
                    .map(|t| Json::Str(round_id_to_hex(t)))
                    .unwrap_or(Json::Null),
            )
            .set(
                "charges",
                Json::Arr(self.charges.iter().map(LedgerCharge::to_json).collect()),
            )
            .set(
                "rounds",
                Json::Arr(self.rounds().iter().map(RoundState::to_json).collect()),
            )
    }

    fn load_snapshot_json(&mut self, j: &Json) -> Result<()> {
        if let Some(tag) = j.get("session_tag").and_then(Json::as_str) {
            self.session_tag = Some(round_id_from_hex(tag)?);
        }
        for c in j.get("charges").and_then(Json::as_arr).unwrap_or(&[]) {
            self.apply_charge(LedgerCharge::from_json(c)?);
        }
        for r in j.get("rounds").and_then(Json::as_arr).unwrap_or(&[]) {
            let state = RoundState::from_json(r)?;
            if !self.states.contains_key(&state.round_id) {
                self.order.push(state.round_id);
            }
            self.states.insert(state.round_id, state);
        }
        Ok(())
    }
}

// ====================================================================
// in-memory backend
// ====================================================================

/// The non-durable [`RoundStore`]: the same fold as the WAL backend,
/// held in process memory.  This is the default backend — every round
/// runs through the state machine whether or not durability was asked
/// for, so the transition table is exercised by every test in the tree.
#[derive(Default)]
pub struct MemRoundStore {
    inner: Mutex<StoreInner>,
}

impl MemRoundStore {
    /// Fresh empty store.
    pub fn new() -> MemRoundStore {
        MemRoundStore::default()
    }
}

impl RoundStore for MemRoundStore {
    fn append(&self, ev: RoundEvent) -> Result<RoundPhase> {
        self.inner.lock().unwrap().apply_event(&ev)
    }

    fn append_charge(&self, charge: LedgerCharge) -> Result<()> {
        self.inner.lock().unwrap().apply_charge(charge);
        Ok(())
    }

    fn charges(&self) -> Result<Vec<LedgerCharge>> {
        Ok(self.inner.lock().unwrap().charges.clone())
    }

    fn round(&self, round_id: u64) -> Result<Option<RoundState>> {
        Ok(self.inner.lock().unwrap().states.get(&round_id).cloned())
    }

    fn rounds(&self) -> Result<Vec<RoundState>> {
        Ok(self.inner.lock().unwrap().rounds())
    }

    fn session_tag(&self) -> Result<Option<u64>> {
        Ok(self.inner.lock().unwrap().session_tag)
    }

    fn set_session_tag(&self, tag: u64) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        match inner.session_tag {
            Some(t) => Ok(t),
            None => {
                inner.session_tag = Some(tag);
                Ok(tag)
            }
        }
    }

    fn compact(&self) -> Result<()> {
        Ok(())
    }

    fn recovery(&self) -> RecoveryStatus {
        RecoveryStatus::default()
    }
}

// ====================================================================
// WAL backend
// ====================================================================

/// One parsed WAL record.
enum WalRecord {
    Event(RoundEvent),
    Charge(LedgerCharge),
    Meta(u64),
}

impl WalRecord {
    fn to_json(&self) -> Json {
        match self {
            WalRecord::Event(ev) => Json::obj().set("event", ev.to_json()),
            WalRecord::Charge(c) => Json::obj().set("charge", c.to_json()),
            WalRecord::Meta(tag) => Json::obj().set(
                "meta",
                Json::obj().set("session_tag", round_id_to_hex(*tag).as_str()),
            ),
        }
    }

    fn from_json(j: &Json) -> Result<WalRecord> {
        if let Some(ev) = j.get("event") {
            return Ok(WalRecord::Event(RoundEvent::from_json(ev)?));
        }
        if let Some(c) = j.get("charge") {
            return Ok(WalRecord::Charge(LedgerCharge::from_json(c)?));
        }
        if let Some(m) = j.get("meta") {
            return Ok(WalRecord::Meta(need_hex_u64(m, "session_tag")?));
        }
        Err(FedError::Json("unknown WAL record shape".into()))
    }
}

/// Frame one serialized payload as a WAL line: `FDW1 <8-hex-crc> <json>\n`.
fn frame_line(payload: &str) -> String {
    format!("{WAL_MAGIC} {:08x} {payload}\n", crc32(payload.as_bytes()))
}

/// Unframe one WAL line; returns the verified JSON payload.
fn unframe_line(line: &str) -> Result<&str> {
    let rest = line
        .strip_prefix(WAL_MAGIC)
        .and_then(|r| r.strip_prefix(' '))
        .ok_or_else(|| FedError::Json("WAL line missing FDW1 magic".into()))?;
    let (crc_hex, payload) = rest
        .split_once(' ')
        .ok_or_else(|| FedError::Json("WAL line missing crc field".into()))?;
    let want = u32::from_str_radix(crc_hex, 16)
        .map_err(|_| FedError::Json("WAL line has malformed crc".into()))?;
    let got = crc32(payload.as_bytes());
    if want != got {
        return Err(FedError::Json(format!(
            "WAL line crc mismatch (want {want:08x}, got {got:08x})"
        )));
    }
    Ok(payload)
}

struct WalInner {
    mem: StoreInner,
    file: fs::File,
    appends_since_compact: usize,
    recovery: RecoveryStatus,
}

/// The durable [`RoundStore`]: a directory holding
///
/// * `wal.jsonl` — one CRC-framed JSON record per line, appended on
///   every transition, fsynced on phase boundaries (and always for
///   `LearnClosed`, charges and metadata — the records recovery cannot
///   re-derive);
/// * `snapshot.json` — a CRC-framed compaction of everything before the
///   current WAL, rewritten atomically (`snapshot.tmp` + rename) every
///   [`COMPACT_EVERY`] appends or on [`RoundStore::compact`].
///
/// Reopening replays snapshot + WAL.  A line that fails its CRC or does
/// not parse marks the *corrupt tail*: it and everything after it are
/// counted, the file is truncated back to the last good line, and every
/// round still in flight is marked [`RoundState::tainted`] — the
/// coordinator then voids tainted rounds under its `RevealPolicy`
/// rather than resuming from a log whose tail is missing.
///
/// One store directory belongs to one coordinator process at a time;
/// concurrent writers are not detected.
pub struct WalRoundStore {
    dir: PathBuf,
    inner: Mutex<WalInner>,
}

impl WalRoundStore {
    /// Open (or create) a store directory, replaying any existing
    /// snapshot + WAL.  A corrupt *snapshot* is a hard error — it is
    /// rewritten atomically, so corruption means operator intervention;
    /// a corrupt WAL *tail* is expected crash damage and is handled as
    /// described on the type.
    pub fn open(dir: impl AsRef<Path>) -> Result<WalRoundStore> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)?;
        let mut mem = StoreInner::default();
        let mut recovery = RecoveryStatus::default();

        let snap_path = dir.join("snapshot.json");
        if snap_path.exists() {
            let text = fs::read_to_string(&snap_path)?;
            let payload = text
                .strip_prefix(SNAP_MAGIC)
                .and_then(|r| r.strip_prefix(' '))
                .ok_or_else(|| {
                    FedError::Json(format!(
                        "{}: missing {SNAP_MAGIC} magic",
                        snap_path.display()
                    ))
                })?;
            let (crc_hex, body) = payload.split_once(' ').ok_or_else(|| {
                FedError::Json(format!("{}: missing crc field", snap_path.display()))
            })?;
            let want = u32::from_str_radix(crc_hex, 16).map_err(|_| {
                FedError::Json(format!("{}: malformed crc", snap_path.display()))
            })?;
            if want != crc32(body.as_bytes()) {
                return Err(FedError::Json(format!(
                    "{}: snapshot crc mismatch — refusing to open a \
                     corrupt round store snapshot",
                    snap_path.display()
                )));
            }
            mem.load_snapshot_json(&Json::parse(body)?)?;
            recovery.snapshot_loaded = true;
        }

        let wal_path = dir.join("wal.jsonl");
        let mut good_bytes: u64 = 0;
        let mut corrupt_tail = 0usize;
        if wal_path.exists() {
            let text = fs::read_to_string(&wal_path)?;
            let mut offset = 0usize;
            let mut lines = Vec::new();
            // split keeping byte offsets so the tail truncation point is
            // exact even if the final line has no newline
            for line in text.split_inclusive('\n') {
                lines.push((offset, line));
                offset += line.len();
            }
            for (i, (start, raw)) in lines.iter().enumerate() {
                let line = raw.trim_end_matches('\n');
                if line.is_empty() {
                    good_bytes = (*start + raw.len()) as u64;
                    continue;
                }
                let applied = unframe_line(line)
                    .and_then(|payload| WalRecord::from_json(&Json::parse(payload)?))
                    .and_then(|rec| {
                        match rec {
                            WalRecord::Event(ev) => {
                                mem.apply_event(&ev)?;
                            }
                            WalRecord::Charge(c) => mem.apply_charge(c),
                            WalRecord::Meta(tag) => {
                                if mem.session_tag.is_none() {
                                    mem.session_tag = Some(tag);
                                }
                            }
                        }
                        Ok(())
                    });
                match applied {
                    Ok(()) => {
                        // a line not terminated by '\n' replayed fine but a
                        // concurrent append could interleave with it; still
                        // count it good — append() always writes whole lines
                        good_bytes = (*start + raw.len()) as u64;
                        recovery.events_replayed += 1;
                    }
                    Err(e) => {
                        corrupt_tail = lines.len() - i;
                        log::warn!(target: "coordinator::round_store",
                            "{}: corrupt WAL tail at byte {start} ({e}) — \
                             truncating {corrupt_tail} record(s), tainting \
                             in-flight rounds",
                            wal_path.display());
                        break;
                    }
                }
            }
            if corrupt_tail > 0 {
                // drop the unreadable tail so the next append starts from
                // a clean frame boundary...
                let f = fs::OpenOptions::new().write(true).open(&wal_path)?;
                f.set_len(good_bytes)?;
                f.sync_data()?;
                // ...and poison every round the missing records may have
                // belonged to
                for s in mem.states.values_mut() {
                    if !s.phase.is_terminal() {
                        s.tainted = true;
                    }
                }
            }
        }
        recovery.corrupt_tail_events = corrupt_tail;
        recovery.rounds_loaded = mem.states.len();
        recovery.in_flight = mem
            .states
            .values()
            .filter(|s| !s.phase.is_terminal())
            .count();

        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&wal_path)?;
        Ok(WalRoundStore {
            dir,
            inner: Mutex::new(WalInner {
                mem,
                file,
                appends_since_compact: 0,
                recovery,
            }),
        })
    }

    /// The store directory this WAL lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn write_record(
        &self,
        inner: &mut WalInner,
        rec: &WalRecord,
        sync: bool,
    ) -> Result<()> {
        let line = frame_line(&rec.to_json().to_string());
        inner.file.write_all(line.as_bytes())?;
        inner.file.flush()?;
        if sync {
            inner.file.sync_data()?;
        }
        inner.appends_since_compact += 1;
        if inner.appends_since_compact >= COMPACT_EVERY {
            self.compact_locked(inner)?;
        }
        Ok(())
    }

    fn compact_locked(&self, inner: &mut WalInner) -> Result<()> {
        let body = inner.mem.snapshot_json().to_string();
        let framed = format!("{SNAP_MAGIC} {:08x} {body}", crc32(body.as_bytes()));
        let tmp = self.dir.join("snapshot.tmp");
        let snap = self.dir.join("snapshot.json");
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(framed.as_bytes())?;
            f.sync_data()?;
        }
        fs::rename(&tmp, &snap)?;
        // make the rename + truncation durable on platforms where the
        // directory entry needs its own sync; best-effort elsewhere
        if let Ok(d) = fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        let wal_path = self.dir.join("wal.jsonl");
        fs::File::create(&wal_path)?; // truncate: everything is in the snapshot now
        inner.file = fs::OpenOptions::new().append(true).open(&wal_path)?;
        inner.appends_since_compact = 0;
        Ok(())
    }
}

impl RoundStore for WalRoundStore {
    fn append(&self, ev: RoundEvent) -> Result<RoundPhase> {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.mem.states.get(&ev.round_id).map(|s| s.phase);
        let next = inner.mem.apply_event(&ev)?;
        // fsync at phase boundaries; LearnClosed keeps Learn -> Learn but
        // carries the collected updates — the one payload recovery cannot
        // re-derive from the clients — so it syncs too
        let sync =
            before != Some(next) || matches!(ev.kind, EventKind::LearnClosed { .. });
        self.write_record(&mut inner, &WalRecord::Event(ev), sync)?;
        Ok(next)
    }

    fn append_charge(&self, charge: LedgerCharge) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.mem.apply_charge(charge.clone());
        self.write_record(&mut inner, &WalRecord::Charge(charge), true)
    }

    fn charges(&self) -> Result<Vec<LedgerCharge>> {
        Ok(self.inner.lock().unwrap().mem.charges.clone())
    }

    fn round(&self, round_id: u64) -> Result<Option<RoundState>> {
        Ok(self
            .inner
            .lock()
            .unwrap()
            .mem
            .states
            .get(&round_id)
            .cloned())
    }

    fn rounds(&self) -> Result<Vec<RoundState>> {
        Ok(self.inner.lock().unwrap().mem.rounds())
    }

    fn session_tag(&self) -> Result<Option<u64>> {
        Ok(self.inner.lock().unwrap().mem.session_tag)
    }

    fn set_session_tag(&self, tag: u64) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(t) = inner.mem.session_tag {
            return Ok(t);
        }
        inner.mem.session_tag = Some(tag);
        self.write_record(&mut inner, &WalRecord::Meta(tag), true)?;
        Ok(tag)
    }

    fn compact(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        self.compact_locked(&mut inner)
    }

    fn recovery(&self) -> RecoveryStatus {
        self.inner.lock().unwrap().recovery.clone()
    }

    fn trace_dir(&self) -> Option<PathBuf> {
        Some(self.dir.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "feddart_round_store_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn tb(vals: &[f32]) -> TensorBuf {
        TensorBuf::from_f32_slice(vals)
    }

    fn configured(round_id: u64) -> RoundEvent {
        RoundEvent::new(
            round_id,
            EventKind::Configured {
                clustering_round: 0,
                cluster_id: 1,
                round: 2,
                cohort: vec!["a".into(), "b".into(), "c".into()],
                sample_rate: 0.75,
                mode: "secagg+dp".into(),
                params: tb(&[1.0, 2.0, 3.0]),
                deadline_ms: 500,
                session_tag: 0xdead_beef_dead_beef,
            },
        )
    }

    fn repaired(round_id: u64) -> RoundEvent {
        RoundEvent::new(
            round_id,
            EventKind::CohortRepaired {
                presumed_dead: vec!["c".into()],
                replacements: vec!["d".into()],
                cohort: vec!["a".into(), "b".into(), "c".into(), "d".into()],
                sample_rate: 0.9,
            },
        )
    }

    fn keys(round_id: u64) -> RoundEvent {
        let mut pk = BTreeMap::new();
        pk.insert("a".to_string(), "aa11".to_string());
        pk.insert("b".to_string(), "bb22".to_string());
        pk.insert("c".to_string(), "cc33".to_string());
        RoundEvent::new(
            round_id,
            EventKind::KeysCollected {
                pubkeys: pk,
                threshold: 2,
            },
        )
    }

    fn shares(round_id: u64) -> RoundEvent {
        let mut enc = BTreeMap::new();
        let mut inner = BTreeMap::new();
        inner.insert("b".to_string(), "cafe".to_string());
        enc.insert("a".to_string(), inner.clone());
        let mut commits = BTreeMap::new();
        commits.insert("a".to_string(), inner);
        RoundEvent::new(
            round_id,
            EventKind::SharesDealt {
                participants: vec!["a".into(), "b".into(), "c".into()],
                enc_shares: enc,
                commits,
            },
        )
    }

    fn dispatched(round_id: u64) -> RoundEvent {
        RoundEvent::new(
            round_id,
            EventKind::LearnDispatched {
                addressed: vec!["a".into(), "b".into(), "c".into()],
                dispatched_at_ms: 1_000,
                deadline_ms: 500,
            },
        )
    }

    fn learn_closed(round_id: u64) -> RoundEvent {
        RoundEvent::new(
            round_id,
            EventKind::LearnClosed {
                updates: vec![StoredUpdate {
                    device: "a".into(),
                    params: tb(&[0.5, 0.5, 0.5]),
                    n_samples: 10.0,
                    loss: 0.25,
                    duration: 1.5,
                    tau: 4.0,
                }],
                late: 1,
                dropped: vec!["c".into()],
            },
        )
    }

    fn revealed(round_id: u64) -> RoundEvent {
        RoundEvent::new(
            round_id,
            EventKind::Revealed {
                audit: Json::obj().set("outcome", "recovered"),
            },
        )
    }

    fn aggregated(round_id: u64) -> RoundEvent {
        RoundEvent::new(
            round_id,
            EventKind::Aggregated {
                params: tb(&[1.5, 2.5, 3.5]),
                record: Json::obj().set("mean_loss", 0.25),
                opt_state: Json::obj().set("step", 1.0),
            },
        )
    }

    fn full_round(store: &dyn RoundStore, round_id: u64) {
        store.append(configured(round_id)).unwrap();
        store.append(keys(round_id)).unwrap();
        store.append(shares(round_id)).unwrap();
        store.append(dispatched(round_id)).unwrap();
        store.append(learn_closed(round_id)).unwrap();
        store.append(revealed(round_id)).unwrap();
        store.append(aggregated(round_id)).unwrap();
        store.append(RoundEvent::new(round_id, EventKind::Closed)).unwrap();
    }

    #[test]
    fn transition_table_legal_and_illegal() {
        use RoundPhase as P;
        // the canonical full path
        assert_eq!(
            transition(None, &configured(1).kind).unwrap(),
            P::Configured
        );
        assert_eq!(
            transition(Some(P::Configured), &keys(1).kind).unwrap(),
            P::Keys
        );
        assert_eq!(transition(Some(P::Keys), &shares(1).kind).unwrap(), P::Shares);
        assert_eq!(
            transition(Some(P::Shares), &dispatched(1).kind).unwrap(),
            P::Learn
        );
        assert_eq!(
            transition(Some(P::Learn), &learn_closed(1).kind).unwrap(),
            P::Learn
        );
        assert_eq!(
            transition(Some(P::Learn), &revealed(1).kind).unwrap(),
            P::Reveal
        );
        assert_eq!(
            transition(Some(P::Reveal), &aggregated(1).kind).unwrap(),
            P::Aggregated
        );
        assert_eq!(
            transition(Some(P::Aggregated), &EventKind::Closed).unwrap(),
            P::Closed
        );
        // skip edges
        assert_eq!(
            transition(Some(P::Configured), &dispatched(1).kind).unwrap(),
            P::Learn
        );
        assert_eq!(
            transition(Some(P::Keys), &dispatched(1).kind).unwrap(),
            P::Learn
        );
        assert_eq!(
            transition(Some(P::Learn), &aggregated(1).kind).unwrap(),
            P::Aggregated
        );
        // in-round repair: stays in phase, legal only before share dealing
        assert_eq!(
            transition(Some(P::Configured), &repaired(1).kind).unwrap(),
            P::Configured
        );
        assert_eq!(
            transition(Some(P::Keys), &repaired(1).kind).unwrap(),
            P::Keys
        );
        assert!(transition(Some(P::Shares), &repaired(1).kind).is_err());
        assert!(transition(Some(P::Learn), &repaired(1).kind).is_err());
        assert!(transition(Some(P::Closed), &repaired(1).kind).is_err());
        assert!(transition(None, &repaired(1).kind).is_err());
        // recovery re-entry edges
        assert_eq!(transition(Some(P::Keys), &keys(1).kind).unwrap(), P::Keys);
        assert_eq!(
            transition(Some(P::Shares), &shares(1).kind).unwrap(),
            P::Shares
        );
        assert_eq!(
            transition(Some(P::Learn), &dispatched(1).kind).unwrap(),
            P::Learn
        );
        assert_eq!(
            transition(Some(P::Reveal), &revealed(1).kind).unwrap(),
            P::Reveal
        );
        // abandonment from any non-terminal phase
        for p in [P::Configured, P::Keys, P::Shares, P::Learn, P::Reveal, P::Aggregated]
        {
            assert_eq!(
                transition(
                    Some(p),
                    &EventKind::Voided {
                        reason: "x".into(),
                        record: Json::Null
                    }
                )
                .unwrap(),
                P::Voided
            );
        }
        // illegal sequences
        assert!(transition(None, &keys(1).kind).is_err());
        assert!(transition(Some(P::Configured), &configured(1).kind).is_err());
        assert!(transition(Some(P::Configured), &shares(1).kind).is_err());
        assert!(transition(Some(P::Configured), &revealed(1).kind).is_err());
        assert!(transition(Some(P::Closed), &dispatched(1).kind).is_err());
        assert!(transition(
            Some(P::Closed),
            &EventKind::Voided {
                reason: "x".into(),
                record: Json::Null
            }
        )
        .is_err());
        assert!(transition(Some(P::Voided), &EventKind::Closed).is_err());
    }

    #[test]
    fn event_json_round_trip() {
        for ev in [
            configured(42),
            repaired(42),
            keys(42),
            shares(42),
            dispatched(42),
            learn_closed(42),
            revealed(42),
            aggregated(42),
            RoundEvent::new(42, EventKind::Closed),
            RoundEvent::new(
                42,
                EventKind::Voided {
                    reason: "deadline elapsed".into(),
                    record: Json::obj().set("mean_loss", 0.0),
                },
            ),
        ] {
            let j = ev.to_json();
            let text = j.to_string();
            let back = RoundEvent::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.round_id, ev.round_id);
            assert_eq!(back.kind.tag(), ev.kind.tag());
            // round-trips stay equal through a second cycle
            assert_eq!(back.to_json().to_string(), text);
        }
    }

    #[test]
    fn round_state_json_round_trip() {
        let store = MemRoundStore::new();
        let big = u64::MAX - 5; // above 2^53: hex encoding must hold it
        store.append(configured(big)).unwrap();
        store.append(keys(big)).unwrap();
        store.append(shares(big)).unwrap();
        store.append(dispatched(big)).unwrap();
        store.append(learn_closed(big)).unwrap();
        let state = store.round(big).unwrap().unwrap();
        let text = state.to_json().to_string();
        let back = RoundState::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.round_id, big);
        assert_eq!(back.phase, RoundPhase::Learn);
        assert_eq!(back.session_tag, 0xdead_beef_dead_beef);
        assert_eq!(back.cohort, state.cohort);
        assert_eq!(back.updates.len(), 1);
        assert_eq!(back.updates[0].params.as_f32_slice(), &[0.5, 0.5, 0.5]);
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn repaired_cohort_replaces_the_draw_in_state_and_replay() {
        let store = MemRoundStore::new();
        store.append(configured(9)).unwrap();
        store.append(repaired(9)).unwrap();
        let s = store.round(9).unwrap().unwrap();
        assert_eq!(s.phase, RoundPhase::Configured);
        assert_eq!(s.cohort, vec!["a", "b", "c", "d"]);
        assert!((s.sample_rate - 0.9).abs() < 1e-12, "conservative q folded");
        assert_eq!(s.repaired, 1);
        // the repaired state survives a JSON round trip (WAL replay form)
        let back =
            RoundState::from_json(&Json::parse(&s.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back.cohort, s.cohort);
        assert_eq!(back.repaired, 1);
        // and the round proceeds through the normal machine afterwards
        store.append(keys(9)).unwrap();
        store.append(shares(9)).unwrap();
        // ...but repair after share dealing is rejected by the machine
        assert!(store.append(repaired(9)).is_err());
    }

    #[test]
    fn terminal_rounds_trim_bulk_but_keep_outcome() {
        let store = MemRoundStore::new();
        full_round(&store, 7);
        let s = store.round(7).unwrap().unwrap();
        assert_eq!(s.phase, RoundPhase::Closed);
        assert!(s.params.is_none());
        assert!(s.updates.is_empty());
        assert!(s.enc_shares.is_empty());
        assert_eq!(
            s.params_after.as_ref().unwrap().as_f32_slice(),
            &[1.5, 2.5, 3.5]
        );
        assert!(s.record.is_some());
        // optimizer state rides with params_after through the trim
        assert!(s.opt_state.is_some());
    }

    #[test]
    fn status_json_page_slices_and_echoes_totals() {
        let store = MemRoundStore::new();
        for id in 1..=5u64 {
            store.append(configured(id)).unwrap();
        }
        full_round(&store, 6);
        let j = store.status_json_page(2, 2).unwrap();
        assert_eq!(j.get("total").and_then(Json::as_usize), Some(6));
        assert_eq!(j.get("in_flight").and_then(Json::as_usize), Some(5));
        assert_eq!(j.get("offset").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("limit").and_then(Json::as_usize), Some(2));
        let page = j.get("rounds").and_then(Json::as_arr).unwrap();
        assert_eq!(page.len(), 2);
        // first-seen order: the page starting at 2 holds rounds 3 and 4
        assert_eq!(
            page[0].get("round_id").and_then(Json::as_str),
            Some(round_id_to_hex(3).as_str())
        );
        // an offset past the end yields an empty page, not an error
        let tail = store.status_json_page(100, 10).unwrap();
        assert_eq!(
            tail.get("rounds").and_then(Json::as_arr).map(Vec::len),
            Some(0)
        );
        assert_eq!(tail.get("total").and_then(Json::as_usize), Some(6));
    }

    #[test]
    fn mem_and_wal_agree() {
        let dir = tmp_dir("agree");
        let mem = MemRoundStore::new();
        let wal = WalRoundStore::open(&dir).unwrap();
        for store in [&mem as &dyn RoundStore, &wal as &dyn RoundStore] {
            full_round(store, 11);
            store.append(configured(12)).unwrap();
            store.append(keys(12)).unwrap();
            store
                .append_charge(LedgerCharge {
                    clustering_round: 0,
                    round: 2,
                    q: 0.75,
                    noise_multiplier: 1.1,
                })
                .unwrap();
        }
        let a = mem.rounds().unwrap();
        let b = wal.rounds().unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_json().to_string(), y.to_json().to_string());
        }
        assert_eq!(mem.charges().unwrap().len(), wal.charges().unwrap().len());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_replay_restores_everything() {
        let dir = tmp_dir("replay");
        {
            let wal = WalRoundStore::open(&dir).unwrap();
            assert_eq!(wal.set_session_tag(99).unwrap(), 99);
            full_round(&wal, 21);
            wal.append(configured(22)).unwrap();
            wal.append(keys(22)).unwrap();
            wal.append(shares(22)).unwrap();
            wal.append_charge(LedgerCharge {
                clustering_round: 0,
                round: 2,
                q: 0.75,
                noise_multiplier: 1.1,
            })
            .unwrap();
            // dropped without compaction: pure WAL replay
        }
        let wal = WalRoundStore::open(&dir).unwrap();
        let rec = wal.recovery();
        assert!(rec.events_replayed > 0);
        assert_eq!(rec.corrupt_tail_events, 0);
        assert_eq!(rec.rounds_loaded, 2);
        assert_eq!(rec.in_flight, 1);
        assert!(!rec.snapshot_loaded);
        assert_eq!(wal.session_tag().unwrap(), Some(99));
        // an existing tag wins over the caller's
        assert_eq!(wal.set_session_tag(123).unwrap(), 99);
        let closed = wal.round(21).unwrap().unwrap();
        assert_eq!(closed.phase, RoundPhase::Closed);
        assert_eq!(
            closed.params_after.as_ref().unwrap().as_f32_slice(),
            &[1.5, 2.5, 3.5]
        );
        let open_round = wal.round(22).unwrap().unwrap();
        assert_eq!(open_round.phase, RoundPhase::Shares);
        assert!(!open_round.tainted);
        assert_eq!(
            open_round.params.as_ref().unwrap().as_f32_slice(),
            &[1.0, 2.0, 3.0]
        );
        assert_eq!(open_round.enc_shares["a"]["b"], "cafe");
        let charges = wal.charges().unwrap();
        assert_eq!(charges.len(), 1);
        assert_eq!(charges[0].key(), (0, 2));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_tail_truncated_and_tainted() {
        let dir = tmp_dir("corrupt");
        {
            let wal = WalRoundStore::open(&dir).unwrap();
            full_round(&wal, 31);
            wal.append(configured(32)).unwrap();
            wal.append(keys(32)).unwrap();
        }
        // simulate a crash mid-write: garbage where the next frame began
        let wal_path = dir.join("wal.jsonl");
        let mut f = fs::OpenOptions::new().append(true).open(&wal_path).unwrap();
        f.write_all(b"FDW1 00000000 {\"event\": garbage\nFDW1 trailing\n")
            .unwrap();
        drop(f);
        let before_len = fs::metadata(&wal_path).unwrap().len();

        let wal = WalRoundStore::open(&dir).unwrap();
        let rec = wal.recovery();
        assert_eq!(rec.corrupt_tail_events, 2);
        assert_eq!(rec.rounds_loaded, 2);
        // the closed round is untouched; the in-flight one is poisoned
        assert!(!wal.round(31).unwrap().unwrap().tainted);
        assert!(wal.round(32).unwrap().unwrap().tainted);
        assert_eq!(wal.round(32).unwrap().unwrap().phase, RoundPhase::Keys);
        // the unreadable tail was physically dropped
        assert!(fs::metadata(&wal_path).unwrap().len() < before_len);
        // and appends continue cleanly after truncation
        wal.append(shares(32)).unwrap();
        let wal2 = WalRoundStore::open(&dir).unwrap();
        assert_eq!(wal2.recovery().corrupt_tail_events, 0);
        assert_eq!(wal2.round(32).unwrap().unwrap().phase, RoundPhase::Shares);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_refuses_to_open() {
        let dir = tmp_dir("badsnap");
        {
            let wal = WalRoundStore::open(&dir).unwrap();
            full_round(&wal, 41);
            wal.compact().unwrap();
        }
        let snap = dir.join("snapshot.json");
        let mut text = fs::read_to_string(&snap).unwrap();
        text.truncate(text.len() - 4); // chop the tail: crc must fail
        fs::write(&snap, text).unwrap();
        assert!(WalRoundStore::open(&dir).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_then_reopen() {
        let dir = tmp_dir("compact");
        {
            let wal = WalRoundStore::open(&dir).unwrap();
            wal.set_session_tag(7).unwrap();
            full_round(&wal, 51);
            wal.append(configured(52)).unwrap();
            wal.append_charge(LedgerCharge {
                clustering_round: 0,
                round: 2,
                q: 0.5,
                noise_multiplier: 0.9,
            })
            .unwrap();
            wal.compact().unwrap();
            // WAL is empty after compaction; new appends land in it
            assert_eq!(
                fs::metadata(dir.join("wal.jsonl")).unwrap().len(),
                0
            );
            wal.append(keys(52)).unwrap();
        }
        let wal = WalRoundStore::open(&dir).unwrap();
        let rec = wal.recovery();
        assert!(rec.snapshot_loaded);
        assert_eq!(rec.rounds_loaded, 2);
        assert_eq!(wal.session_tag().unwrap(), Some(7));
        assert_eq!(wal.round(51).unwrap().unwrap().phase, RoundPhase::Closed);
        assert_eq!(wal.round(52).unwrap().unwrap().phase, RoundPhase::Keys);
        assert_eq!(wal.charges().unwrap().len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn charges_dedup_on_key() {
        let store = MemRoundStore::new();
        for _ in 0..3 {
            store
                .append_charge(LedgerCharge {
                    clustering_round: 1,
                    round: 4,
                    q: 0.5,
                    noise_multiplier: 1.0,
                })
                .unwrap();
        }
        assert_eq!(store.charges().unwrap().len(), 1);
    }

    #[test]
    fn status_json_lists_rounds() {
        let store = MemRoundStore::new();
        full_round(&store, 61);
        store.append(configured(62)).unwrap();
        let j = store.status_json().unwrap();
        assert_eq!(j.get("attached").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("total").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("in_flight").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("rounds").and_then(Json::as_arr).unwrap().len(), 2);
    }
}
