//! The Fed-DART coordination library (the paper's Python-library layer,
//! natively in Rust).
//!
//! * [`workflow::WorkflowManager`] — the user entry point (§A.1).
//! * [`selector::Selector`] — accept/reject, init-task scheduling, task
//!   queue, aggregator management (§A.2, non-ephemeral).
//! * [`aggregator::Aggregator`] — per-task tree of result collectors with
//!   the parallel weighted reduction (§A.2, ephemeral).
//! * [`device`] — `DeviceSingle` / `DeviceHolder` caches (§A.2).
//! * [`task`] — task representation + the `check` function (§A.2).
//! * [`participation`] — deterministic cohort sampling for
//!   partial-participation rounds (uniform / weighted / sticky-stratified).
//! * [`latency`] — per-client learn-latency tracking behind adaptive
//!   round deadlines.
//! * [`round_store`] — the explicit round state machine and its durable
//!   (WAL-backed) / in-memory persistence backends.

pub mod aggregator;
pub mod device;
pub mod latency;
pub mod participation;
pub mod round_store;
pub mod selector;
pub mod task;
pub mod workflow;

pub use aggregator::{flat_reduce_weighted, parallel_reduce_weighted, tree_reduce_weighted, Aggregator};
pub use device::{DeviceHolder, DeviceSingle};
pub use latency::{effective_deadline, LatencyTracker};
pub use participation::{participation_round_key, Candidate, CohortSampler};
pub use round_store::{
    transition, EventKind, LedgerCharge, MemRoundStore, RecoveryStatus, RoundEvent,
    RoundPhase, RoundState, RoundStore, StoredUpdate, WalRoundStore,
};
pub use selector::{InitTask, Selector, WfTaskStatus};
pub use task::{Task, TaskHandle, TaskKind};
pub use workflow::{QuorumOutcome, RoundClose, WorkflowManager};
