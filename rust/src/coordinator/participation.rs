//! Cohort sampling for partial-participation rounds.
//!
//! Cross-device FL at production scale cannot address every alive client
//! each round: a round over-provisions a sampled cohort of ⌈q·N⌉ clients
//! and closes on a K-of-N quorum/deadline instead of waiting for
//! stragglers (Nguyen et al., *FL for IIoT*; Zhang et al., *EdgeFL*).
//! [`CohortSampler`] is the deterministic draw behind that: seeded per
//! round from [`crate::util::rng`], so a (seed, clustering round,
//! cluster, round) tuple always reproduces the same cohort — the property
//! the participation integration tests and the DP accountant both rely
//! on.
//!
//! Three strategies (see [`SamplingStrategy`]): uniform (the only one
//! that earns DP amplification-by-subsampling), weighted-by-samples
//! (Efraimidis–Spirakis keys over last-known client sample counts), and
//! sticky-stratified (hash strata, session-stable priorities — stable
//! cohorts for warm-client locality).

use crate::config::{ParticipationConfig, SamplingStrategy};
use crate::util::rng::{fnv1a, splitmix64, Rng};

/// One pool member offered to the sampler.  `weight` is the last-known
/// sample count (1.0 when unknown); only [`SamplingStrategy::WeightedBySamples`]
/// reads it.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Client name (unique within the pool).
    pub name: String,
    /// Last-known sample count, 1.0 when unknown.
    pub weight: f64,
}

impl Candidate {
    /// A candidate with unit weight.
    pub fn uniform(name: &str) -> Candidate {
        Candidate { name: name.to_string(), weight: 1.0 }
    }
}

/// Deterministic per-round draw key: every field shifts a disjoint bit
/// range so distinct (clustering round, cluster, round) tuples never
/// collide before the splitmix avalanche.
pub fn participation_round_key(
    seed: u64,
    clustering_round: usize,
    cluster_id: usize,
    round: usize,
) -> u64 {
    splitmix64(
        seed ^ ((clustering_round as u64) << 42)
            ^ ((cluster_id as u64) << 21)
            ^ round as u64,
    )
}

fn name_hash(name: &str) -> u64 {
    splitmix64(fnv1a(name))
}

/// The cohort sampler: pure function of (config, round key, pool).
#[derive(Debug, Clone)]
pub struct CohortSampler {
    cfg: ParticipationConfig,
}

impl CohortSampler {
    /// Wrap a participation config in a sampler.
    pub fn new(cfg: ParticipationConfig) -> CohortSampler {
        CohortSampler { cfg }
    }

    /// The participation config the sampler draws with.
    pub fn config(&self) -> &ParticipationConfig {
        &self.cfg
    }

    /// Target cohort size for a pool of `n`: ⌈q·n⌉, floored by
    /// `min_cohort`, capped at the pool.
    pub fn target(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        let t = (self.cfg.sample_rate * n as f64).ceil() as usize;
        t.max(self.cfg.min_cohort).max(1).min(n)
    }

    /// Dispatch size: the target inflated by `over_provision`, capped at
    /// the pool.
    pub fn dispatch_size(&self, n: usize) -> usize {
        let t = self.target(n);
        (((t as f64) * self.cfg.over_provision).ceil() as usize).clamp(t, n)
    }

    /// Reports needed before the round may close early: ⌈quorum·cohort⌉.
    pub fn quorum_count(&self, cohort: usize) -> usize {
        if cohort == 0 {
            return 0;
        }
        ((self.cfg.quorum * cohort as f64).ceil() as usize).clamp(1, cohort)
    }

    /// The sampling rate the DP accountant may claim for a cohort drawn
    /// from a pool of `n`: the configured inclusion probability for
    /// Poisson draws (the quantity the RDP bound is stated in — NOT the
    /// realized cohort fraction), the realized q for fixed-size uniform
    /// draws (standard approximation, see [`SamplingStrategy`]), and 1.0
    /// (no amplification) for the data-dependent / sticky strategies.
    pub fn amplification_rate(&self, cohort: usize, n: usize) -> f64 {
        match self.cfg.strategy {
            SamplingStrategy::Poisson => {
                let q = self.cfg.sample_rate.clamp(0.0, 1.0);
                if n > 0 {
                    // the empty-draw fallback (see `sample`) force-includes
                    // one uniformly chosen client with probability
                    // (1-q)^n, raising each client's true inclusion
                    // probability — charge the corrected rate, not the
                    // configured one, or small pools under-report ε
                    (q + (1.0 - q).powi(n as i32) / n as f64).min(1.0)
                } else {
                    q
                }
            }
            SamplingStrategy::Uniform if n > 0 => {
                (cohort as f64 / n as f64).min(1.0)
            }
            _ => 1.0,
        }
    }

    /// Draw this round's dispatch cohort.  Deterministic in
    /// (config, `round_key`, pool contents) and independent of the
    /// caller's pool ordering.
    pub fn sample(&self, round_key: u64, pool: &[Candidate]) -> Vec<String> {
        let mut pool: Vec<&Candidate> = pool.iter().collect();
        pool.sort_by(|a, b| a.name.cmp(&b.name));
        pool.dedup_by(|a, b| a.name == b.name);
        let n = pool.len();
        if n == 0 {
            return Vec::new();
        }
        if self.cfg.strategy == SamplingStrategy::Poisson {
            // independent per-client inclusion at exactly `sample_rate` —
            // the sampled Gaussian mechanism the accountant's bound is
            // proved for.  One uniform draw per (sorted) candidate keeps
            // the result deterministic and pool-order-independent.
            let q = self.cfg.sample_rate.clamp(0.0, 1.0);
            let mut rng = Rng::new(round_key);
            let mut picked: Vec<String> = pool
                .iter()
                .filter(|_| rng.uniform() < q)
                .map(|c| c.name.clone())
                .collect();
            if picked.is_empty() {
                // probability (1-q)^n — fall back to one client rather
                // than abort the round; `amplification_rate` charges the
                // correspondingly raised inclusion probability
                picked.push(pool[rng.below(n)].name.clone());
            }
            return picked;
        }
        let k = self.dispatch_size(n);
        if k >= n {
            return pool.into_iter().map(|c| c.name.clone()).collect();
        }
        match self.cfg.strategy {
            // handled by the early return above
            SamplingStrategy::Poisson => unreachable!("poisson draws early-return"),
            SamplingStrategy::Uniform => {
                // partial Fisher-Yates: the first k slots of a seeded
                // shuffle are a uniform k-subset
                let mut rng = Rng::new(round_key);
                let mut idx: Vec<usize> = (0..n).collect();
                for i in 0..k {
                    let j = i + rng.below(n - i);
                    idx.swap(i, j);
                }
                idx[..k].iter().map(|&i| pool[i].name.clone()).collect()
            }
            SamplingStrategy::WeightedBySamples => {
                // Efraimidis–Spirakis: key_i = u_i^(1/w_i); the top-k keys
                // are a weighted-without-replacement sample
                let mut rng = Rng::new(round_key);
                let mut keyed: Vec<(f64, usize)> = pool
                    .iter()
                    .enumerate()
                    .map(|(i, c)| {
                        let u = rng.uniform().max(1e-300);
                        (u.powf(1.0 / c.weight.max(1e-9)), i)
                    })
                    .collect();
                keyed.sort_by(|a, b| {
                    b.0.partial_cmp(&a.0)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then_with(|| pool[a.1].name.cmp(&pool[b.1].name))
                });
                let mut picked: Vec<String> =
                    keyed[..k].iter().map(|&(_, i)| pool[i].name.clone()).collect();
                picked.sort();
                picked
            }
            SamplingStrategy::StickyStratified { strata } => {
                // hash into strata; inside each stratum order by a
                // session-stable priority (seed, not round key), then take
                // slots round-robin across strata — the cohort is stable
                // from round to round ("sticky") yet spread across strata
                let s = strata.max(1);
                let mut buckets: Vec<Vec<&Candidate>> = vec![Vec::new(); s];
                for c in &pool {
                    buckets[(name_hash(&c.name) % s as u64) as usize].push(*c);
                }
                for b in buckets.iter_mut() {
                    // cached: sort_by_key may re-evaluate (hash + String
                    // clone) per comparison
                    b.sort_by_cached_key(|c| {
                        (splitmix64(self.cfg.seed ^ name_hash(&c.name)), c.name.clone())
                    });
                }
                let mut picked = Vec::with_capacity(k);
                let mut cursor = vec![0usize; s];
                'outer: loop {
                    let mut advanced = false;
                    for (b, cur) in buckets.iter().zip(cursor.iter_mut()) {
                        if let Some(c) = b.get(*cur) {
                            *cur += 1;
                            advanced = true;
                            picked.push(c.name.clone());
                            if picked.len() == k {
                                break 'outer;
                            }
                        }
                    }
                    if !advanced {
                        break;
                    }
                }
                picked.sort();
                picked
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(rate: f64, strategy: SamplingStrategy) -> ParticipationConfig {
        ParticipationConfig {
            sample_rate: rate,
            strategy,
            seed: 42,
            ..Default::default()
        }
    }

    fn pool(n: usize) -> Vec<Candidate> {
        (0..n).map(|i| Candidate::uniform(&format!("client-{i}"))).collect()
    }

    #[test]
    fn sizes_target_dispatch_quorum() {
        let s = CohortSampler::new(ParticipationConfig {
            sample_rate: 0.25,
            over_provision: 1.5,
            quorum: 0.75,
            min_cohort: 2,
            ..Default::default()
        });
        assert_eq!(s.target(16), 4);
        assert_eq!(s.dispatch_size(16), 6); // ceil(4 * 1.5)
        assert_eq!(s.quorum_count(6), 5); // ceil(0.75 * 6)
        // min_cohort floors, pool caps
        assert_eq!(s.target(4), 2);
        assert_eq!(s.target(1), 1);
        assert_eq!(s.target(0), 0);
        assert_eq!(s.dispatch_size(4), 3);
        assert_eq!(s.quorum_count(0), 0);
    }

    #[test]
    fn uniform_deterministic_and_round_varying() {
        let s = CohortSampler::new(cfg(0.5, SamplingStrategy::Uniform));
        let p = pool(12);
        let a = s.sample(participation_round_key(42, 0, 0, 0), &p);
        let b = s.sample(participation_round_key(42, 0, 0, 0), &p);
        assert_eq!(a, b, "same key must reproduce the cohort");
        assert_eq!(a.len(), 6);
        // pool order must not matter
        let mut rev = p.clone();
        rev.reverse();
        assert_eq!(s.sample(participation_round_key(42, 0, 0, 0), &rev), a);
        // different rounds draw different cohorts (with overwhelming prob.)
        let later: Vec<Vec<String>> = (1..6)
            .map(|r| s.sample(participation_round_key(42, 0, 0, r), &p))
            .collect();
        assert!(later.iter().any(|c| *c != a), "cohort never rotated");
    }

    #[test]
    fn uniform_coverage_is_roughly_q() {
        // every client should be sampled ~q of the time over many rounds
        let s = CohortSampler::new(cfg(0.5, SamplingStrategy::Uniform));
        let p = pool(12);
        let rounds = 400;
        let mut hits = std::collections::BTreeMap::<String, usize>::new();
        for r in 0..rounds {
            for name in s.sample(participation_round_key(7, 0, 0, r), &p) {
                *hits.entry(name).or_default() += 1;
            }
        }
        for (name, h) in hits {
            assert!(
                (120..=280).contains(&h),
                "client {name} sampled {h}/{rounds} times at q=0.5"
            );
        }
    }

    #[test]
    fn weighted_prefers_heavy_clients() {
        let mut p = pool(10);
        p[0].weight = 50.0; // client-0 carries 50x the samples
        let s = CohortSampler::new(cfg(0.3, SamplingStrategy::WeightedBySamples));
        let rounds = 200;
        let mut heavy = 0;
        let mut light = 0;
        for r in 0..rounds {
            let c = s.sample(participation_round_key(3, 0, 0, r), &p);
            assert_eq!(c.len(), 3);
            if c.iter().any(|n| n == "client-0") {
                heavy += 1;
            }
            if c.iter().any(|n| n == "client-1") {
                light += 1;
            }
        }
        assert!(
            heavy > 2 * light,
            "heavy client sampled {heavy}, light {light}"
        );
    }

    #[test]
    fn sticky_stratified_is_stable_across_rounds() {
        let s = CohortSampler::new(cfg(
            0.5,
            SamplingStrategy::StickyStratified { strata: 3 },
        ));
        let p = pool(12);
        let first = s.sample(participation_round_key(42, 0, 0, 0), &p);
        assert_eq!(first.len(), 6);
        for r in 1..10 {
            assert_eq!(
                s.sample(participation_round_key(42, 0, 0, r), &p),
                first,
                "sticky cohort drifted at round {r}"
            );
        }
        // a different session seed picks a different cohort
        let other = CohortSampler::new(ParticipationConfig {
            seed: 43,
            ..cfg(0.5, SamplingStrategy::StickyStratified { strata: 3 })
        });
        assert_ne!(other.sample(participation_round_key(43, 0, 0, 0), &p), first);
    }

    #[test]
    fn poisson_draws_independently_at_rate_q() {
        let s = CohortSampler::new(cfg(0.25, SamplingStrategy::Poisson));
        let p = pool(16);
        let a = s.sample(participation_round_key(5, 0, 0, 0), &p);
        let b = s.sample(participation_round_key(5, 0, 0, 0), &p);
        assert_eq!(a, b, "same key must reproduce the draw");
        assert!(!a.is_empty(), "empty-draw fallback must fire");
        // mean cohort size over many rounds ≈ q·n = 4
        let rounds = 500;
        let total: usize = (0..rounds)
            .map(|r| s.sample(participation_round_key(5, 0, 0, r), &p).len())
            .sum();
        let mean = total as f64 / rounds as f64;
        assert!(
            (3.0..=5.0).contains(&mean),
            "poisson mean cohort {mean}, expected ~4"
        );
        // accountant claims the inclusion probability corrected for the
        // empty-draw fallback: q + (1-q)^n / n
        let expect = 0.25 + 0.75f64.powi(16) / 16.0;
        assert!((s.amplification_rate(7, 16) - expect).abs() < 1e-12);
        assert!(s.amplification_rate(7, 16) > 0.25);
    }

    #[test]
    fn amplification_only_for_uniform() {
        let u = CohortSampler::new(cfg(0.25, SamplingStrategy::Uniform));
        assert!((u.amplification_rate(4, 16) - 0.25).abs() < 1e-12);
        let w = CohortSampler::new(cfg(0.25, SamplingStrategy::WeightedBySamples));
        assert_eq!(w.amplification_rate(4, 16), 1.0);
        let st = CohortSampler::new(cfg(
            0.25,
            SamplingStrategy::StickyStratified { strata: 2 },
        ));
        assert_eq!(st.amplification_rate(4, 16), 1.0);
    }

    #[test]
    fn full_rate_returns_whole_pool() {
        let s = CohortSampler::new(cfg(1.0, SamplingStrategy::Uniform));
        let p = pool(5);
        let c = s.sample(participation_round_key(1, 0, 0, 0), &p);
        assert_eq!(c.len(), 5);
    }
}
