//! Selector — the central orchestration instance of the Fed-DART library
//! (paper §A.2).
//!
//! "Selector has knowledge about the connected clients and is responsible
//! for accepting or rejecting incoming task requests from the
//! WorkflowManager. It schedules the initTask to new clients. If a task
//! request is accepted, the task is put into a queue until the DART-Server
//! has capacity to schedule a new task. After scheduling a task, [it]
//! creates an Aggregator and hands over the DeviceSingles to them. It
//! manages all existing Aggregators."

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::aggregator::{Aggregator, DEFAULT_FANOUT};
use crate::coordinator::device::{DeviceHolder, DeviceSingle};
use crate::coordinator::task::{Task, TaskHandle, TaskKind};
use crate::dart::scheduler::{TaskResult, TaskStatus};
use crate::dart::DartApi;
use crate::error::{FedError, Result};
use crate::json::Json;

/// Coordinator-level task status (adds `Queued` over the backend enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WfTaskStatus {
    Queued,
    InProgress,
    Finished,
    PartiallyFailed,
    Stopped,
}

impl From<TaskStatus> for WfTaskStatus {
    fn from(s: TaskStatus) -> Self {
        match s {
            TaskStatus::InProgress => WfTaskStatus::InProgress,
            TaskStatus::Finished => WfTaskStatus::Finished,
            TaskStatus::PartiallyFailed => WfTaskStatus::PartiallyFailed,
            TaskStatus::Stopped => WfTaskStatus::Stopped,
        }
    }
}

/// The template for the init task (function + shared parameters); scheduled
/// to every client before any other task runs on it (Alg. 1).
#[derive(Debug, Clone)]
pub struct InitTask {
    pub execute_function: String,
    pub shared_params: Json,
}

enum Slot {
    /// accepted but not yet dispatched to the backend
    Queued(Task),
    /// dispatched
    Running(Arc<Aggregator>),
    /// cancelled before dispatch
    StoppedBeforeDispatch,
}

pub struct Selector {
    api: Arc<dyn DartApi>,
    devices: Mutex<DeviceHolder>,
    slots: Mutex<BTreeMap<TaskHandle, Slot>>,
    queue: Mutex<VecDeque<TaskHandle>>,
    init_task: Mutex<Option<InitTask>>,
    next_handle: AtomicU64,
    /// settled backend statuses — settled tasks are never re-queried, so a
    /// poll costs O(active tasks) instead of O(all tasks ever submitted)
    /// (§Perf: this was the dominant REST-path overhead after ~10 rounds)
    terminal: Mutex<BTreeMap<TaskHandle, WfTaskStatus>>,
    /// serializes initTask scheduling: concurrent submits must not both
    /// observe a device as uninitialized and double-run init (Alg 1)
    init_lock: Mutex<()>,
    /// aggregators ever created (dispatch successes), for observability
    aggregators_created: AtomicU64,
    /// backend capacity: max tasks dispatched concurrently
    max_concurrent: usize,
    fanout: usize,
}

impl Selector {
    pub fn new(api: Arc<dyn DartApi>) -> Selector {
        Selector {
            api,
            devices: Mutex::new(DeviceHolder::default()),
            slots: Mutex::new(BTreeMap::new()),
            queue: Mutex::new(VecDeque::new()),
            init_task: Mutex::new(None),
            next_handle: AtomicU64::new(1),
            terminal: Mutex::new(BTreeMap::new()),
            init_lock: Mutex::new(()),
            aggregators_created: AtomicU64::new(0),
            max_concurrent: 16,
            fanout: DEFAULT_FANOUT,
        }
    }

    pub fn with_capacity(mut self, max_concurrent: usize) -> Selector {
        self.max_concurrent = max_concurrent.max(1);
        self
    }

    pub fn with_fanout(mut self, fanout: usize) -> Selector {
        self.fanout = fanout.max(2);
        self
    }

    pub fn api(&self) -> &Arc<dyn DartApi> {
        &self.api
    }

    /// Configure the init task (Alg. 1 step 3).
    pub fn set_init_task(&self, init: InitTask) {
        *self.init_task.lock().unwrap() = Some(init);
    }

    /// Refresh the device view from the backend.  New devices get a
    /// DeviceSingle; vanished devices are marked dead (their cached state
    /// is retained — the paper's DeviceSingle caches survive reconnects).
    pub fn refresh_devices(&self) -> Result<DeviceHolder> {
        let infos = self.api.devices()?;
        let mut holder = self.devices.lock().unwrap();
        let mut devices: Vec<Arc<DeviceSingle>> = holder.devices().to_vec();
        for info in &infos {
            match devices.iter().find(|d| d.name == info.name) {
                Some(d) => d.set_alive(info.alive),
                None => {
                    devices.push(DeviceSingle::new(&info.name, info.hardware.clone()))
                }
            }
        }
        // devices the backend no longer reports are dead
        for d in &devices {
            if !infos.iter().any(|i| i.name == d.name) {
                d.set_alive(false);
            }
        }
        *holder = DeviceHolder::new(devices);
        Ok(holder.clone())
    }

    /// Names of alive, known devices.
    pub fn device_names(&self) -> Result<Vec<String>> {
        Ok(self
            .refresh_devices()?
            .devices()
            .iter()
            .filter(|d| d.is_alive())
            .map(|d| d.name.clone())
            .collect())
    }

    /// Accept (or reject) a task request.  Accepted tasks get a handle
    /// immediately; dispatch happens now if the backend has capacity,
    /// otherwise the task waits in the queue (pumped on every poll).
    pub fn submit(&self, task: Task) -> Result<TaskHandle> {
        let devices = self.refresh_devices()?;
        task.check(&devices)?; // accept/reject decision
        let handle = TaskHandle(self.next_handle.fetch_add(1, Ordering::Relaxed));
        self.slots.lock().unwrap().insert(handle, Slot::Queued(task));
        self.queue.lock().unwrap().push_back(handle);
        self.pump()?;
        Ok(handle)
    }

    /// Backend status with a terminal-status cache.
    fn backend_status(&self, handle: TaskHandle, agg: &Aggregator) -> Result<WfTaskStatus> {
        if let Some(st) = self.terminal.lock().unwrap().get(&handle) {
            return Ok(*st);
        }
        let st: WfTaskStatus = agg.status(self.api.as_ref())?.into();
        if st != WfTaskStatus::InProgress {
            self.terminal.lock().unwrap().insert(handle, st);
        }
        Ok(st)
    }

    /// Dispatch queued tasks while the backend has capacity.
    pub fn pump(&self) -> Result<()> {
        loop {
            // nothing queued — skip the running-count probe entirely (it
            // costs one backend status RPC per in-flight task, and pump
            // runs on every poll of every quorum loop)
            if self.queue.lock().unwrap().is_empty() {
                return Ok(());
            }
            // count running (settled tasks resolve from the cache)
            let running = {
                let entries: Vec<(TaskHandle, Arc<Aggregator>)> = {
                    let slots = self.slots.lock().unwrap();
                    slots
                        .iter()
                        .filter_map(|(h, s)| match s {
                            Slot::Running(a) => Some((*h, Arc::clone(a))),
                            _ => None,
                        })
                        .collect()
                };
                entries
                    .into_iter()
                    .filter(|(h, a)| {
                        self.backend_status(*h, a)
                            .map(|st| st == WfTaskStatus::InProgress)
                            .unwrap_or(false)
                    })
                    .count()
            };
            if running >= self.max_concurrent {
                return Ok(());
            }
            let Some(handle) = self.queue.lock().unwrap().pop_front() else {
                return Ok(());
            };
            let task = {
                let slots = self.slots.lock().unwrap();
                match slots.get(&handle) {
                    Some(Slot::Queued(t)) => t.clone(),
                    _ => continue, // stopped before dispatch
                }
            };
            match self.dispatch(handle, task) {
                Ok(agg) => {
                    self.aggregators_created.fetch_add(1, Ordering::Relaxed);
                    self.slots.lock().unwrap().insert(handle, Slot::Running(agg));
                }
                Err(e) => {
                    // A dispatch failure is THAT task's failure, not the
                    // pumping caller's: propagating it here failed a
                    // freshly *accepted* submit whenever an unrelated
                    // queued task could not dispatch.  The failed handle
                    // surfaces `Stopped` on poll; keep pumping the queue.
                    log::error!(target: "coordinator::selector",
                        "dispatch of {handle} failed: {e}");
                    self.slots
                        .lock()
                        .unwrap()
                        .insert(handle, Slot::StoppedBeforeDispatch);
                    continue;
                }
            }
        }
    }

    fn dispatch(&self, handle: TaskHandle, task: Task) -> Result<Arc<Aggregator>> {
        // Alg 1 guarantee: init runs on each addressed client first.
        if task.kind == TaskKind::Default {
            self.ensure_initialized(&task.client_names())?;
        }
        let id = self.api.submit(task.to_spec())?;
        let devices = {
            let holder = self.devices.lock().unwrap();
            let subset: Vec<Arc<DeviceSingle>> = task
                .client_names()
                .iter()
                .filter_map(|n| holder.get(n).cloned())
                .collect();
            DeviceHolder::new(subset)
        };
        Ok(Arc::new(Aggregator::new(handle, task, id, devices, self.fanout)))
    }

    /// Run the init task on every addressed client that has not been
    /// initialized yet, waiting for completion (bounded).
    pub fn ensure_initialized(&self, clients: &[String]) -> Result<()> {
        let init = self.init_task.lock().unwrap().clone();
        let Some(init) = init else { return Ok(()) };
        // Fast path: initialized flags are only ever set AFTER an init
        // task finished, so observing every addressed client initialized
        // is proof there is nothing to schedule — return without touching
        // the init lock.  Otherwise a submit for long-initialized clients
        // would convoy behind an unrelated in-flight init for up to the
        // full bounded wait.
        {
            let holder = self.devices.lock().unwrap();
            let all_done = clients.iter().all(|c| {
                holder.get(c).map(|d| d.is_initialized()).unwrap_or(true)
            });
            if all_done {
                return Ok(());
            }
        }
        // Serialize init scheduling end to end: without this, two
        // concurrent submits both read `!is_initialized()` and schedule
        // the initTask twice to the same clients, violating Alg. 1's
        // "init exactly once".  The second comer blocks here until the
        // first init completes, then re-reads the updated flags and
        // finds nothing pending.
        let _init_guard = self.init_lock.lock().unwrap();
        let pending: Vec<String> = {
            let holder = self.devices.lock().unwrap();
            clients
                .iter()
                .filter(|c| {
                    holder.get(c).map(|d| !d.is_initialized()).unwrap_or(false)
                })
                .cloned()
                .collect()
        };
        if pending.is_empty() {
            return Ok(());
        }
        log::info!(target: "coordinator::selector",
            "scheduling initTask to {} new client(s)", pending.len());
        let dict: BTreeMap<String, Json> = pending
            .iter()
            .map(|c| (c.clone(), init.shared_params.clone()))
            .collect();
        let task = Task::new(TaskKind::Init, &init.execute_function, dict);
        let id = self.api.submit(task.to_spec())?;
        // bounded wait: init must complete before other tasks run (Alg 1)
        let t0 = Instant::now();
        loop {
            match self.api.status(id)? {
                TaskStatus::Finished => break,
                TaskStatus::InProgress => {
                    if t0.elapsed() > Duration::from_secs(120) {
                        return Err(FedError::Task("initTask timed out".into()));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                other => {
                    return Err(FedError::Task(format!(
                        "initTask ended with {other:?}"
                    )))
                }
            }
        }
        let holder = self.devices.lock().unwrap();
        for c in &pending {
            if let Some(d) = holder.get(c) {
                d.mark_initialized();
            }
        }
        Ok(())
    }

    /// Status of a handle (includes `Queued` before dispatch).
    pub fn status(&self, handle: TaskHandle) -> Result<WfTaskStatus> {
        self.pump().ok();
        let slots = self.slots.lock().unwrap();
        match slots.get(&handle) {
            None => Err(FedError::Task(format!("unknown handle {handle}"))),
            Some(Slot::Queued(_)) => Ok(WfTaskStatus::Queued),
            Some(Slot::StoppedBeforeDispatch) => Ok(WfTaskStatus::Stopped),
            Some(Slot::Running(agg)) => {
                let agg = Arc::clone(agg);
                drop(slots);
                self.backend_status(handle, &agg)
            }
        }
    }

    /// Results available so far (partial, non-blocking).
    pub fn results(&self, handle: TaskHandle) -> Result<Vec<TaskResult>> {
        self.pump().ok();
        let agg = {
            let slots = self.slots.lock().unwrap();
            match slots.get(&handle) {
                None => return Err(FedError::Task(format!("unknown handle {handle}"))),
                Some(Slot::Queued(_)) | Some(Slot::StoppedBeforeDispatch) => {
                    return Ok(Vec::new())
                }
                Some(Slot::Running(agg)) => Arc::clone(agg),
            }
        };
        agg.sync_results(self.api.as_ref())
    }

    /// Number of results available for a handle — the payload-free poll
    /// quorum loops use (the full `results` fetch clones every client's
    /// parameter tensors; over REST it re-downloads them).
    pub fn result_count(&self, handle: TaskHandle) -> Result<usize> {
        self.pump().ok();
        let id = {
            let slots = self.slots.lock().unwrap();
            match slots.get(&handle) {
                None => {
                    return Err(FedError::Task(format!(
                        "unknown handle {handle}"
                    )))
                }
                Some(Slot::Queued(_)) | Some(Slot::StoppedBeforeDispatch) => {
                    return Ok(0)
                }
                Some(Slot::Running(agg)) => agg.scheduler_id(),
            }
        };
        self.api.result_count(id)
    }

    /// Status + result count in one backend query (with the terminal
    /// cache): what a quorum loop polls every couple of milliseconds.
    pub fn progress(&self, handle: TaskHandle) -> Result<(WfTaskStatus, usize)> {
        self.pump().ok();
        let id = {
            let slots = self.slots.lock().unwrap();
            match slots.get(&handle) {
                None => {
                    return Err(FedError::Task(format!(
                        "unknown handle {handle}"
                    )))
                }
                Some(Slot::Queued(_)) => return Ok((WfTaskStatus::Queued, 0)),
                Some(Slot::StoppedBeforeDispatch) => {
                    return Ok((WfTaskStatus::Stopped, 0))
                }
                Some(Slot::Running(agg)) => agg.scheduler_id(),
            }
        };
        if let Some(st) = self.terminal.lock().unwrap().get(&handle).copied() {
            return Ok((st, self.api.result_count(id)?));
        }
        let (st, n) = self.api.progress(id)?;
        let wf: WfTaskStatus = st.into();
        if wf != WfTaskStatus::InProgress {
            self.terminal.lock().unwrap().insert(handle, wf);
        }
        Ok((wf, n))
    }

    /// Stop a task (queued or running).
    pub fn stop(&self, handle: TaskHandle) -> Result<()> {
        let mut slots = self.slots.lock().unwrap();
        match slots.get(&handle) {
            None => Err(FedError::Task(format!("unknown handle {handle}"))),
            Some(Slot::Queued(_)) => {
                slots.insert(handle, Slot::StoppedBeforeDispatch);
                Ok(())
            }
            Some(Slot::StoppedBeforeDispatch) => Ok(()),
            Some(Slot::Running(agg)) => agg.stop(self.api.as_ref()),
        }
    }

    /// The aggregator managing a dispatched handle (None while queued).
    pub fn aggregator(&self, handle: TaskHandle) -> Option<Arc<Aggregator>> {
        match self.slots.lock().unwrap().get(&handle) {
            Some(Slot::Running(agg)) => Some(Arc::clone(agg)),
            _ => None,
        }
    }

    /// Number of aggregators ever created (observability).  Counted at
    /// dispatch time — the slot map also holds queued and
    /// stopped-before-dispatch handles, so filtering it for `Running`
    /// undercounted whenever dispatches failed and would stop matching
    /// "ever created" the moment slots are ever pruned.
    pub fn aggregator_count(&self) -> usize {
        self.aggregators_created.load(Ordering::Relaxed) as usize
    }

    /// Sample a participation cohort from the currently alive devices
    /// (uniform candidate weights — the coordinator has no sample counts;
    /// the FACT server feeds observed per-client weights instead).
    pub fn sample_cohort(
        &self,
        sampler: &crate::coordinator::participation::CohortSampler,
        round_key: u64,
    ) -> Result<Vec<String>> {
        let pool: Vec<crate::coordinator::participation::Candidate> = self
            .device_names()?
            .iter()
            .map(|n| crate::coordinator::participation::Candidate::uniform(n))
            .collect();
        Ok(sampler.sample(round_key, &pool))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dart::testmode::TestModeDart;
    use crate::dart::TaskRegistry;

    fn registry() -> TaskRegistry {
        let reg = TaskRegistry::new();
        reg.register("init", |p| Ok(p.clone()));
        reg.register("learn", |p| {
            Ok(Json::obj().set("echo", p.clone()))
        });
        reg
    }

    fn selector(n: usize) -> (Selector, Arc<TestModeDart>) {
        let sim = Arc::new(TestModeDart::start_reliable(n, registry(), 2));
        let sel = Selector::new(sim.clone() as Arc<dyn DartApi>);
        (sel, sim)
    }

    fn dict(names: &[String]) -> BTreeMap<String, Json> {
        names.iter().map(|n| (n.clone(), Json::obj().set("w", 1))).collect()
    }

    fn wait(sel: &Selector, h: TaskHandle) -> WfTaskStatus {
        let t0 = Instant::now();
        loop {
            let st = sel.status(h).unwrap();
            if st != WfTaskStatus::InProgress && st != WfTaskStatus::Queued {
                return st;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "task stuck");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn submit_and_complete() {
        let (sel, _sim) = selector(3);
        let names = sel.device_names().unwrap();
        assert_eq!(names.len(), 3);
        let h = sel
            .submit(Task::new(TaskKind::Default, "learn", dict(&names)))
            .unwrap();
        assert_eq!(wait(&sel, h), WfTaskStatus::Finished);
        assert_eq!(sel.results(h).unwrap().len(), 3);
        assert_eq!(sel.aggregator_count(), 1);
    }

    #[test]
    fn rejects_unknown_client() {
        let (sel, _sim) = selector(2);
        let res = sel.submit(Task::new(
            TaskKind::Default,
            "learn",
            dict(&["nope".to_string()]),
        ));
        assert!(res.is_err());
    }

    #[test]
    fn init_task_runs_before_first_default_task() {
        let counter = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let order: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let reg = TaskRegistry::new();
        {
            let order = Arc::clone(&order);
            reg.register("init", move |_| {
                order.lock().unwrap().push("init".into());
                Ok(Json::Null)
            });
        }
        {
            let order = Arc::clone(&order);
            let counter = Arc::clone(&counter);
            reg.register("learn", move |_| {
                counter.fetch_add(1, Ordering::SeqCst);
                order.lock().unwrap().push("learn".into());
                Ok(Json::Null)
            });
        }
        let sim = Arc::new(TestModeDart::start_reliable(2, reg, 1));
        let sel = Selector::new(sim as Arc<dyn DartApi>);
        sel.set_init_task(InitTask {
            execute_function: "init".into(),
            shared_params: Json::obj().set("model", "mlp"),
        });
        let names = sel.device_names().unwrap();
        let h = sel
            .submit(Task::new(TaskKind::Default, "learn", dict(&names)))
            .unwrap();
        assert_eq!(wait(&sel, h), WfTaskStatus::Finished);
        let ord = order.lock().unwrap().clone();
        // both inits strictly precede all learns
        let last_init = ord.iter().rposition(|s| s == "init").unwrap();
        let first_learn = ord.iter().position(|s| s == "learn").unwrap();
        assert!(last_init < first_learn, "order was {ord:?}");

        // second task: init must NOT run again
        let before = ord.len();
        let h2 = sel
            .submit(Task::new(TaskKind::Default, "learn", dict(&names)))
            .unwrap();
        assert_eq!(wait(&sel, h2), WfTaskStatus::Finished);
        let ord2 = order.lock().unwrap().clone();
        assert_eq!(
            ord2[before..].iter().filter(|s| *s == "init").count(),
            0,
            "init re-ran: {ord2:?}"
        );
    }

    #[test]
    fn capacity_queues_tasks() {
        let (sel, _sim) = selector(2);
        let sel = sel.with_capacity(1);
        let names = sel.device_names().unwrap();
        let reg_handles: Vec<TaskHandle> = (0..3)
            .map(|_| {
                sel.submit(Task::new(TaskKind::Default, "learn", dict(&names)))
                    .unwrap()
            })
            .collect();
        // all eventually finish despite capacity 1
        for h in reg_handles {
            assert_eq!(wait(&sel, h), WfTaskStatus::Finished);
        }
    }

    #[test]
    fn stop_queued_task() {
        let (sel, _sim) = selector(1);
        let sel = sel.with_capacity(1);
        let names = sel.device_names().unwrap();
        // a slow first task would be needed to truly queue; with fast echo
        // tasks we simply verify stop on an already-finished handle is ok
        let h = sel
            .submit(Task::new(TaskKind::Default, "learn", dict(&names)))
            .unwrap();
        wait(&sel, h);
        assert!(sel.stop(h).is_ok());
        assert!(sel.status(TaskHandle(999)).is_err());
    }

    /// Backend wrapper whose `submit` fails for one function name —
    /// simulates a dispatch error for a specific queued task.
    struct FailingSubmit {
        inner: Arc<TestModeDart>,
        fail_fn: &'static str,
    }

    impl crate::dart::DartApi for FailingSubmit {
        fn devices(&self) -> Result<Vec<crate::dart::DeviceInfo>> {
            self.inner.devices()
        }
        fn submit(&self, spec: crate::dart::scheduler::TaskSpec) -> Result<u64> {
            if spec.function == self.fail_fn {
                return Err(FedError::Task("backend rejected spec".into()));
            }
            self.inner.submit(spec)
        }
        fn status(&self, id: u64) -> Result<TaskStatus> {
            self.inner.status(id)
        }
        fn results(&self, id: u64) -> Result<Vec<TaskResult>> {
            self.inner.results(id)
        }
        fn stop_task(&self, id: u64) -> Result<()> {
            self.inner.stop_task(id)
        }
    }

    /// Regression (PR 4): a queued task whose dispatch fails must not fail
    /// the unrelated submit that happened to pump the queue — the new
    /// handle is returned, the failed handle polls as `Stopped`.
    #[test]
    fn queued_dispatch_failure_does_not_fail_unrelated_submit() {
        let reg = registry();
        reg.register("sleepy", |p| {
            std::thread::sleep(Duration::from_millis(150));
            Ok(p.clone())
        });
        // "bad" never runs — the wrapped backend rejects its spec
        reg.register("bad", |p| Ok(p.clone()));
        let sim = Arc::new(TestModeDart::start_reliable(2, reg, 2));
        let api = Arc::new(FailingSubmit { inner: sim, fail_fn: "bad" });
        let sel = Selector::new(api as Arc<dyn crate::dart::DartApi>)
            .with_capacity(1);
        let names = sel.device_names().unwrap();

        // occupy the single slot so the next submit only queues
        let _slow = sel
            .submit(Task::new(TaskKind::Default, "sleepy", dict(&names)))
            .unwrap();
        let doomed = sel
            .submit(Task::new(TaskKind::Default, "bad", dict(&names)))
            .unwrap();
        // let the slow task finish WITHOUT polling (polling would pump
        // the queue early); the next submit is then the first pump that
        // sees free capacity and dispatches the doomed task
        std::thread::sleep(Duration::from_millis(400));

        // pumping for this unrelated submit dispatches (and fails) the
        // queued "bad" task; the submit itself must still succeed
        let fresh = sel
            .submit(Task::new(TaskKind::Default, "learn", dict(&names)))
            .expect("unrelated submit must not absorb the dispatch failure");
        assert_eq!(wait(&sel, fresh), WfTaskStatus::Finished);
        assert_eq!(sel.status(doomed).unwrap(), WfTaskStatus::Stopped);
    }

    /// Regression (PR 4): two concurrent submits must not both schedule
    /// the initTask to the same clients (Alg. 1 "init exactly once").
    #[test]
    fn concurrent_submits_run_init_exactly_once() {
        for attempt in 0..5 {
            let init_calls: Arc<Mutex<BTreeMap<String, usize>>> =
                Arc::new(Mutex::new(BTreeMap::new()));
            let reg = TaskRegistry::new();
            {
                let init_calls = Arc::clone(&init_calls);
                reg.register("init", move |p| {
                    let dev = p
                        .get("_device")
                        .and_then(Json::as_str)
                        .unwrap_or("?")
                        .to_string();
                    // widen the race window the serialization must close
                    std::thread::sleep(Duration::from_millis(10));
                    *init_calls.lock().unwrap().entry(dev).or_insert(0) += 1;
                    Ok(Json::Null)
                });
            }
            reg.register("learn", |p| Ok(p.clone()));
            let sim = Arc::new(TestModeDart::start_reliable(3, reg, 4));
            let sel = Arc::new(Selector::new(sim as Arc<dyn DartApi>));
            sel.set_init_task(InitTask {
                execute_function: "init".into(),
                shared_params: Json::obj().set("seed", attempt),
            });
            let names = sel.device_names().unwrap();

            let barrier = Arc::new(std::sync::Barrier::new(2));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let sel = Arc::clone(&sel);
                    let names = names.clone();
                    let barrier = Arc::clone(&barrier);
                    std::thread::spawn(move || {
                        barrier.wait();
                        let h = sel
                            .submit(Task::new(
                                TaskKind::Default,
                                "learn",
                                dict(&names),
                            ))
                            .unwrap();
                        loop {
                            let st = sel.status(h).unwrap();
                            if st != WfTaskStatus::InProgress
                                && st != WfTaskStatus::Queued
                            {
                                return st;
                            }
                            std::thread::sleep(Duration::from_millis(2));
                        }
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), WfTaskStatus::Finished);
            }
            let calls = init_calls.lock().unwrap();
            for name in &names {
                assert_eq!(
                    calls.get(name).copied().unwrap_or(0),
                    1,
                    "attempt {attempt}: init ran {:?} times on {name}",
                    calls.get(name)
                );
            }
        }
    }

    #[test]
    fn aggregator_count_is_ever_created_not_currently_running() {
        let (sel, _sim) = selector(2);
        let names = sel.device_names().unwrap();
        for _ in 0..3 {
            let h = sel
                .submit(Task::new(TaskKind::Default, "learn", dict(&names)))
                .unwrap();
            assert_eq!(wait(&sel, h), WfTaskStatus::Finished);
        }
        // all three settled long ago — the count still reports 3
        assert_eq!(sel.aggregator_count(), 3);
    }

    #[test]
    fn sample_cohort_draws_from_alive_devices() {
        use crate::config::ParticipationConfig;
        use crate::coordinator::participation::{
            participation_round_key, CohortSampler,
        };
        let (sel, sim) = selector(8);
        let sampler = CohortSampler::new(ParticipationConfig {
            sample_rate: 0.5,
            ..Default::default()
        });
        let cohort = sel
            .sample_cohort(&sampler, participation_round_key(1, 0, 0, 0))
            .unwrap();
        assert_eq!(cohort.len(), 4);
        // dead devices never enter the pool
        sim.scheduler().remove_worker("client-0");
        for r in 0..20 {
            let c = sel
                .sample_cohort(&sampler, participation_round_key(1, 0, 0, r))
                .unwrap();
            assert!(
                !c.contains(&"client-0".to_string()),
                "dead device sampled in round {r}"
            );
        }
    }

    #[test]
    fn device_view_tracks_liveness() {
        let (sel, sim) = selector(2);
        assert_eq!(sel.device_names().unwrap().len(), 2);
        sim.scheduler().remove_worker("client-0");
        let names = sel.device_names().unwrap();
        assert_eq!(names, vec!["client-1".to_string()]);
        // rejoin
        sim.scheduler().add_worker(
            "client-0",
            crate::config::HardwareConfig::default(),
            1,
        );
        assert_eq!(sel.device_names().unwrap().len(), 2);
    }
}
