//! WorkflowManager — the user-facing entry point of the Fed-DART library
//! (paper §A.1, Figure A.8: createInitTask, startFedDART,
//! getAllDeviceNames, startTask, getTaskStatus, getTaskResult, stopTask).
//!
//! The same manager drives both backends — the in-process test mode and the
//! production REST path — which is the paper's "seamless transition from
//! rapid, local prototyping to deployment in a production environment".

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{DeviceConfig, ServerConfig};
use crate::coordinator::selector::{InitTask, Selector, WfTaskStatus};
use crate::coordinator::task::{Task, TaskHandle, TaskKind};
use crate::dart::rest::RestDartApi;
use crate::dart::scheduler::TaskResult;
use crate::dart::testmode::{SimClient, TestModeDart};
use crate::dart::{DartApi, TaskRegistry};
use crate::error::{FedError, Result};
use crate::json::Json;

/// Why a quorum round stopped collecting results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundClose {
    /// Every addressed client reported.
    Complete,
    /// The quorum was reached before the deadline; stragglers dropped.
    Quorum,
    /// The deadline fired below quorum; whatever arrived is the report set.
    Deadline,
    /// The backend settled (failures) below quorum before the deadline.
    Settled,
}

impl RoundClose {
    /// Lowercase label for metrics and telemetry events.
    pub fn as_str(&self) -> &'static str {
        match self {
            RoundClose::Complete => "complete",
            RoundClose::Quorum => "quorum",
            RoundClose::Deadline => "deadline",
            RoundClose::Settled => "settled",
        }
    }
}

/// Outcome of [`WorkflowManager::run_task_quorum`].
#[derive(Debug)]
pub struct QuorumOutcome {
    /// Results that arrived before the round closed (the aggregate set).
    pub results: Vec<TaskResult>,
    pub close: RoundClose,
    /// Devices whose results arrived *after* the close (observed during
    /// the late-grace sweep) — counted, then discarded.
    pub late: Vec<String>,
    /// Wall-clock milliseconds from dispatch to close (grace sweep
    /// excluded) — the censored latency lower bound for non-reporters,
    /// fed into the adaptive-deadline latency tracker.
    pub elapsed_ms: u64,
}

/// The WorkflowManager.
pub struct WorkflowManager {
    selector: Selector,
    test_mode: bool,
    /// kept alive for the lifetime of a test-mode manager
    _sim: Option<Arc<TestModeDart>>,
}

impl WorkflowManager {
    // ------------------------------------------------------------ builders

    /// Test mode with `n` reliable simulated clients (paper §3).
    /// `parallelism = 1` matches the paper's sequential dummy server.
    pub fn test_mode(n: usize, registry: TaskRegistry, parallelism: usize) -> Self {
        let sim = Arc::new(TestModeDart::start_reliable(n, registry, parallelism));
        WorkflowManager {
            selector: Selector::new(sim.clone() as Arc<dyn DartApi>),
            test_mode: true,
            _sim: Some(sim),
        }
    }

    /// Test mode with an explicit per-client capacity and poll batch size —
    /// the batched-dispatch analogue of [`WorkflowManager::test_mode`].
    pub fn test_mode_batched(
        n: usize,
        registry: TaskRegistry,
        parallelism: usize,
        capacity: usize,
        batch: usize,
    ) -> Self {
        let clients = (0..n)
            .map(|i| SimClient::reliable(&format!("client-{i}")).with_capacity(capacity))
            .collect();
        let sim = Arc::new(TestModeDart::start_with_batch(
            clients,
            registry,
            parallelism,
            batch,
        ));
        WorkflowManager {
            selector: Selector::new(sim.clone() as Arc<dyn DartApi>),
            test_mode: true,
            _sim: Some(sim),
        }
    }

    /// Test mode with explicit simulated clients (fault profiles, hardware).
    pub fn test_mode_with(
        clients: Vec<SimClient>,
        registry: TaskRegistry,
        parallelism: usize,
    ) -> Self {
        let sim = Arc::new(TestModeDart::start(clients, registry, parallelism));
        WorkflowManager {
            selector: Selector::new(sim.clone() as Arc<dyn DartApi>),
            test_mode: true,
            _sim: Some(sim),
        }
    }

    /// Test mode from device config entries (paper Listing 3 — in test
    /// mode addresses are dummies; names and hardware are used).
    pub fn test_mode_from_devices(
        devices: &[DeviceConfig],
        registry: TaskRegistry,
        parallelism: usize,
    ) -> Self {
        let clients = devices
            .iter()
            .map(|d| SimClient {
                name: d.name.clone(),
                hardware: d.hardware.clone(),
                faults: crate::dart::faults::FaultInjector::none(),
                capacity: 1,
            })
            .collect();
        Self::test_mode_with(clients, registry, parallelism)
    }

    /// Production mode: connect to a running DART-server through the
    /// REST-API (paper Listing 2 server config).
    pub fn production(cfg: &ServerConfig) -> Result<Self> {
        let api = RestDartApi::connect(cfg);
        if !api.health().unwrap_or(false) {
            return Err(FedError::Config(format!(
                "DART-server at {} is not healthy",
                cfg.server
            )));
        }
        Ok(WorkflowManager {
            selector: Selector::new(Arc::new(api) as Arc<dyn DartApi>),
            test_mode: false,
            _sim: None,
        })
    }

    /// Bring-your-own backend (tests / custom deployments).
    pub fn with_backend(api: Arc<dyn DartApi>) -> Self {
        WorkflowManager { selector: Selector::new(api), test_mode: false, _sim: None }
    }

    pub fn is_test_mode(&self) -> bool {
        self.test_mode
    }

    pub fn selector(&self) -> &Selector {
        &self.selector
    }

    // ------------------------------------------------------- paper methods

    /// `createInitTask` (Alg 1): register the function every client must
    /// run before other tasks.  "Typically the model structure is passed
    /// via the parameter Dict."
    pub fn create_init_task(&self, shared_params: Json, execute_function: &str) {
        self.selector.set_init_task(InitTask {
            execute_function: execute_function.to_string(),
            shared_params,
        });
    }

    /// `startFedDART`: connect to the runtime and wait until at least
    /// `min_clients` are visible (0 = no wait).
    pub fn start_fed_dart(&self, min_clients: usize, timeout: Duration) -> Result<()> {
        let t0 = Instant::now();
        loop {
            let n = self.selector.device_names()?.len();
            if n >= min_clients {
                log::info!(target: "coordinator::workflow",
                    "startFedDART: {n} client(s) connected");
                return Ok(());
            }
            if t0.elapsed() > timeout {
                return Err(FedError::Device(format!(
                    "only {n}/{min_clients} clients connected after {timeout:?}"
                )));
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// `getAllDeviceNames`.
    pub fn get_all_device_names(&self) -> Result<Vec<String>> {
        self.selector.device_names()
    }

    /// `startTask`: submit a default task with per-client parameters.
    /// Non-blocking — returns the handle immediately (§A.1).
    pub fn start_task(
        &self,
        parameter_dict: BTreeMap<String, Json>,
        execute_function: &str,
    ) -> Result<TaskHandle> {
        self.selector
            .submit(Task::new(TaskKind::Default, execute_function, parameter_dict))
    }

    /// `startTask` with an explicit task (requirements, retries).
    pub fn start_task_full(&self, task: Task) -> Result<TaskHandle> {
        self.selector.submit(task)
    }

    /// `getTaskStatus`.
    pub fn get_task_status(&self, handle: TaskHandle) -> Result<WfTaskStatus> {
        self.selector.status(handle)
    }

    /// `getTaskResult`: the results available *now* (possibly partial).
    pub fn get_task_result(&self, handle: TaskHandle) -> Result<Vec<TaskResult>> {
        self.selector.results(handle)
    }

    /// Number of results available now, without fetching their payloads.
    pub fn get_task_result_count(&self, handle: TaskHandle) -> Result<usize> {
        self.selector.result_count(handle)
    }

    /// Status and result count in one backend query.
    pub fn get_task_progress(
        &self,
        handle: TaskHandle,
    ) -> Result<(WfTaskStatus, usize)> {
        self.selector.progress(handle)
    }

    /// `stopTask`.
    pub fn stop_task(&self, handle: TaskHandle) -> Result<()> {
        self.selector.stop(handle)
    }

    // -------------------------------------------------------- conveniences

    /// Poll until the task settles or `timeout` elapses (Alg 2's wait loop).
    pub fn wait_for_task(
        &self,
        handle: TaskHandle,
        timeout: Duration,
    ) -> Result<WfTaskStatus> {
        let t0 = Instant::now();
        loop {
            let st = self.get_task_status(handle)?;
            match st {
                WfTaskStatus::Queued | WfTaskStatus::InProgress => {
                    if t0.elapsed() > timeout {
                        return Ok(st);
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                settled => return Ok(settled),
            }
        }
    }

    /// Run a task as a partial-participation round: collect results until
    /// `quorum` of the addressed clients reported or `deadline` fired,
    /// whichever comes first, then cancel the task.  Results arriving
    /// after the close are dropped; with a non-zero `late_grace` they are
    /// swept once (already-running stragglers can still settle at the
    /// backend after the stop) and reported in
    /// [`QuorumOutcome::late`] so participation metrics can count them.
    ///
    /// This is the production cross-device round loop: a round never
    /// waits for its slowest sampled client.
    pub fn run_task_quorum(
        &self,
        parameter_dict: BTreeMap<String, Json>,
        execute_function: &str,
        quorum: usize,
        deadline: Duration,
        late_grace: Duration,
    ) -> Result<QuorumOutcome> {
        let expected = parameter_dict.len();
        let quorum = quorum.clamp(1, expected.max(1));
        let h = self.start_task(parameter_dict, execute_function)?;
        let t0 = Instant::now();
        // poll the payload-free (status, count) pair in one backend query
        // per iteration; the full result set (every client's parameter
        // tensors — re-downloaded per fetch over REST) is fetched exactly
        // once, at close
        let (results, close, backend_settled) = loop {
            let (st, n) = self.get_task_progress(h)?;
            if n >= expected {
                break (self.get_task_result(h)?, RoundClose::Complete, true);
            }
            match st {
                WfTaskStatus::Queued | WfTaskStatus::InProgress => {
                    if n >= quorum {
                        break (
                            self.get_task_result(h)?,
                            RoundClose::Quorum,
                            false,
                        );
                    }
                    if t0.elapsed() >= deadline {
                        break (
                            self.get_task_result(h)?,
                            RoundClose::Deadline,
                            false,
                        );
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                // the backend settled early (client failures): whatever
                // arrived is final
                _ => {
                    let rs = self.get_task_result(h)?;
                    let close = if rs.len() >= quorum {
                        RoundClose::Quorum
                    } else {
                        RoundClose::Settled
                    };
                    break (rs, close, true);
                }
            }
        };
        let elapsed_ms = t0.elapsed().as_millis() as u64;
        let mut late = Vec::new();
        let mut close = close;
        if !backend_settled {
            if results.len() >= expected {
                // Every addressed client's result landed between the
                // payload-free progress poll and the full fetch: nothing
                // is outstanding, so there is no straggler to stop or
                // sweep — sleeping out the grace window here stalled a
                // fully-reported round for the whole `late_grace` for
                // nothing (and the stop would clobber the backend's
                // settled status).
                close = RoundClose::Complete;
            } else {
                // cancel outstanding units; units already running when
                // the round closed may still settle into the backend's
                // result set (a settled backend has nothing outstanding —
                // stopping it would only overwrite its
                // Finished/PartiallyFailed status, and sleeping out the
                // grace window could observe nothing)
                let _ = self.stop_task(h);
                if !late_grace.is_zero() {
                    std::thread::sleep(late_grace);
                    if let Ok(after) = self.get_task_result(h) {
                        for r in after {
                            if !results
                                .iter()
                                .any(|x| x.device_name == r.device_name)
                            {
                                late.push(r.device_name);
                            }
                        }
                    }
                    late.sort();
                }
            }
        }
        // flight-recorder breadcrumb on the caller's active span (the
        // round's quorum_wait phase): why the round closed, and when
        crate::telemetry::event(
            "quorum_close",
            &[
                ("function", execute_function),
                ("close", close.as_str()),
                ("results", &results.len().to_string()),
                ("expected", &expected.to_string()),
                ("quorum", &quorum.to_string()),
                ("late", &late.len().to_string()),
                ("elapsed_ms", &elapsed_ms.to_string()),
            ],
        );
        Ok(QuorumOutcome { results, close, late, elapsed_ms })
    }

    /// Run a task to completion and return its results (the common Alg 2
    /// body: start, wait, fetch).
    pub fn run_task(
        &self,
        parameter_dict: BTreeMap<String, Json>,
        execute_function: &str,
        timeout: Duration,
    ) -> Result<Vec<TaskResult>> {
        let h = self.start_task(parameter_dict, execute_function)?;
        let st = self.wait_for_task(h, timeout)?;
        match st {
            WfTaskStatus::Finished | WfTaskStatus::PartiallyFailed => {
                self.get_task_result(h)
            }
            other => Err(FedError::Task(format!(
                "task {h} did not finish: {other:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> TaskRegistry {
        let reg = TaskRegistry::new();
        reg.register("init", |_| Ok(Json::Null));
        reg.register("learn", |p| {
            let lr = p.get("lr").and_then(Json::as_f64).unwrap_or(0.0);
            Ok(Json::obj().set("loss", 1.0 / (1.0 + lr)))
        });
        reg
    }

    #[test]
    fn paper_workflow_end_to_end() {
        // Alg 1: init the manager, create the init task, start Fed-DART
        let wm = WorkflowManager::test_mode(4, registry(), 2);
        assert!(wm.is_test_mode());
        wm.create_init_task(Json::obj().set("model", "mlp"), "init");
        wm.start_fed_dart(4, Duration::from_secs(5)).unwrap();

        // Alg 2: learning rounds
        for round in 0..3 {
            let clients = wm.get_all_device_names().unwrap();
            assert_eq!(clients.len(), 4);
            let dict: BTreeMap<String, Json> = clients
                .iter()
                .map(|c| (c.clone(), Json::obj().set("lr", 0.1 * (round + 1) as f64)))
                .collect();
            let handle = wm.start_task(dict, "learn").unwrap();
            let st = wm.wait_for_task(handle, Duration::from_secs(10)).unwrap();
            assert_eq!(st, WfTaskStatus::Finished);
            let results = wm.get_task_result(handle).unwrap();
            assert_eq!(results.len(), 4);
            for r in &results {
                assert!(r.result.get("loss").unwrap().as_f64().unwrap() < 1.0);
                assert!(r.duration >= 0.0);
            }
        }
    }

    #[test]
    fn batched_test_mode_runs_rounds() {
        // capacity 4, poll batch 4: the same paper workflow over the
        // batched dispatch path
        let wm = WorkflowManager::test_mode_batched(4, registry(), 2, 4, 4);
        wm.start_fed_dart(4, Duration::from_secs(5)).unwrap();
        for _ in 0..3 {
            let clients = wm.get_all_device_names().unwrap();
            let dict: BTreeMap<String, Json> = clients
                .iter()
                .map(|c| (c.clone(), Json::obj().set("lr", 0.5)))
                .collect();
            let results = wm.run_task(dict, "learn", Duration::from_secs(10)).unwrap();
            assert_eq!(results.len(), 4);
        }
    }

    #[test]
    fn run_task_convenience() {
        let wm = WorkflowManager::test_mode(2, registry(), 1);
        let clients = wm.get_all_device_names().unwrap();
        let dict: BTreeMap<String, Json> = clients
            .iter()
            .map(|c| (c.clone(), Json::obj().set("lr", 1.0)))
            .collect();
        let results = wm.run_task(dict, "learn", Duration::from_secs(10)).unwrap();
        assert_eq!(results.len(), 2);
    }

    #[test]
    fn quorum_round_closes_early_and_counts_late_stragglers() {
        let reg = TaskRegistry::new();
        reg.register("slowfast", |p| {
            if p.get("slow").and_then(Json::as_bool).unwrap_or(false) {
                std::thread::sleep(Duration::from_millis(250));
            }
            Ok(Json::obj().set("ok", true))
        });
        // one dispatcher thread per client so a straggler never delays
        // the fast clients' execution
        let wm = WorkflowManager::test_mode(4, reg, 4);
        let clients = wm.get_all_device_names().unwrap();
        let slow = clients[3].clone();
        let dict: BTreeMap<String, Json> = clients
            .iter()
            .map(|c| (c.clone(), Json::obj().set("slow", *c == slow)))
            .collect();
        let out = wm
            .run_task_quorum(
                dict,
                "slowfast",
                3,
                Duration::from_secs(10),
                Duration::from_millis(800),
            )
            .unwrap();
        assert_eq!(out.close, RoundClose::Quorum);
        assert_eq!(out.results.len(), 3);
        assert!(out.results.iter().all(|r| r.device_name != slow));
        // the straggler settled during the grace sweep: counted as late
        assert_eq!(out.late, vec![slow]);
    }

    #[test]
    fn quorum_round_completes_without_stop_when_everyone_reports() {
        let wm = WorkflowManager::test_mode(3, registry(), 3);
        let clients = wm.get_all_device_names().unwrap();
        let dict: BTreeMap<String, Json> = clients
            .iter()
            .map(|c| (c.clone(), Json::obj().set("lr", 1.0)))
            .collect();
        let out = wm
            .run_task_quorum(
                dict,
                "learn",
                3,
                Duration::from_secs(10),
                Duration::from_millis(500),
            )
            .unwrap();
        assert_eq!(out.close, RoundClose::Complete);
        assert_eq!(out.results.len(), 3);
        assert!(out.late.is_empty());
    }

    #[test]
    fn deadline_closes_a_round_below_quorum() {
        let reg = TaskRegistry::new();
        reg.register("sleepy", |_| {
            std::thread::sleep(Duration::from_millis(400));
            Ok(Json::obj().set("ok", true))
        });
        let wm = WorkflowManager::test_mode(2, reg, 2);
        let clients = wm.get_all_device_names().unwrap();
        let dict: BTreeMap<String, Json> =
            clients.iter().map(|c| (c.clone(), Json::Null)).collect();
        let t0 = Instant::now();
        let out = wm
            .run_task_quorum(
                dict,
                "sleepy",
                2,
                Duration::from_millis(60),
                Duration::ZERO,
            )
            .unwrap();
        assert_eq!(out.close, RoundClose::Deadline);
        assert!(out.results.is_empty());
        assert!(
            t0.elapsed() < Duration::from_millis(350),
            "deadline close waited for the stragglers"
        );
    }

    /// Backend that reports quorum-level progress while the full fetch
    /// already returns every result — the exact race where the old code
    /// stopped the task and slept out the entire late-grace window even
    /// though every addressed client had reported.
    struct FullFetchApi {
        n: usize,
        stopped: std::sync::atomic::AtomicBool,
    }

    impl crate::dart::DartApi for FullFetchApi {
        fn devices(&self) -> Result<Vec<crate::dart::DeviceInfo>> {
            Ok((0..self.n)
                .map(|i| crate::dart::DeviceInfo {
                    name: format!("client-{i}"),
                    hardware: Default::default(),
                    alive: true,
                })
                .collect())
        }
        fn submit(&self, _: crate::dart::scheduler::TaskSpec) -> Result<u64> {
            Ok(1)
        }
        fn status(&self, _: u64) -> Result<crate::dart::scheduler::TaskStatus> {
            Ok(crate::dart::scheduler::TaskStatus::InProgress)
        }
        fn progress(
            &self,
            _: u64,
        ) -> Result<(crate::dart::scheduler::TaskStatus, usize)> {
            // report exactly quorum-many results available
            Ok((crate::dart::scheduler::TaskStatus::InProgress, self.n - 1))
        }
        fn results(&self, _: u64) -> Result<Vec<TaskResult>> {
            // ...but by fetch time EVERY client has settled
            Ok((0..self.n)
                .map(|i| TaskResult {
                    device_name: format!("client-{i}"),
                    duration: 0.0,
                    result: Json::obj().set("ok", true),
                })
                .collect())
        }
        fn stop_task(&self, _: u64) -> Result<()> {
            self.stopped
                .store(true, std::sync::atomic::Ordering::SeqCst);
            Ok(())
        }
    }

    /// Regression: a quorum close whose result fetch already covers every
    /// addressed client must skip the stop + grace sleep entirely —
    /// `reported == expected` means no straggler can exist.
    #[test]
    fn fully_reported_quorum_close_skips_grace_sleep() {
        let api = Arc::new(FullFetchApi {
            n: 4,
            stopped: std::sync::atomic::AtomicBool::new(false),
        });
        let wm = WorkflowManager::with_backend(
            api.clone() as Arc<dyn crate::dart::DartApi>
        );
        let dict: BTreeMap<String, Json> = (0..4)
            .map(|i| (format!("client-{i}"), Json::Null))
            .collect();
        let t0 = Instant::now();
        let out = wm
            .run_task_quorum(
                dict,
                "f",
                3,
                Duration::from_secs(10),
                Duration::from_secs(5), // the old code slept out all 5s
            )
            .unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "fully-reported round paid the grace stall: {:?}",
            t0.elapsed()
        );
        assert_eq!(out.results.len(), 4);
        assert_eq!(out.close, RoundClose::Complete);
        assert!(out.late.is_empty());
        assert!(
            !api.stopped.load(std::sync::atomic::Ordering::SeqCst),
            "nothing outstanding — stop would clobber the settled status"
        );
    }

    #[test]
    fn start_fed_dart_times_out_without_clients() {
        let wm = WorkflowManager::test_mode(1, registry(), 1);
        let err = wm.start_fed_dart(5, Duration::from_millis(100));
        assert!(err.is_err());
    }

    #[test]
    fn unknown_function_partially_fails() {
        let wm = WorkflowManager::test_mode(2, registry(), 1);
        let clients = wm.get_all_device_names().unwrap();
        let dict: BTreeMap<String, Json> =
            clients.iter().map(|c| (c.clone(), Json::Null)).collect();
        let h = wm.start_task(dict, "no_such_fn").unwrap();
        let st = wm.wait_for_task(h, Duration::from_secs(10)).unwrap();
        assert_eq!(st, WfTaskStatus::PartiallyFailed);
    }
}
