//! Coordinator-level task representation (paper §A.2 "Task": "manages all
//! relevant information, such as the function to be executed and the
//! function parameters for each client. A check function verifies the task
//! requirements to ensure that hardware requirements and device
//! availability are fulfilled.")

use std::collections::BTreeMap;

use crate::config::HardwareConfig;
use crate::coordinator::device::DeviceHolder;
use crate::error::{FedError, Result};
use crate::json::Json;

/// Opaque task handle returned to the user (paper §A.1: "If the task was
/// accepted, a handle is returned to the user").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskHandle(pub u64);

impl std::fmt::Display for TaskHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "task#{}", self.0)
    }
}

/// Kind of task in the Fed-DART workflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// The init task, guaranteed to run on each client before anything else
    /// (Alg. 1).
    Init,
    /// A regular (default / learning) task.
    Default,
}

/// A task as the coordinator tracks it.
#[derive(Debug, Clone)]
pub struct Task {
    pub kind: TaskKind,
    /// client-side `@feddart` function name
    pub execute_function: String,
    /// per-client parameters (the parameterDict, §A.1)
    pub parameter_dict: BTreeMap<String, Json>,
    pub requirements: HardwareConfig,
    pub max_retries: u32,
}

impl Task {
    pub fn new(
        kind: TaskKind,
        execute_function: &str,
        parameter_dict: BTreeMap<String, Json>,
    ) -> Task {
        Task {
            kind,
            execute_function: execute_function.to_string(),
            parameter_dict,
            requirements: HardwareConfig::default(),
            max_retries: 2,
        }
    }

    pub fn with_requirements(mut self, req: HardwareConfig) -> Task {
        self.requirements = req;
        self
    }

    pub fn with_retries(mut self, r: u32) -> Task {
        self.max_retries = r;
        self
    }

    pub fn client_names(&self) -> Vec<String> {
        self.parameter_dict.keys().cloned().collect()
    }

    /// The paper's check function: hardware requirements and device
    /// availability must be fulfilled for every addressed client.
    pub fn check(&self, devices: &DeviceHolder) -> Result<()> {
        if self.parameter_dict.is_empty() {
            return Err(FedError::Task("task addresses no clients".into()));
        }
        if self.execute_function.is_empty() {
            return Err(FedError::Task("executeFunction must be non-empty".into()));
        }
        for name in self.parameter_dict.keys() {
            let dev = devices.get(name).ok_or_else(|| {
                FedError::Task(format!("unknown device '{name}'"))
            })?;
            if !dev.is_alive() {
                return Err(FedError::Task(format!("device '{name}' not connected")));
            }
            if !dev.hardware.satisfies(&self.requirements) {
                return Err(FedError::Task(format!(
                    "device '{name}' fails hardware check (has {:?}, needs {:?})",
                    dev.hardware, self.requirements
                )));
            }
        }
        Ok(())
    }

    /// Convert into the scheduler-level spec.
    pub fn to_spec(&self) -> crate::dart::scheduler::TaskSpec {
        crate::dart::scheduler::TaskSpec {
            function: self.execute_function.clone(),
            params: self.parameter_dict.clone(),
            requirements: self.requirements.clone(),
            max_retries: self.max_retries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::device::DeviceSingle;

    fn holder() -> DeviceHolder {
        DeviceHolder::new(vec![
            DeviceSingle::new("a", HardwareConfig::default()),
            DeviceSingle::new(
                "b",
                HardwareConfig { cpus: 8, mem_gb: 16, accelerator: "tpu".into() },
            ),
        ])
    }

    fn dict(names: &[&str]) -> BTreeMap<String, Json> {
        names.iter().map(|n| (n.to_string(), Json::Null)).collect()
    }

    #[test]
    fn check_passes_for_known_alive_devices() {
        let t = Task::new(TaskKind::Default, "learn", dict(&["a", "b"]));
        assert!(t.check(&holder()).is_ok());
        assert_eq!(t.client_names(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn check_rejects_unknown_device() {
        let t = Task::new(TaskKind::Default, "learn", dict(&["ghost"]));
        assert!(t.check(&holder()).is_err());
    }

    #[test]
    fn check_rejects_dead_device() {
        let h = holder();
        h.get("a").unwrap().set_alive(false);
        let t = Task::new(TaskKind::Default, "learn", dict(&["a"]));
        assert!(t.check(&h).is_err());
    }

    #[test]
    fn check_rejects_insufficient_hardware() {
        let t = Task::new(TaskKind::Default, "learn", dict(&["a"]))
            .with_requirements(HardwareConfig {
                cpus: 4,
                mem_gb: 8,
                accelerator: "none".into(),
            });
        assert!(t.check(&holder()).is_err());
        // device b satisfies it
        let t2 = Task::new(TaskKind::Default, "learn", dict(&["b"]))
            .with_requirements(HardwareConfig {
                cpus: 4,
                mem_gb: 8,
                accelerator: "tpu".into(),
            });
        assert!(t2.check(&holder()).is_ok());
    }

    #[test]
    fn check_rejects_empty() {
        let t = Task::new(TaskKind::Default, "learn", BTreeMap::new());
        assert!(t.check(&holder()).is_err());
        let t2 = Task::new(TaskKind::Default, "", dict(&["a"]));
        assert!(t2.check(&holder()).is_err());
    }

    #[test]
    fn to_spec_preserves_fields() {
        let t = Task::new(TaskKind::Default, "learn", dict(&["a"])).with_retries(7);
        let s = t.to_spec();
        assert_eq!(s.function, "learn");
        assert_eq!(s.max_retries, 7);
        assert_eq!(s.params.len(), 1);
    }
}
