//! DeviceSingle / DeviceHolder — the virtual client representations
//! (paper §A.2).
//!
//! "DeviceSingle is the virtual representation of each real physical
//! client. ... Each deviceSingle caches the task parameters of an open task
//! and the task results of already finished tasks."
//!
//! "DeviceHolder groups multiple DeviceSingles together. Every request to a
//! client must go through the DeviceHolder. If possible, computations or
//! requests are performed on deviceHolder level to avoid too many small
//! operations on deviceSingle level."

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::config::HardwareConfig;
use crate::dart::scheduler::TaskResult;
use crate::json::Json;

/// Virtual representation of one physical client.
#[derive(Debug)]
pub struct DeviceSingle {
    pub name: String,
    pub hardware: HardwareConfig,
    state: Mutex<DeviceState>,
}

#[derive(Debug, Default)]
struct DeviceState {
    alive: bool,
    /// parameters of the currently open task (if any), by task handle
    open_params: BTreeMap<u64, Json>,
    /// finished task results, by task handle
    finished: BTreeMap<u64, TaskResult>,
    /// has the init task completed on this device?
    initialized: bool,
}

impl DeviceSingle {
    pub fn new(name: &str, hardware: HardwareConfig) -> Arc<DeviceSingle> {
        Arc::new(DeviceSingle {
            name: name.to_string(),
            hardware,
            state: Mutex::new(DeviceState { alive: true, ..Default::default() }),
        })
    }

    pub fn is_alive(&self) -> bool {
        self.state.lock().unwrap().alive
    }

    pub fn set_alive(&self, alive: bool) {
        self.state.lock().unwrap().alive = alive;
    }

    pub fn is_initialized(&self) -> bool {
        self.state.lock().unwrap().initialized
    }

    pub fn mark_initialized(&self) {
        self.state.lock().unwrap().initialized = true;
    }

    /// Cache the parameters of an open task.
    pub fn open_task(&self, handle: u64, params: Json) {
        self.state.lock().unwrap().open_params.insert(handle, params);
    }

    /// Parameters cached for an open task.
    pub fn open_params(&self, handle: u64) -> Option<Json> {
        self.state.lock().unwrap().open_params.get(&handle).cloned()
    }

    /// Record a finished result (moves the task out of the open set).
    pub fn finish_task(&self, handle: u64, result: TaskResult) {
        let mut st = self.state.lock().unwrap();
        st.open_params.remove(&handle);
        st.finished.insert(handle, result);
    }

    /// Cached result of a finished task.
    pub fn finished_result(&self, handle: u64) -> Option<TaskResult> {
        self.state.lock().unwrap().finished.get(&handle).cloned()
    }

    /// Number of cached finished results.
    pub fn finished_count(&self) -> usize {
        self.state.lock().unwrap().finished.len()
    }

    /// Drop cached results older than the newest `keep` (bounded cache).
    pub fn prune_finished(&self, keep: usize) {
        let mut st = self.state.lock().unwrap();
        while st.finished.len() > keep {
            let oldest = *st.finished.keys().next().unwrap();
            st.finished.remove(&oldest);
        }
    }
}

/// A group of devices; holder-level bulk operations.
#[derive(Debug, Clone, Default)]
pub struct DeviceHolder {
    devices: Vec<Arc<DeviceSingle>>,
}

impl DeviceHolder {
    pub fn new(devices: Vec<Arc<DeviceSingle>>) -> DeviceHolder {
        DeviceHolder { devices }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn devices(&self) -> &[Arc<DeviceSingle>] {
        &self.devices
    }

    pub fn names(&self) -> Vec<String> {
        self.devices.iter().map(|d| d.name.clone()).collect()
    }

    pub fn get(&self, name: &str) -> Option<&Arc<DeviceSingle>> {
        self.devices.iter().find(|d| d.name == name)
    }

    /// Holder-level: open a task on every contained device at once.
    pub fn open_task_all(&self, handle: u64, params: &BTreeMap<String, Json>) {
        for d in &self.devices {
            if let Some(p) = params.get(&d.name) {
                d.open_task(handle, p.clone());
            }
        }
    }

    /// Holder-level: record finished results in bulk.
    pub fn finish_tasks(&self, handle: u64, results: &[TaskResult]) {
        for r in results {
            if let Some(d) = self.get(&r.device_name) {
                d.finish_task(handle, r.clone());
            }
        }
    }

    /// Holder-level: collect all cached results for a task.
    pub fn collect_results(&self, handle: u64) -> Vec<TaskResult> {
        self.devices
            .iter()
            .filter_map(|d| d.finished_result(handle))
            .collect()
    }

    /// All devices satisfying a hardware requirement.
    pub fn satisfying(&self, req: &HardwareConfig) -> Vec<Arc<DeviceSingle>> {
        self.devices
            .iter()
            .filter(|d| d.hardware.satisfies(req))
            .cloned()
            .collect()
    }

    /// Split into `n` balanced holders (for the Aggregator tree).
    pub fn split(&self, n: usize) -> Vec<DeviceHolder> {
        let n = n.max(1).min(self.devices.len().max(1));
        let mut parts: Vec<Vec<Arc<DeviceSingle>>> = vec![Vec::new(); n];
        for (i, d) in self.devices.iter().enumerate() {
            parts[i % n].push(Arc::clone(d));
        }
        parts.into_iter().map(DeviceHolder::new).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn holder(n: usize) -> DeviceHolder {
        DeviceHolder::new(
            (0..n)
                .map(|i| DeviceSingle::new(&format!("d{i}"), HardwareConfig::default()))
                .collect(),
        )
    }

    #[test]
    fn device_caches_open_and_finished() {
        let d = DeviceSingle::new("edge", HardwareConfig::default());
        assert!(d.is_alive());
        assert!(!d.is_initialized());
        d.open_task(1, Json::obj().set("lr", 0.1));
        assert_eq!(
            d.open_params(1).unwrap().get("lr").unwrap().as_f64(),
            Some(0.1)
        );
        d.finish_task(
            1,
            TaskResult { device_name: "edge".into(), duration: 1.0, result: Json::Null },
        );
        assert!(d.open_params(1).is_none(), "open params cleared on finish");
        assert!(d.finished_result(1).is_some());
        d.mark_initialized();
        assert!(d.is_initialized());
    }

    #[test]
    fn prune_keeps_newest() {
        let d = DeviceSingle::new("edge", HardwareConfig::default());
        for h in 0..10 {
            d.finish_task(h, TaskResult {
                device_name: "edge".into(), duration: 0.0, result: Json::Null,
            });
        }
        d.prune_finished(3);
        assert_eq!(d.finished_count(), 3);
        assert!(d.finished_result(9).is_some());
        assert!(d.finished_result(0).is_none());
    }

    #[test]
    fn holder_bulk_operations() {
        let h = holder(3);
        let mut params = BTreeMap::new();
        for i in 0..3 {
            params.insert(format!("d{i}"), Json::obj().set("i", i));
        }
        h.open_task_all(7, &params);
        assert_eq!(
            h.get("d1").unwrap().open_params(7).unwrap().get("i").unwrap().as_i64(),
            Some(1)
        );
        let results: Vec<TaskResult> = (0..3)
            .map(|i| TaskResult {
                device_name: format!("d{i}"),
                duration: i as f64,
                result: Json::Null,
            })
            .collect();
        h.finish_tasks(7, &results);
        assert_eq!(h.collect_results(7).len(), 3);
    }

    #[test]
    fn holder_split_balances() {
        let h = holder(10);
        let parts = h.split(3);
        assert_eq!(parts.len(), 3);
        let sizes: Vec<usize> = parts.iter().map(DeviceHolder::len).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4));
        // split of an empty holder does not panic
        assert_eq!(DeviceHolder::default().split(4).len(), 1);
    }

    #[test]
    fn satisfying_filters_hardware() {
        let strong = DeviceSingle::new(
            "strong",
            HardwareConfig { cpus: 16, mem_gb: 64, accelerator: "tpu".into() },
        );
        let weak = DeviceSingle::new("weak", HardwareConfig::default());
        let h = DeviceHolder::new(vec![strong, weak]);
        let req = HardwareConfig { cpus: 8, mem_gb: 8, accelerator: "none".into() };
        let ok = h.satisfying(&req);
        assert_eq!(ok.len(), 1);
        assert_eq!(ok[0].name, "strong");
    }
}
