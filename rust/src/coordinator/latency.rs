//! Per-client learn-latency tracking for adaptive round deadlines.
//!
//! A production cross-device round should not wait a static `deadline_ms`
//! for a cohort whose healthy members reliably report in a fraction of
//! it.  [`LatencyTracker`] keeps a small ring of recently observed learn
//! latencies per client — fed by the quorum round loop's close data
//! (completer-reported durations plus censored round-elapsed lower
//! bounds for non-reporters) — and [`effective_deadline`] resolves a
//! round's deadline from the configured percentile of those
//! observations × a safety margin, clamped into `[min, max]`.  Until the
//! tracker is warm the static `deadline_ms` applies, so a cold start is
//! never more aggressive than the operator asked for.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;

use crate::config::ParticipationConfig;

/// Observations kept per client (ring buffer).
const DEFAULT_WINDOW: usize = 64;
/// Total observations before the tracker is considered warm.
const DEFAULT_MIN_SAMPLES: usize = 8;

/// Streaming per-client learn-latency quantile tracker.
///
/// Thread-safe; the FACT server shares one tracker across its cluster
/// worker threads for the lifetime of a session.
pub struct LatencyTracker {
    window: usize,
    min_samples: usize,
    inner: Mutex<BTreeMap<String, VecDeque<u64>>>,
}

impl Default for LatencyTracker {
    fn default() -> Self {
        Self::new(DEFAULT_WINDOW, DEFAULT_MIN_SAMPLES)
    }
}

impl LatencyTracker {
    /// A tracker keeping up to `window` observations per client and
    /// reporting quantiles only after `min_samples` total observations.
    pub fn new(window: usize, min_samples: usize) -> LatencyTracker {
        LatencyTracker {
            window: window.max(1),
            min_samples: min_samples.max(1),
            inner: Mutex::new(BTreeMap::new()),
        }
    }

    /// Record one observed learn latency (ms) for `client`.
    pub fn observe(&self, client: &str, ms: u64) {
        let mut inner = self.inner.lock().unwrap();
        let ring = inner.entry(client.to_string()).or_default();
        if ring.len() >= self.window {
            ring.pop_front();
        }
        ring.push_back(ms);
    }

    /// Record one completed learn round for `client`: `total_ms` is the
    /// server-observed submit→complete wall time, `compute_ms` the
    /// client-reported on-device compute time when it reported one.
    ///
    /// When the client reports its compute time, *that* is what enters
    /// the ring — a client that computes fast but sat in a deep worker
    /// queue should not inflate the adaptive-deadline percentile with
    /// queueing delay the next round will not repeat.  A client-reported
    /// time above the server-observed total is clock skew, not signal,
    /// so it is capped at `total_ms`.  Without a report, the wall time
    /// is the best available estimate.
    pub fn observe_round(&self, client: &str, total_ms: u64, compute_ms: Option<u64>) {
        let ms = match compute_ms {
            Some(c) => c.min(total_ms),
            None => total_ms,
        };
        self.observe(client, ms);
    }

    /// Record a censored observation: `client` had not reported when the
    /// round closed after `ms`, so its true latency is *at least* `ms`.
    /// Recording the lower bound keeps chronic stragglers from shrinking
    /// the tracked percentile while never inflating it past what was
    /// actually waited.
    pub fn observe_censored(&self, client: &str, ms: u64) {
        self.observe(client, ms);
    }

    /// Total observations held across all clients.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().values().map(VecDeque::len).sum()
    }

    /// Whether nothing has been observed yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether enough observations exist to trust a quantile.
    pub fn is_warm(&self) -> bool {
        self.len() >= self.min_samples
    }

    /// The `q`-quantile (0..=1, nearest-rank) over the observations of
    /// `cohort`'s members — falling back to the whole pool when no cohort
    /// member has history (a freshly sampled cohort still benefits from
    /// fleet-wide latency knowledge).  `None` until warm.
    pub fn quantile_for(&self, cohort: &[String], q: f64) -> Option<u64> {
        if !self.is_warm() {
            return None;
        }
        let inner = self.inner.lock().unwrap();
        let mut samples: Vec<u64> = cohort
            .iter()
            .filter_map(|c| inner.get(c))
            .flatten()
            .copied()
            .collect();
        if samples.is_empty() {
            samples = inner.values().flatten().copied().collect();
        }
        drop(inner);
        if samples.is_empty() {
            return None;
        }
        samples.sort_unstable();
        let q = q.clamp(0.0, 1.0);
        let idx = ((samples.len() as f64 * q).ceil() as usize)
            .saturating_sub(1)
            .min(samples.len() - 1);
        Some(samples[idx])
    }

    /// Pool-wide `q`-quantile (`None` until warm).
    pub fn quantile(&self, q: f64) -> Option<u64> {
        self.quantile_for(&[], q)
    }
}

/// A fully explained deadline resolution — every input the decision was
/// made from, so the telemetry `deadline_decision` event (and with it a
/// post-mortem) can say *why* a round closed when it did.
#[derive(Debug, Clone, Copy)]
pub struct DeadlineDecision {
    /// The resolved deadline actually applied to the round.
    pub deadline_ms: u64,
    /// True only when a tracked percentile decided the value.
    pub adaptive: bool,
    /// The percentile consulted (0 when the mode is static).
    pub quantile: f64,
    /// The observed cohort latency at that percentile, when warm.
    pub observed_ms: Option<u64>,
    /// Observations the tracker held at decision time.
    pub tracker_len: usize,
}

impl DeadlineDecision {
    fn fallback(p: &ParticipationConfig, quantile: f64, tracker_len: usize) -> DeadlineDecision {
        DeadlineDecision {
            deadline_ms: p.deadline_ms,
            adaptive: false,
            quantile,
            observed_ms: None,
            tracker_len,
        }
    }
}

/// Resolve the effective learn deadline for a round: the configured
/// percentile of `cohort`'s tracked latencies × `deadline_margin`,
/// clamped into `[deadline_min_ms, deadline_max_ms]` — or the static
/// `deadline_ms` when the mode is static or the tracker is cold.
pub fn effective_deadline_explained(
    tracker: &LatencyTracker,
    p: &ParticipationConfig,
    cohort: &[String],
) -> DeadlineDecision {
    let len = tracker.len();
    let Some(q) = p.deadline.quantile() else {
        return DeadlineDecision::fallback(p, 0.0, len);
    };
    let Some(observed) = tracker.quantile_for(cohort, q) else {
        return DeadlineDecision::fallback(p, q, len); // cold: static fallback
    };
    let mut d = (observed as f64 * p.deadline_margin.max(1.0)).ceil() as u64;
    if p.deadline_min_ms > 0 {
        d = d.max(p.deadline_min_ms);
    }
    if p.deadline_max_ms > 0 {
        d = d.min(p.deadline_max_ms);
    }
    DeadlineDecision {
        // an adaptive deadline of 0 would mean "no deadline" downstream —
        // never let clamping produce that inversion
        deadline_ms: d.max(1),
        adaptive: true,
        quantile: q,
        observed_ms: Some(observed),
        tracker_len: len,
    }
}

/// [`effective_deadline_explained`] reduced to `(deadline_ms, adaptive)`
/// for callers that don't need the inputs.
pub fn effective_deadline(
    tracker: &LatencyTracker,
    p: &ParticipationConfig,
    cohort: &[String],
) -> (u64, bool) {
    let d = effective_deadline_explained(tracker, p, cohort);
    (d.deadline_ms, d.adaptive)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeadlineMode;

    fn cfg(mode: DeadlineMode) -> ParticipationConfig {
        ParticipationConfig {
            deadline: mode,
            deadline_ms: 2_000,
            deadline_margin: 1.5,
            deadline_min_ms: 10,
            deadline_max_ms: 10_000,
            ..Default::default()
        }
    }

    #[test]
    fn quantiles_over_observations() {
        let t = LatencyTracker::new(16, 4);
        for (i, ms) in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100]
            .iter()
            .enumerate()
        {
            t.observe(&format!("c-{}", i % 2), *ms);
        }
        assert!(t.is_warm());
        assert_eq!(t.quantile(0.5).unwrap(), 50);
        assert_eq!(t.quantile(0.9).unwrap(), 90);
        assert_eq!(t.quantile(1.0).unwrap(), 100);
        assert_eq!(t.quantile(0.0).unwrap(), 10);
    }

    #[test]
    fn cold_tracker_reports_nothing_and_falls_back_static() {
        let t = LatencyTracker::new(16, 8);
        for i in 0..7 {
            t.observe("c-0", 100 + i);
        }
        assert!(!t.is_warm());
        assert_eq!(t.quantile(0.5), None);
        // effective deadline: static fallback while cold
        let (d, adaptive) = effective_deadline(&t, &cfg(DeadlineMode::P90), &[]);
        assert_eq!(d, 2_000);
        assert!(!adaptive);
        // static mode never consults the tracker even when warm
        t.observe("c-0", 107);
        assert!(t.is_warm());
        let (d, adaptive) = effective_deadline(&t, &cfg(DeadlineMode::Static), &[]);
        assert_eq!(d, 2_000);
        assert!(!adaptive);
    }

    #[test]
    fn adaptive_deadline_applies_margin_and_clamps() {
        let t = LatencyTracker::new(16, 4);
        for ms in [100u64, 100, 100, 200] {
            t.observe("c-0", ms);
        }
        let (d, adaptive) = effective_deadline(&t, &cfg(DeadlineMode::P50), &[]);
        assert!(adaptive);
        assert_eq!(d, 150); // 100 * 1.5
        let (d, _) = effective_deadline(&t, &cfg(DeadlineMode::P99), &[]);
        assert_eq!(d, 300); // 200 * 1.5
        // the floor clamps up...
        let mut c = cfg(DeadlineMode::P50);
        c.deadline_min_ms = 400;
        assert_eq!(effective_deadline(&t, &c, &[]).0, 400);
        // ...and the cap clamps down
        let mut c = cfg(DeadlineMode::P99);
        c.deadline_max_ms = 120;
        assert_eq!(effective_deadline(&t, &c, &[]).0, 120);
    }

    #[test]
    fn cohort_scoped_quantile_falls_back_to_pool() {
        let t = LatencyTracker::new(16, 4);
        for _ in 0..8 {
            t.observe("fast", 10);
            t.observe("slow", 1_000);
        }
        // a cohort of only the slow client sees the slow distribution
        let slow_cohort = vec!["slow".to_string()];
        assert_eq!(t.quantile_for(&slow_cohort, 0.5).unwrap(), 1_000);
        // a cohort with no history falls back to the fleet-wide pool
        let fresh = vec!["newcomer".to_string()];
        assert_eq!(t.quantile_for(&fresh, 0.5).unwrap(), 10);
        assert_eq!(t.quantile_for(&fresh, 1.0).unwrap(), 1_000);
    }

    #[test]
    fn explained_decision_carries_inputs() {
        let t = LatencyTracker::new(16, 4);
        for ms in [100u64, 100, 100, 200] {
            t.observe("c-0", ms);
        }
        let d = effective_deadline_explained(&t, &cfg(DeadlineMode::P50), &[]);
        assert!(d.adaptive);
        assert_eq!(d.deadline_ms, 150);
        assert_eq!(d.quantile, 0.5);
        assert_eq!(d.observed_ms, Some(100));
        assert_eq!(d.tracker_len, 4);
        // static mode explains itself as non-adaptive with no observation
        let d = effective_deadline_explained(&t, &cfg(DeadlineMode::Static), &[]);
        assert!(!d.adaptive);
        assert_eq!(d.observed_ms, None);
        assert_eq!(d.deadline_ms, 2_000);
    }

    #[test]
    fn queue_time_does_not_inflate_compute_percentile() {
        let t = LatencyTracker::new(16, 4);
        // a fast-compute client stuck behind a deep worker queue: wall
        // time 5s, on-device compute 80ms — the ring records the compute
        for _ in 0..4 {
            t.observe_round("queued", 5_000, Some(80));
        }
        assert_eq!(t.quantile(1.0).unwrap(), 80);
        let (d, adaptive) = effective_deadline(&t, &cfg(DeadlineMode::P90), &[]);
        assert!(adaptive);
        assert_eq!(d, 120); // 80 * 1.5 margin — not 7_500
        // no compute report: the wall time is all we have
        t.observe_round("silent", 400, None);
        let silent = vec!["silent".to_string()];
        assert_eq!(t.quantile_for(&silent, 1.0).unwrap(), 400);
        // skewed client clock claiming more compute than the round took
        // is capped at the observed total
        t.observe_round("skewed", 300, Some(9_999));
        let skewed = vec!["skewed".to_string()];
        assert_eq!(t.quantile_for(&skewed, 1.0).unwrap(), 300);
    }

    #[test]
    fn window_evicts_oldest_observations() {
        let t = LatencyTracker::new(4, 1);
        for ms in [1_000u64, 1_000, 1_000, 1_000, 10, 10, 10, 10] {
            t.observe("c", ms);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.quantile(1.0).unwrap(), 10); // the slow era aged out
    }
}
