//! Aggregator / ChildAggregator — the ephemeral per-task managers
//! (paper §A.2, Figure A.10).
//!
//! "Aggregator is responsible for managing a task. In order to scale with
//! the amount of clients required for a task, the Aggregator can spawn
//! ChildAggregators to create a tree structure. This allows balancing and
//! parallelization of operations if needed. The associated clients are
//! stored in one or more deviceHolders."
//!
//! Besides result bookkeeping, the tree structure is what makes parameter
//! aggregation scale: [`tree_reduce_weighted`] reduces K client parameter
//! vectors through a fanout-bounded tree with each node's partial sums
//! computed on scoped threads — benched against the flat loop and the
//! HLO-fused kernel in E7 (`bench_aggregation`).  All reductions are
//! generic over `AsRef<[f32]>`, so they consume received
//! [`crate::util::tensorbuf::TensorBuf`]s directly (zero-copy views).

use crate::coordinator::device::DeviceHolder;
use crate::coordinator::task::{Task, TaskHandle};
use crate::dart::scheduler::{TaskId, TaskResult, TaskStatus};
use crate::dart::DartApi;
use crate::error::Result;

/// Fanout above which an aggregator splits its devices into children.
pub const DEFAULT_FANOUT: usize = 8;

/// The per-task aggregator tree.
pub struct Aggregator {
    pub handle: TaskHandle,
    pub task: Task,
    scheduler_id: TaskId,
    devices: DeviceHolder,
    children: Vec<ChildAggregator>,
}

/// A leaf/branch of the tree, owning one device holder.
pub struct ChildAggregator {
    pub devices: DeviceHolder,
}

impl Aggregator {
    /// Build the tree for a task already accepted by the backend.
    pub fn new(
        handle: TaskHandle,
        task: Task,
        scheduler_id: TaskId,
        devices: DeviceHolder,
        fanout: usize,
    ) -> Aggregator {
        let fanout = fanout.max(2);
        let children = if devices.len() > fanout {
            devices
                .split(devices.len().div_ceil(fanout))
                .into_iter()
                .map(|d| ChildAggregator { devices: d })
                .collect()
        } else {
            Vec::new()
        };
        // cache open-task parameters on every device (paper: DeviceSingle
        // caches the task parameters of an open task)
        devices.open_task_all(handle.0, &task.parameter_dict);
        Aggregator { handle, task, scheduler_id, devices, children }
    }

    pub fn scheduler_id(&self) -> TaskId {
        self.scheduler_id
    }

    pub fn device_holder(&self) -> &DeviceHolder {
        &self.devices
    }

    pub fn children(&self) -> &[ChildAggregator] {
        &self.children
    }

    /// Depth of the tree (1 = flat).
    pub fn depth(&self) -> usize {
        if self.children.is_empty() {
            1
        } else {
            2
        }
    }

    /// Poll the backend status.
    pub fn status(&self, api: &dyn DartApi) -> Result<TaskStatus> {
        api.status(self.scheduler_id)
    }

    /// Pull currently available results from the backend and cache them on
    /// the device singles; returns everything cached so far.
    pub fn sync_results(&self, api: &dyn DartApi) -> Result<Vec<TaskResult>> {
        let results = api.results(self.scheduler_id)?;
        if self.children.is_empty() {
            self.devices.finish_tasks(self.handle.0, &results);
        } else {
            // tree: each child ingests the slice of results for its devices
            for child in &self.children {
                child.devices.finish_tasks(self.handle.0, &results);
            }
        }
        Ok(self.devices.collect_results(self.handle.0))
    }

    /// Cancel the task at the backend.
    pub fn stop(&self, api: &dyn DartApi) -> Result<()> {
        api.stop_task(self.scheduler_id)
    }
}

// ---------------------------------------------------------------------------
// Parallel weighted tree reduction over client parameter vectors
// ---------------------------------------------------------------------------

/// Flat (single-pass) weighted average: baseline for E7.
///
/// `out[p] = sum_k w_k * x_k[p] / sum_k w_k`
pub fn flat_reduce_weighted<V: AsRef<[f32]> + Sync>(
    vectors: &[V],
    weights: &[f32],
) -> Vec<f32> {
    assert_eq!(vectors.len(), weights.len());
    assert!(!vectors.is_empty());
    let p = vectors[0].as_ref().len();
    let wsum: f32 = weights.iter().sum::<f32>().max(f32::MIN_POSITIVE);
    let mut out = vec![0.0f32; p];
    for (v, &w) in vectors.iter().zip(weights) {
        let v = v.as_ref();
        debug_assert_eq!(v.len(), p);
        let wn = w / wsum;
        for (o, &x) in out.iter_mut().zip(v.iter()) {
            *o += wn * x;
        }
    }
    out
}

/// Tree reduction with parallel leaves: clients are grouped into `fanout`-
/// sized chunks; each chunk's weighted partial sum runs on its own scoped
/// thread (zero copies of the input vectors — the §Perf pass measured the
/// earlier clone-into-`Arc` variant at up to 8x *slower* than the flat
/// loop), and the root combines the partials.  Equivalent to
/// [`flat_reduce_weighted`] up to f32 re-association.
///
/// Scoped threads borrow the inputs directly; the shared
/// [`crate::util::pool::ThreadPool`] cannot do that (its jobs must be
/// `'static`), which is why leaves spawn scoped threads rather than going
/// through the pool.
pub fn tree_reduce_weighted<V: AsRef<[f32]> + Sync>(
    vectors: &[V],
    weights: &[f32],
    fanout: usize,
) -> Vec<f32> {
    assert_eq!(vectors.len(), weights.len());
    assert!(!vectors.is_empty());
    let k = vectors.len();
    let fanout = fanout.max(2);
    if k <= fanout {
        return flat_reduce_weighted(vectors, weights);
    }
    let wsum: f32 = weights.iter().sum::<f32>().max(f32::MIN_POSITIVE);
    let p = vectors[0].as_ref().len();

    // each leaf computes an *unnormalized* weighted partial sum over a
    // fanout-sized chunk of clients, borrowing the inputs directly
    let partials: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..k)
            .step_by(fanout)
            .map(|s| {
                let e = (s + fanout).min(k);
                let vectors = &vectors[s..e];
                let weights = &weights[s..e];
                scope.spawn(move || {
                    let mut acc = vec![0.0f32; p];
                    for (v, &w) in vectors.iter().zip(weights) {
                        for (a, &x) in acc.iter_mut().zip(v.as_ref().iter()) {
                            *a += w * x;
                        }
                    }
                    acc
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // root combine + normalize
    let mut out = vec![0.0f32; p];
    for part in partials {
        for (o, x) in out.iter_mut().zip(part) {
            *o += x;
        }
    }
    for o in out.iter_mut() {
        *o /= wsum;
    }
    out
}

/// P-chunked parallel reduction — the optimized hot path used by
/// [`crate::fact::Aggregation`].  Each thread owns a disjoint slice of the
/// *output* and streams all K inputs over it, so there are no intermediate
/// partial vectors at all and writes never contend.  Bit-identical to
/// [`flat_reduce_weighted`] (same per-coordinate accumulation order).
pub fn parallel_reduce_weighted<V: AsRef<[f32]> + Sync>(
    vectors: &[V],
    weights: &[f32],
    nthreads: usize,
) -> Vec<f32> {
    assert_eq!(vectors.len(), weights.len());
    assert!(!vectors.is_empty());
    let p = vectors[0].as_ref().len();
    let wsum: f32 = weights.iter().sum::<f32>().max(f32::MIN_POSITIVE);
    let nthreads = nthreads.max(1).min(p.max(1));
    let mut out = vec![0.0f32; p];
    if nthreads == 1 || p < 1 << 14 {
        // small problems: thread spawn overhead dominates
        return flat_reduce_weighted(vectors, weights);
    }
    let chunk = p.div_ceil(nthreads);
    std::thread::scope(|scope| {
        for (t, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let start = t * chunk;
            scope.spawn(move || {
                for (v, &w) in vectors.iter().zip(weights) {
                    let wn = w / wsum;
                    let src = &v.as_ref()[start..start + out_chunk.len()];
                    for (o, &x) in out_chunk.iter_mut().zip(src.iter()) {
                        *o += wn * x;
                    }
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::coordinator::device::DeviceSingle;
    use crate::coordinator::task::TaskKind;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn holder(n: usize) -> DeviceHolder {
        DeviceHolder::new(
            (0..n)
                .map(|i| DeviceSingle::new(&format!("d{i}"), HardwareConfig::default()))
                .collect(),
        )
    }

    fn task_for(n: usize) -> Task {
        let dict: BTreeMap<String, crate::json::Json> = (0..n)
            .map(|i| (format!("d{i}"), crate::json::Json::Null))
            .collect();
        Task::new(TaskKind::Default, "learn", dict)
    }

    #[test]
    fn small_task_stays_flat() {
        let agg = Aggregator::new(TaskHandle(1), task_for(4), 1, holder(4), 8);
        assert!(agg.children().is_empty());
        assert_eq!(agg.depth(), 1);
    }

    #[test]
    fn large_task_splits_into_children() {
        let agg = Aggregator::new(TaskHandle(1), task_for(20), 1, holder(20), 8);
        assert!(!agg.children().is_empty());
        assert_eq!(agg.depth(), 2);
        let total: usize = agg.children().iter().map(|c| c.devices.len()).sum();
        assert_eq!(total, 20);
        // balanced within 1
        let sizes: Vec<usize> = agg.children().iter().map(|c| c.devices.len()).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1, "unbalanced: {sizes:?}");
    }

    #[test]
    fn open_params_cached_on_devices() {
        let h = holder(3);
        let mut dict = BTreeMap::new();
        for i in 0..3 {
            dict.insert(format!("d{i}"), crate::json::Json::obj().set("i", i));
        }
        let task = Task::new(TaskKind::Default, "learn", dict);
        let _agg = Aggregator::new(TaskHandle(9), task, 1, h.clone(), 8);
        assert!(h.get("d2").unwrap().open_params(9).is_some());
    }

    #[test]
    fn flat_reduce_matches_hand_computation() {
        let vs = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let out = flat_reduce_weighted(&vs, &[1.0, 3.0]);
        // (1*1 + 3*3)/4 = 2.5 ; (1*2 + 3*4)/4 = 3.5
        assert_eq!(out, vec![2.5, 3.5]);
    }

    #[test]
    fn tree_reduce_matches_flat() {
        let mut rng = Rng::new(3);
        for &(k, p) in &[(3usize, 17usize), (9, 100), (33, 257), (64, 1000)] {
            let vectors: Vec<Vec<f32>> =
                (0..k).map(|_| rng.normal_vec(p)).collect();
            let weights: Vec<f32> =
                (0..k).map(|_| rng.range_f32(0.1, 2.0)).collect();
            let flat = flat_reduce_weighted(&vectors, &weights);
            for fanout in [2, 4, 8] {
                let tree = tree_reduce_weighted(&vectors, &weights, fanout);
                for (a, b) in flat.iter().zip(tree.iter()) {
                    assert!((a - b).abs() < 1e-4, "k={k} fanout={fanout}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn reduces_accept_tensor_buffers_directly() {
        use crate::util::tensorbuf::TensorBuf;
        // TensorBuf implements AsRef<[f32]>, so received buffers feed the
        // reductions without re-materializing Vec<f32>
        let bufs: Vec<TensorBuf> = vec![
            TensorBuf::from_f32_vec(vec![1.0, 2.0]),
            TensorBuf::from_f32_vec(vec![3.0, 4.0]),
        ];
        let out = flat_reduce_weighted(&bufs, &[1.0, 3.0]);
        assert_eq!(out, vec![2.5, 3.5]);
        let tree = tree_reduce_weighted(&bufs, &[1.0, 3.0], 2);
        assert_eq!(out, tree);
    }

    #[test]
    fn reduce_single_client_is_identity() {
        let v = vec![vec![5.0, -1.0, 2.0]];
        let out = flat_reduce_weighted(&v, &[0.7]);
        for (a, b) in out.iter().zip(v[0].iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
