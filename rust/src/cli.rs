//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `feddart <subcommand> [--key value]... [--flag]... [positional]...`

use std::collections::BTreeMap;

use crate::error::{FedError, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Options that take no value (everything else with `--` takes one).
const KNOWN_FLAGS: &[&str] = &["verbose", "quiet", "help", "test-mode", "json"];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if KNOWN_FLAGS.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        FedError::Config(format!("option --{name} needs a value"))
                    })?;
                    out.options.insert(name.to_string(), v.clone());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                FedError::Config(format!("--{name} expects an integer, got '{v}'"))
            }),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                FedError::Config(format!("--{name} expects a number, got '{v}'"))
            }),
        }
    }

    /// A ratio option: must parse as a number in (0, 1] (sampling rates,
    /// quorum fractions).
    pub fn opt_ratio(&self, name: &str, default: f64) -> Result<f64> {
        let v = self.opt_f64(name, default)?;
        if v > 0.0 && v <= 1.0 {
            Ok(v)
        } else {
            Err(FedError::Config(format!(
                "--{name} expects a ratio in (0, 1], got {v}"
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
            .unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("server --port 7777 --clients 8 --verbose extra");
        assert_eq!(a.subcommand.as_deref(), Some("server"));
        assert_eq!(a.opt("port"), Some("7777"));
        assert_eq!(a.opt_usize("clients", 0).unwrap(), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("run --rounds=20 --lr=0.5");
        assert_eq!(a.opt_usize("rounds", 0).unwrap(), 20);
        assert!((a.opt_f64("lr", 0.0).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn missing_value_is_error() {
        let argv: Vec<String> = vec!["run".into(), "--port".into()];
        assert!(Args::parse(&argv).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("run --rounds ten");
        assert!(a.opt_usize("rounds", 0).is_err());
    }

    #[test]
    fn ratio_option_enforces_range() {
        let a = parse("run --sample-rate 0.25 --quorum 1.0");
        assert!((a.opt_ratio("sample-rate", 1.0).unwrap() - 0.25).abs() < 1e-12);
        assert!((a.opt_ratio("quorum", 1.0).unwrap() - 1.0).abs() < 1e-12);
        assert!((a.opt_ratio("missing", 0.5).unwrap() - 0.5).abs() < 1e-12);
        assert!(parse("run --q 0").opt_ratio("q", 1.0).is_err());
        assert!(parse("run --q 1.5").opt_ratio("q", 1.0).is_err());
        assert!(parse("run --q nope").opt_ratio("q", 1.0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.opt_or("addr", "127.0.0.1:0"), "127.0.0.1:0");
        assert_eq!(a.opt_usize("clients", 4).unwrap(), 4);
        assert!(!a.flag("verbose"));
    }
}
