//! Panic-freedom rules for wire-facing and durability-critical modules.
//!
//! The modules in [`PANIC_SCOPE`] parse attacker-controlled bytes (HTTP,
//! JSON, tensor frames, DART transport) or sit on the durability path
//! (round store, FACT server, the round pipeline under `fact::rounds`).  A panic there is a remote crash — or a
//! poisoned lock that cascades one — so these modules must surface
//! failures as typed `FedError`s instead:
//!
//! * `panic-unwrap` — `.unwrap()` / `.expect(..)` calls.  The mutex
//!   idiom `.lock().unwrap()` (and `.read()`/`.write()` for `RwLock`) is
//!   exempt: poisoning only propagates a panic that already happened.
//! * `panic-macro` — `panic!`, `unreachable!`, `todo!`, `unimplemented!`.
//! * `panic-index` — unchecked `expr[..]` indexing.  A single numeric
//!   literal index (fixed offset into a length-checked or compile-time
//!   sized buffer) and the full-range form `[..]` are exempt.
//!
//! Test code (`#[cfg(test)]` / `#[test]`) is never flagged.

use super::lexer::{Tok, TokKind};
use super::{in_scope, Finding, SrcFile};

/// Modules where panics are forbidden.
pub const PANIC_SCOPE: &[&str] = &[
    "http",
    "dart::transport",
    "dart::rest",
    "json",
    "util::tensorbuf",
    "fact::server",
    "fact::rounds",
    "coordinator::round_store",
];

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// `panic-unwrap` + `panic-macro`: unwrap/expect calls and panicking macros.
pub fn check_panic_calls(f: &SrcFile, out: &mut Vec<Finding>) {
    if !in_scope(&f.module, PANIC_SCOPE) {
        return;
    }
    let ts: Vec<&Tok> = f.lexed.toks.iter().filter(|t| !t.test).collect();
    for i in 0..ts.len() {
        let t = ts[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let prv_dot = i > 0 && ts[i - 1].is(".");
        let nxt_paren = ts.get(i + 1).map(|n| n.is("(")).unwrap_or(false);
        if (t.text == "unwrap" || t.text == "expect") && prv_dot && nxt_paren {
            // `.lock().unwrap()` / RwLock `.read()`/`.write()` poisoning idiom
            if t.text == "unwrap"
                && i >= 4
                && ts[i - 2].is(")")
                && ts[i - 3].is("(")
                && matches!(ts[i - 4].text.as_str(), "lock" | "read" | "write")
            {
                continue;
            }
            out.push(Finding {
                rule: "panic-unwrap",
                file: f.rel.clone(),
                line: t.line,
                col: t.col,
                message: format!(
                    "`{}()` in a panic-free module; return a typed error instead",
                    t.text
                ),
            });
        } else if PANIC_MACROS.contains(&t.text.as_str())
            && ts.get(i + 1).map(|n| n.is("!")).unwrap_or(false)
        {
            out.push(Finding {
                rule: "panic-macro",
                file: f.rel.clone(),
                line: t.line,
                col: t.col,
                message: format!(
                    "`{}!` in a panic-free module; return a typed error instead",
                    t.text
                ),
            });
        }
    }
}

/// `panic-index`: unchecked slice/array indexing.
pub fn check_indexing(f: &SrcFile, out: &mut Vec<Finding>) {
    if !in_scope(&f.module, PANIC_SCOPE) {
        return;
    }
    let ts: Vec<&Tok> = f.lexed.toks.iter().filter(|t| !t.test).collect();
    let mut i = 0usize;
    while i < ts.len() {
        let t = ts[i];
        if !(t.kind == TokKind::Punct && t.text == "[") {
            i += 1;
            continue;
        }
        // only index *expressions*: `ident[..]`, `call()[..]`, `a[0][..]` —
        // not array literals, attributes, or type syntax
        let Some(prv) = (i > 0).then(|| ts[i - 1]) else {
            i += 1;
            continue;
        };
        let is_expr = prv.kind == TokKind::Ident || prv.is(")") || prv.is("]");
        let keyword_before = prv.kind == TokKind::Ident
            && matches!(
                prv.text.as_str(),
                "mut" | "dyn" | "return" | "in" | "as" | "if" | "else" | "match" | "box"
            );
        if !is_expr || keyword_before {
            i += 1;
            continue;
        }
        // collect the index tokens up to the matching `]`
        let mut j = i + 1;
        let mut d = 1usize;
        let mut inner: Vec<&Tok> = Vec::new();
        while j < ts.len() && d > 0 {
            if ts[j].is("[") {
                d += 1;
            } else if ts[j].is("]") {
                d -= 1;
            }
            if d > 0 {
                inner.push(ts[j]);
            }
            j += 1;
        }
        if inner.is_empty() {
            i += 1;
            continue;
        }
        let single_literal = inner.len() == 1 && inner[0].kind == TokKind::Num;
        let full_range = inner.iter().all(|tk| tk.is(".."));
        if !single_literal && !full_range {
            let txt: String = inner.iter().map(|tk| tk.text.as_str()).collect();
            out.push(Finding {
                rule: "panic-index",
                file: f.rel.clone(),
                line: t.line,
                col: t.col,
                message: format!(
                    "unchecked slice index `[{txt}]`; use get()/split-checked access"
                ),
            });
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(rel: &str, src: &str) -> Vec<Finding> {
        let f = SrcFile::from_source(rel, src);
        let mut out = Vec::new();
        check_panic_calls(&f, &mut out);
        check_indexing(&f, &mut out);
        out
    }

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn flags_unwrap_expect_and_macros_in_scope() {
        let src = "fn f() { x.unwrap(); y.expect(\"msg\"); panic!(\"boom\"); }";
        let got = run("rust/src/http/server.rs", src);
        assert_eq!(rules(&got), vec!["panic-unwrap", "panic-unwrap", "panic-macro"]);
    }

    #[test]
    fn out_of_scope_modules_are_ignored() {
        let src = "fn f() { x.unwrap(); v[i]; }";
        assert!(run("rust/src/dart/scheduler.rs", src).is_empty());
    }

    #[test]
    fn lock_unwrap_idiom_is_exempt() {
        let src = "fn f() { let g = m.lock().unwrap(); let r = rw.read().unwrap(); }";
        assert!(run("rust/src/http/server.rs", src).is_empty());
    }

    #[test]
    fn flags_dynamic_index_but_not_literal_or_full_range() {
        let src = "fn f(v: &[u8], i: usize) { v[i]; v[0]; v[..]; v[i + 1]; }";
        let got = run("rust/src/json/mod.rs", src);
        assert_eq!(rules(&got), vec!["panic-index", "panic-index"]);
    }

    #[test]
    fn array_literals_attrs_and_types_are_not_indexing() {
        let src = "#[derive(Clone)] struct S { a: [u8; 32] }\n\
                   fn f() -> Vec<u8> { let a = [0u8, 1u8]; vec![1, 2] }";
        assert!(run("rust/src/json/mod.rs", src).is_empty());
    }

    #[test]
    fn strings_comments_and_test_code_are_exempt() {
        let src = "fn f() { let s = \"x.unwrap()\"; } // y.unwrap()\n\
                   #[cfg(test)]\nmod tests { fn t() { z.unwrap(); q[i]; } }";
        assert!(run("rust/src/http/server.rs", src).is_empty());
    }

    #[test]
    fn pragma_suppresses_via_engine() {
        // the pragma itself is honored by Linter::run; here we just check
        // the raw finding is produced so the engine has something to drop
        let src = "// feddart-lint: allow(panic-unwrap): fixture\nfn f() { x.unwrap(); }";
        let f = SrcFile::from_source("rust/src/http/server.rs", src);
        let mut out = Vec::new();
        check_panic_calls(&f, &mut out);
        assert_eq!(out.len(), 1);
        assert!(f.lexed.pragmas.allows("panic-unwrap", out[0].line));
    }
}
