//! Lightweight Rust tokenizer for the `feddart lint` analyzer.
//!
//! This is not a full Rust lexer — it is exactly enough structure for the
//! project-invariant rules in this module: identifiers, literals (strings,
//! raw strings, byte strings, chars, numbers), lifetimes, and punctuation,
//! each carrying a source position.  Comments never enter the token stream
//! (they are collected separately so inline `// feddart-lint: allow(..)`
//! pragmas can be resolved), and string/char contents are opaque — a
//! `".unwrap()"` inside a string literal can never look like a method call.
//!
//! Two pieces of higher-level structure are computed here because every
//! rule needs them:
//!
//! * **test regions** — tokens inside a `#[cfg(test)]`-gated item (or a
//!   bare `#[test]` function) are flagged so rules skip test code, where
//!   `unwrap()` on known-good fixtures is idiomatic;
//! * **pragmas** — `// feddart-lint: allow(rule-a, rule-b)` suppresses
//!   those rules on the same and the following source line, and
//!   `// feddart-lint: allow-file(rule)` suppresses a rule for the whole
//!   file.  Pragma comments should carry a justification after the
//!   closing parenthesis (`// feddart-lint: allow(panic-index): const
//!   table, mask bounds the index`).

use std::collections::{BTreeMap, BTreeSet};

/// Token classification — deliberately coarse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Numeric literal (integer or float, any base, with suffix).
    Num,
    /// String literal: `"…"`, `r#"…"#`, `b"…"` — content opaque.
    Str,
    /// Char or byte literal: `'x'`, `b'\n'`.
    Char,
    /// Lifetime: `'a`.
    Lifetime,
    /// Punctuation; multi-char operators (`==`, `::`, `..`) are one token.
    Punct,
}

/// One token with its source position.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Kind of token.
    pub kind: TokKind,
    /// Raw text (for `Str`, includes the quotes).
    pub text: String,
    /// 1-based source line.
    pub line: u32,
    /// 1-based source column.
    pub col: u32,
    /// Inside a `#[cfg(test)]`-gated item (rules skip these).
    pub test: bool,
}

impl Tok {
    fn new(kind: TokKind, text: impl Into<String>, line: u32, col: u32) -> Tok {
        Tok { kind, text: text.into(), line, col, test: false }
    }

    /// `true` for a punct token with exactly this text.
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }

    /// `true` for an identifier with exactly this text.
    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == TokKind::Ident && self.text == text
    }
}

/// Suppression pragmas collected from a file's comments.
#[derive(Debug, Default, Clone)]
pub struct Pragmas {
    /// rule id → set of suppressed lines (pragma line + the next line).
    pub line_allow: BTreeMap<String, BTreeSet<u32>>,
    /// rule ids suppressed for the whole file.
    pub file_allow: BTreeSet<String>,
}

impl Pragmas {
    /// Whether `rule` is suppressed at `line`.
    pub fn allows(&self, rule: &str, line: u32) -> bool {
        if self.file_allow.contains(rule) {
            return true;
        }
        self.line_allow.get(rule).map(|s| s.contains(&line)).unwrap_or(false)
    }
}

/// Tokenized source file plus its comment-derived pragmas.
#[derive(Debug)]
pub struct Lexed {
    /// Token stream (comments excluded, test regions marked).
    pub toks: Vec<Tok>,
    /// Pragmas parsed from comments.
    pub pragmas: Pragmas,
}

const MULTI_PUNCT: &[&str] = &[
    "..=", "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "<<", ">>",
    "+=", "-=", "*=", "/=", "..", "%=", "^=", "|=", "&=",
];

/// Tokenize `src`, collect pragmas, and mark `#[cfg(test)]` regions.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut toks: Vec<Tok> = Vec::new();
    let mut pragmas = Pragmas::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut col = 1u32;

    // advance k bytes, tracking line/col
    macro_rules! adv {
        ($k:expr) => {{
            let k: usize = $k;
            for _ in 0..k {
                if i < b.len() && b[i] == b'\n' {
                    line += 1;
                    col = 1;
                } else {
                    col += 1;
                }
                i += 1;
            }
        }};
    }

    while i < b.len() {
        let c = b[i];
        if c == b' ' || c == b'\t' || c == b'\r' || c == b'\n' {
            adv!(1);
            continue;
        }
        // line comment (also doc comments)
        if src[i..].starts_with("//") {
            let end = src[i..].find('\n').map(|k| i + k).unwrap_or(b.len());
            collect_pragma(&mut pragmas, line, &src[i..end]);
            adv!(end - i);
            continue;
        }
        // block comment, nested
        if src[i..].starts_with("/*") {
            let start_line = line;
            let mut depth = 0usize;
            let mut j = i;
            while j < b.len() {
                if src[j..].starts_with("/*") {
                    depth += 1;
                    j += 2;
                } else if src[j..].starts_with("*/") {
                    depth -= 1;
                    j += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    j += 1;
                }
            }
            collect_pragma(&mut pragmas, start_line, &src[i..j.min(b.len())]);
            adv!(j - i);
            continue;
        }
        // raw / byte-raw strings: r"…", r#"…"#, br#"…"#
        if let Some(len) = raw_string_len(&src[i..]) {
            toks.push(Tok::new(TokKind::Str, &src[i..i + len], line, col));
            adv!(len);
            continue;
        }
        // plain / byte strings
        if c == b'"' || src[i..].starts_with("b\"") {
            let open = if c == b'"' { 1 } else { 2 };
            let mut j = i + open;
            while j < b.len() {
                if b[j] == b'\\' {
                    j = (j + 2).min(b.len());
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            toks.push(Tok::new(TokKind::Str, &src[i..j], line, col));
            adv!(j - i);
            continue;
        }
        // lifetime vs char literal
        if c == b'\'' || src[i..].starts_with("b'") {
            let open = if c == b'\'' { 1 } else { 2 };
            if c == b'\'' {
                if let Some(len) = lifetime_len(&src[i..]) {
                    toks.push(Tok::new(TokKind::Lifetime, &src[i..i + len], line, col));
                    adv!(len);
                    continue;
                }
            }
            let mut j = i + open;
            while j < b.len() {
                if b[j] == b'\\' {
                    j = (j + 2).min(b.len());
                } else if b[j] == b'\'' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            toks.push(Tok::new(TokKind::Char, &src[i..j], line, col));
            adv!(j - i);
            continue;
        }
        // identifier / keyword
        if c == b'_' || c.is_ascii_alphabetic() {
            let mut j = i + 1;
            while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                j += 1;
            }
            toks.push(Tok::new(TokKind::Ident, &src[i..j], line, col));
            adv!(j - i);
            continue;
        }
        // numeric literal: digits, hex/oct/bin, underscores, one float
        // part, exponent, suffix — but never eat a `..` range or a method
        // call on a literal (`1.max(2)`)
        if c.is_ascii_digit() {
            let mut j = i + 1;
            let mut seen_dot = false;
            while j < b.len() {
                let d = b[j];
                if d == b'_' || d.is_ascii_alphanumeric() {
                    j += 1;
                } else if d == b'.' && !seen_dot {
                    if j + 1 < b.len() && (b[j + 1] == b'.' || b[j + 1] == b'_' || b[j + 1].is_ascii_alphabetic()) {
                        break; // range or method call
                    }
                    seen_dot = true;
                    j += 1;
                } else if (d == b'+' || d == b'-')
                    && (b[j - 1] == b'e' || b[j - 1] == b'E')
                    && seen_dot
                {
                    j += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok::new(TokKind::Num, &src[i..j], line, col));
            adv!(j - i);
            continue;
        }
        // punctuation
        let mut matched = false;
        for p in MULTI_PUNCT {
            if src[i..].starts_with(p) {
                toks.push(Tok::new(TokKind::Punct, *p, line, col));
                adv!(p.len());
                matched = true;
                break;
            }
        }
        if !matched {
            toks.push(Tok::new(TokKind::Punct, &src[i..i + 1], line, col));
            adv!(1);
        }
    }

    mark_test_regions(&mut toks);
    Lexed { toks, pragmas }
}

/// Length of a raw string literal at the start of `s`, or `None`.
fn raw_string_len(s: &str) -> Option<usize> {
    let body = s.strip_prefix("br").or_else(|| s.strip_prefix('r').map(|x| x))?;
    let prefix_len = s.len() - body.len();
    let hashes = body.len() - body.trim_start_matches('#').len();
    let after = &body[hashes..];
    if !after.starts_with('"') {
        return None;
    }
    let close: String = format!("\"{}", "#".repeat(hashes));
    match after[1..].find(&close) {
        Some(k) => Some(prefix_len + hashes + 1 + k + close.len()),
        None => Some(s.len()), // unterminated — consume the rest
    }
}

/// Length of a lifetime token (`'a`, `'static`) at the start of `s`, or
/// `None` when this is a char literal instead.
fn lifetime_len(s: &str) -> Option<usize> {
    let rest = s.strip_prefix('\'')?;
    let ident_len = rest
        .char_indices()
        .take_while(|(k, c)| if *k == 0 { c.is_alphabetic() || *c == '_' } else { c.is_alphanumeric() || *c == '_' })
        .count();
    if ident_len == 0 {
        return None;
    }
    // 'a' is a char literal; 'a followed by anything else is a lifetime
    if rest[ident_len..].starts_with('\'') {
        return None;
    }
    Some(1 + ident_len)
}

/// Parse a `feddart-lint:` pragma out of one comment's text.
fn collect_pragma(pragmas: &mut Pragmas, line: u32, comment: &str) {
    let body = comment.trim_start_matches(['/', '!', '*', ' ', '\t']);
    let Some(rest) = body.strip_prefix("feddart-lint:") else { return };
    let rest = rest.trim_start();
    let (file_wide, rest) = if let Some(r) = rest.strip_prefix("allow-file(") {
        (true, r)
    } else if let Some(r) = rest.strip_prefix("allow(") {
        (false, r)
    } else {
        return;
    };
    let Some(close) = rest.find(')') else { return };
    for rule in rest[..close].split(',') {
        let rule = rule.trim();
        if rule.is_empty() {
            continue;
        }
        if file_wide {
            pragmas.file_allow.insert(rule.to_string());
        } else {
            let lines = pragmas.line_allow.entry(rule.to_string()).or_default();
            lines.insert(line);
            lines.insert(line + 1);
        }
    }
}

/// Mark tokens inside `#[cfg(test)]`-gated items and `#[test]` functions.
///
/// After such an attribute, any further attributes are skipped, then the
/// item is consumed through its terminating `;` or its balanced `{ … }`
/// block, and every token in that span is flagged `test`.
fn mark_test_regions(toks: &mut [Tok]) {
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].is("#") && i + 1 < toks.len() && toks[i + 1].is("[")) {
            i += 1;
            continue;
        }
        // collect the attribute's inner text
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut inner = String::new();
        while j < toks.len() && depth > 0 {
            if toks[j].is("[") {
                depth += 1;
            } else if toks[j].is("]") {
                depth -= 1;
            }
            if depth > 0 {
                inner.push_str(&toks[j].text);
            }
            j += 1;
        }
        let is_test_attr = inner.starts_with("cfg(test") || inner == "test";
        if !is_test_attr {
            i = j;
            continue;
        }
        // skip any further attributes
        let mut k = j;
        while k + 1 < toks.len() && toks[k].is("#") && toks[k + 1].is("[") {
            let mut d = 1usize;
            k += 2;
            while k < toks.len() && d > 0 {
                if toks[k].is("[") {
                    d += 1;
                } else if toks[k].is("]") {
                    d -= 1;
                }
                k += 1;
            }
        }
        // consume the item: through `;` or a balanced brace block at
        // bracket depth 0
        let mut d = 0isize;
        while k < toks.len() {
            let t = &toks[k].text;
            if t == "(" || t == "[" {
                d += 1;
            } else if t == ")" || t == "]" {
                d -= 1;
            } else if t == ";" && d == 0 {
                k += 1;
                break;
            } else if t == "{" && d == 0 {
                let mut bd = 1usize;
                k += 1;
                while k < toks.len() && bd > 0 {
                    if toks[k].is("{") {
                        bd += 1;
                    } else if toks[k].is("}") {
                        bd -= 1;
                    }
                    k += 1;
                }
                break;
            }
            k += 1;
        }
        for t in toks.iter_mut().take(k).skip(i) {
            t.test = true;
        }
        i = k;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).toks.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let toks = kinds(r#"let s = ".unwrap()"; // .expect( in comment"#);
        assert!(toks.iter().all(|(k, t)| *k != TokKind::Ident || t != "unwrap"));
        assert!(toks.iter().all(|(_, t)| t != "expect"));
    }

    #[test]
    fn raw_strings_swallow_quotes() {
        let toks = kinds(r##"let s = r#"a "quoted" panic!("x")"#; x"##);
        let idents: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, vec!["let", "s", "x"]);
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let toks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; }");
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Lifetime && t == "'a"));
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Char && t == "'x'"));
    }

    #[test]
    fn numbers_do_not_eat_ranges_or_method_calls() {
        let toks = kinds("for i in 0..10 { 1.max(2); 1.5e-3; }");
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Punct && t == ".."));
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Ident && t == "max"));
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Num && t == "1.5e-3"));
    }

    #[test]
    fn cfg_test_items_are_marked() {
        let lexed = lex(
            "fn live() { a.unwrap(); }\n\
             #[cfg(test)]\nmod tests {\n fn t() { b.unwrap(); }\n}\n\
             fn live2() {}",
        );
        let unwraps: Vec<bool> = lexed
            .toks
            .iter()
            .filter(|t| t.is_ident("unwrap"))
            .map(|t| t.test)
            .collect();
        assert_eq!(unwraps, vec![false, true]);
        assert!(lexed.toks.iter().any(|t| t.is_ident("live2") && !t.test));
    }

    #[test]
    fn pragmas_parse_and_scope() {
        let lexed = lex(
            "// feddart-lint: allow(panic-unwrap): checked above\n\
             x.unwrap();\n\
             // feddart-lint: allow-file(lock-io)\n",
        );
        assert!(lexed.pragmas.allows("panic-unwrap", 1));
        assert!(lexed.pragmas.allows("panic-unwrap", 2));
        assert!(!lexed.pragmas.allows("panic-unwrap", 3));
        assert!(lexed.pragmas.allows("lock-io", 999));
    }
}
