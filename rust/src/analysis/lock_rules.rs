//! Lock-discipline rules.
//!
//! The project's lock hierarchy (documented in docs/ANALYSIS.md and
//! enforced here) orders every ranked lock class; threads must acquire
//! in increasing rank:
//!
//! ```text
//! sched.workers(1) < sched.shard(2) < sched.queue(3) < sched.hardware(4)
//!     < metrics.registry(5) < telemetry.ring(6)
//! ```
//!
//! * `lock-order` — acquiring a lower-ranked class while a guard of a
//!   higher-ranked class is still live in the same scope.
//! * `lock-io` — calling blocking durability I/O (`sync_all`,
//!   `sync_data`, `fsync`) while *any* named lock guard is held; fsync
//!   latency under a hot lock stalls every peer thread.
//!
//! Guard lifetime model (intra-procedural, matching how the codebase is
//! written): a guard is **named** — lives to the end of its block —
//! only when the lock call chain ends its `let` statement
//! (`let g = x.lock().unwrap();`).  A chain that continues
//! (`let v = x.lock().unwrap().pop();`) binds the popped value; the
//! guard itself is a temporary dying at the `;`.  Guards passed into
//! helper functions are not tracked across the call — see the known
//! limitations section in docs/ANALYSIS.md.

use super::lexer::{Tok, TokKind};
use super::{Finding, SrcFile};

/// (module prefix, receiver identifier, class name, rank).
const LOCK_CLASSES: &[(&str, &str, &str, u32)] = &[
    ("dart::scheduler", "workers", "sched.workers", 1),
    ("dart::scheduler", "shard", "sched.shard", 2),
    ("dart::scheduler", "shards", "sched.shard", 2),
    ("dart::scheduler", "queue", "sched.queue", 3),
    ("dart::scheduler", "hardware", "sched.hardware", 4),
    ("metrics", "counters", "metrics.registry", 5),
    ("metrics", "gauges", "metrics.registry", 5),
    ("metrics", "histograms", "metrics.registry", 5),
    ("dart::scheduler", "metrics", "metrics.registry", 5),
    ("telemetry", "shard_for", "telemetry.ring", 6),
    ("telemetry", "sh", "telemetry.ring", 6),
    ("telemetry", "shards", "telemetry.ring", 6),
];

const BLOCKING_IO: &[&str] = &["sync_all", "sync_data", "fsync"];

/// Human-readable declared order, used in messages and docs tests.
pub const DECLARED_ORDER: &str = "sched.workers < sched.shard < sched.queue < \
                                  sched.hardware < metrics.registry < telemetry.ring";

fn lock_class(module: &str, recv: &str) -> Option<(&'static str, u32)> {
    LOCK_CLASSES
        .iter()
        .find(|(m, r, _, _)| recv == *r && (module == *m || module.starts_with(*m)))
        .map(|(_, _, cls, rank)| (*cls, *rank))
}

struct Held {
    cls: String,
    rank: Option<u32>,
    depth: i32,
    line: u32,
    named: bool,
}

/// `lock-order` + `lock-io` over one file.
pub fn check_locks(f: &SrcFile, out: &mut Vec<Finding>) {
    let ts: Vec<&Tok> = f.lexed.toks.iter().filter(|t| !t.test).collect();
    let mut held: Vec<Held> = Vec::new();
    let mut depth: i32 = 0;
    let mut stmt_has_let = false;

    let mut i = 0usize;
    while i < ts.len() {
        let t = ts[i];
        if t.is("{") {
            depth += 1;
        } else if t.is("}") {
            depth -= 1;
            held.retain(|h| h.depth <= depth);
        } else if t.is(";") {
            held.retain(|h| h.named);
            stmt_has_let = false;
        } else if t.is_ident("let") {
            stmt_has_let = true;
        } else if t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "lock" | "read" | "write")
            && i + 2 < ts.len()
            && ts[i + 1].is("(")
            && ts[i + 2].is(")")
            && i >= 2
            && ts[i - 1].is(".")
        {
            let recv = receiver_ident(&ts, i - 2);
            let class = recv.and_then(|r| lock_class(&f.module, r));
            if let Some((cls, rank)) = class {
                for h in &held {
                    if let Some(hrank) = h.rank {
                        if rank < hrank && h.cls != cls {
                            out.push(Finding {
                                rule: "lock-order",
                                file: f.rel.clone(),
                                line: t.line,
                                col: t.col,
                                message: format!(
                                    "acquires {cls} (rank {rank}) while holding {} \
                                     (rank {hrank}) from line {}; declared order is {}",
                                    h.cls, h.line, DECLARED_ORDER
                                ),
                            });
                            break;
                        }
                    }
                }
            }
            // named iff the lock chain (through .unwrap()/.expect(..))
            // terminates the `let` statement
            let mut named = false;
            if stmt_has_let {
                let mut k = i + 3;
                while k + 2 < ts.len()
                    && ts[k].is(".")
                    && (ts[k + 1].is_ident("unwrap") || ts[k + 1].is_ident("expect"))
                    && ts[k + 2].is("(")
                {
                    let mut d = 1usize;
                    k += 3;
                    while k < ts.len() && d > 0 {
                        if ts[k].is("(") {
                            d += 1;
                        } else if ts[k].is(")") {
                            d -= 1;
                        }
                        k += 1;
                    }
                }
                named = k < ts.len() && (ts[k].is(";") || ts[k].is("?"));
            }
            if class.is_some() || recv.is_some() {
                held.push(Held {
                    cls: class
                        .map(|(c, _)| c.to_string())
                        .unwrap_or_else(|| format!("?{}", recv.unwrap_or("_"))),
                    rank: class.map(|(_, r)| r),
                    depth,
                    line: t.line,
                    named,
                });
            }
        } else if t.kind == TokKind::Ident
            && BLOCKING_IO.contains(&t.text.as_str())
            && i + 1 < ts.len()
            && ts[i + 1].is("(")
            && i >= 1
            && ts[i - 1].is(".")
        {
            if let Some(h) = held.iter().find(|h| h.named) {
                out.push(Finding {
                    rule: "lock-io",
                    file: f.rel.clone(),
                    line: t.line,
                    col: t.col,
                    message: format!(
                        "blocking `{}()` while holding lock guard ({}) acquired at line {}",
                        t.text, h.cls, h.line
                    ),
                });
            }
        }
        i += 1;
    }
}

/// The identifier naming the receiver of a lock call whose `.` sits just
/// after `ts[j]` — walks back over one trailing call or index expression.
fn receiver_ident<'a>(ts: &[&'a Tok], j: usize) -> Option<&'a str> {
    let t = ts[j];
    if t.is(")") || t.is("]") {
        let (open, close) = if t.is(")") { ("(", ")") } else { ("[", "]") };
        let mut d = 1usize;
        let mut k = j;
        while k > 0 && d > 0 {
            k -= 1;
            if ts[k].is(close) {
                d += 1;
            } else if ts[k].is(open) {
                d -= 1;
            }
        }
        if d == 0 && k > 0 && ts[k - 1].kind == TokKind::Ident {
            return Some(&ts[k - 1].text);
        }
        return None;
    }
    (t.kind == TokKind::Ident).then(|| t.text.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(rel: &str, src: &str) -> Vec<Finding> {
        let f = SrcFile::from_source(rel, src);
        let mut out = Vec::new();
        check_locks(&f, &mut out);
        out
    }

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn flags_out_of_order_acquisition() {
        let src = "fn f(&self) { let q = self.queue.lock().unwrap(); \
                   let w = self.workers.lock().unwrap(); }";
        let got = run("rust/src/dart/scheduler.rs", src);
        assert_eq!(rules(&got), vec!["lock-order"]);
        assert!(got[0].message.contains("sched.workers (rank 1)"));
    }

    #[test]
    fn in_order_acquisition_passes() {
        let src = "fn f(&self) { let w = self.workers.lock().unwrap(); \
                   let q = self.queue.lock().unwrap(); }";
        assert!(run("rust/src/dart/scheduler.rs", src).is_empty());
    }

    #[test]
    fn temporary_guard_dies_at_statement_end() {
        // the queue guard is a temporary (chain continues past unwrap),
        // so the workers acquisition on the next statement is clean
        let src = "fn f(&self) { let popped = self.queue.lock().unwrap().pop_front(); \
                   let w = self.workers.lock().unwrap(); }";
        assert!(run("rust/src/dart/scheduler.rs", src).is_empty());
    }

    #[test]
    fn guard_scope_ends_with_block() {
        let src = "fn f(&self) { { let q = self.queue.lock().unwrap(); } \
                   let w = self.workers.lock().unwrap(); }";
        assert!(run("rust/src/dart/scheduler.rs", src).is_empty());
    }

    #[test]
    fn flags_fsync_under_named_guard() {
        let src = "fn f(&self) { let g = self.inner.lock().unwrap(); \
                   self.file.sync_all()?; }";
        let got = run("rust/src/coordinator/wal.rs", src);
        assert_eq!(rules(&got), vec!["lock-io"]);
    }

    #[test]
    fn fsync_after_guard_dropped_passes() {
        let src = "fn f(&self) { { let g = self.inner.lock().unwrap(); } \
                   self.file.sync_all()?; }";
        assert!(run("rust/src/coordinator/wal.rs", src).is_empty());
    }

    #[test]
    fn unranked_receivers_do_not_trip_ordering() {
        let src = "fn f(&self) { let a = self.inner.lock().unwrap(); \
                   let b = self.other.lock().unwrap(); }";
        assert!(run("rust/src/coordinator/round_store.rs", src).is_empty());
    }

    #[test]
    fn indexed_receiver_resolves_through_brackets() {
        let src = "fn f(&self) { let h = self.hardware.lock().unwrap(); \
                   let s = self.shards[i].lock().unwrap(); }";
        let got = run("rust/src/dart/scheduler.rs", src);
        assert_eq!(rules(&got), vec!["lock-order"]);
    }
}
