//! Report rendering for `feddart lint` — human text and machine JSON.

use super::Report;
use crate::json::Json;

/// `file:line:col: [rule] message` lines plus a summary footer.
pub fn render_text(r: &Report) -> String {
    let mut s = String::new();
    for f in &r.findings {
        s.push_str(&format!(
            "{}:{}:{}: [{}] {}\n",
            f.file, f.line, f.col, f.rule, f.message
        ));
    }
    s.push_str(&format!(
        "{} finding(s); {} file(s) scanned, {} rule(s) run\n",
        r.findings.len(),
        r.files_scanned,
        r.rules_run.len()
    ));
    s
}

/// Stable JSON shape consumed by the CI lint job's report artifact.
pub fn render_json(r: &Report) -> String {
    let findings: Vec<Json> = r
        .findings
        .iter()
        .map(|f| {
            Json::obj()
                .set("rule", f.rule)
                .set("file", f.file.as_str())
                .set("line", f.line as usize)
                .set("col", f.col as usize)
                .set("message", f.message.as_str())
        })
        .collect();
    let rules: Vec<Json> = r.rules_run.iter().map(|&x| Json::from(x)).collect();
    Json::obj()
        .set("ok", r.findings.is_empty())
        .set("findings", findings)
        .set("files_scanned", r.files_scanned)
        .set("rules_run", rules)
        .to_pretty()
}

#[cfg(test)]
mod tests {
    use super::super::Finding;
    use super::*;

    fn sample() -> Report {
        Report {
            findings: vec![Finding {
                rule: "panic-unwrap",
                file: "rust/src/http/server.rs".to_string(),
                line: 12,
                col: 9,
                message: "`unwrap()` in a panic-free module".to_string(),
            }],
            files_scanned: 3,
            rules_run: vec!["panic-unwrap", "panic-macro"],
        }
    }

    #[test]
    fn text_has_location_and_summary() {
        let out = render_text(&sample());
        assert!(out.contains("rust/src/http/server.rs:12:9: [panic-unwrap]"));
        assert!(out.contains("1 finding(s); 3 file(s) scanned, 2 rule(s) run"));
    }

    #[test]
    fn json_is_parseable_and_complete() {
        let out = render_json(&sample());
        let j = Json::parse(&out).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        let f = j.get("findings").unwrap().idx(0).unwrap();
        assert_eq!(f.get("rule").unwrap().as_str(), Some("panic-unwrap"));
        assert_eq!(f.get("line").unwrap().as_usize(), Some(12));
        assert_eq!(j.get("rules_run").unwrap().as_arr().unwrap().len(), 2);
    }
}
