//! Crypto-hygiene rules for the privacy stack.
//!
//! Scope: `privacy::*` and `util::hmacsha` ([`CRYPTO_SCOPE`]).
//!
//! * `crypto-ct-eq` — `==` / `!=` where either side is a secret-bearing
//!   identifier (see [`is_secret_ident`]).  Early-exit comparison leaks
//!   a timing oracle on MACs, shares, and keys; use
//!   `util::hmacsha::ct_eq`.  Method-call results (`x.verify() == true`)
//!   are not flagged — only direct secret operands.
//! * `crypto-secret-debug` — `#[derive(Debug)]` on a struct with
//!   secret-named fields.  Debug output reaches logs and panics; write a
//!   manual impl that redacts the secret fields.
//! * `crypto-secret-leak` — a secret-bearing identifier (or `{secret}`
//!   inline capture) inside `format!` / `println!` / log-macro
//!   arguments.  Non-secret projections (`shares.len()`,
//!   `key.is_empty()`) are exempt.
//! * `crypto-weak-rng` — constructing the deterministic `util::rng::Rng`
//!   inside a key-material module ([`WEAK_RNG_SCOPE`]); key and noise
//!   entropy must come from `OsRng` / the `NoiseSource` seam.

use super::lexer::{Tok, TokKind};
use super::{in_scope, Finding, SrcFile};

/// Modules holding secret material.
pub const CRYPTO_SCOPE: &[&str] = &["privacy", "util::hmacsha"];

/// Modules that generate key material or DP noise and must use a CSPRNG.
pub const WEAK_RNG_SCOPE: &[&str] = &["privacy::keys", "privacy::shamir", "privacy::dp"];

const SECRET_WORDS: &[&str] = &[
    "secret", "secrets", "seed", "seeds", "share", "shares", "sk", "privkey", "passphrase",
];

/// Whether an identifier names secret material.  Matches whole
/// underscore-separated words from [`SECRET_WORDS`], plus anything
/// key-like (`key`, `keys`, `*key`) that is not explicitly public.
pub fn is_secret_ident(name: &str) -> bool {
    let lower = name.to_ascii_lowercase();
    let parts: Vec<&str> = lower.split('_').collect();
    if parts.iter().any(|p| SECRET_WORDS.contains(p)) {
        return true;
    }
    if parts.contains(&"key") || parts.contains(&"keys") || lower.ends_with("key") {
        return !(lower.contains("pub") || lower.contains("public"));
    }
    false
}

/// Final path segment of the expression ending just before `ts[i]`, and
/// whether that expression is a call result.
fn path_back<'a>(ts: &[&'a Tok], i: usize) -> (Option<&'a str>, bool) {
    if i == 0 {
        return (None, false);
    }
    let mut j = i - 1;
    if ts[j].is(")") {
        // method call result: find the callee name
        let mut d = 1usize;
        loop {
            if j == 0 {
                return (None, true);
            }
            j -= 1;
            if ts[j].is(")") {
                d += 1;
            } else if ts[j].is("(") {
                d -= 1;
                if d == 0 {
                    break;
                }
            }
        }
        if j > 0 && ts[j - 1].kind == TokKind::Ident {
            return (Some(&ts[j - 1].text), true);
        }
        return (None, true);
    }
    if ts[j].kind == TokKind::Ident {
        return (Some(&ts[j].text), false);
    }
    (None, false)
}

/// First meaningful identifier after `ts[i]` (skipping `&`, `*`, `(` and
/// `self`, following `.`/`::` paths), and whether it is called.
fn path_fwd<'a>(ts: &[&'a Tok], i: usize) -> (Option<&'a str>, bool) {
    let mut j = i + 1;
    let mut last: Option<&'a str> = None;
    while j < ts.len() {
        let t = ts[j];
        if t.kind == TokKind::Ident && t.text != "self" {
            last = Some(&t.text);
            j += 1;
            if j < ts.len() && (ts[j].is(".") || ts[j].is("::")) {
                j += 1;
                continue;
            }
            let called = j < ts.len() && ts[j].is("(");
            return (last, called);
        }
        if t.is("&") || t.is("*") || t.is("(") || t.is_ident("self") {
            j += 1;
            continue;
        }
        break;
    }
    (last, false)
}

/// `crypto-ct-eq`: non-constant-time comparison of secret material.
pub fn check_ct_eq(f: &SrcFile, out: &mut Vec<Finding>) {
    if !in_scope(&f.module, CRYPTO_SCOPE) {
        return;
    }
    let ts: Vec<&Tok> = f.lexed.toks.iter().filter(|t| !t.test).collect();
    for i in 0..ts.len() {
        let t = ts[i];
        if !(t.kind == TokKind::Punct && (t.text == "==" || t.text == "!=")) {
            continue;
        }
        let (ln, lcall) = path_back(&ts, i);
        let (rn, rcall) = path_fwd(&ts, i);
        for (name, is_call) in [(ln, lcall), (rn, rcall)] {
            if let Some(name) = name {
                if !is_call && is_secret_ident(name) {
                    out.push(Finding {
                        rule: "crypto-ct-eq",
                        file: f.rel.clone(),
                        line: t.line,
                        col: t.col,
                        message: format!(
                            "`{}` on secret-bearing `{name}`; use util::hmacsha::ct_eq",
                            t.text
                        ),
                    });
                    break;
                }
            }
        }
    }
}

const FMT_MACROS: &[&str] = &[
    "format", "println", "print", "eprintln", "write", "writeln", "debug", "info", "warn",
    "error", "trace",
];

/// `{ident}` / `{ident:...}` inline captures in a format string literal.
fn inline_captures(text: &str) -> Vec<String> {
    let b = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        if b[i] != b'{' {
            i += 1;
            continue;
        }
        if i + 1 < b.len() && b[i + 1] == b'{' {
            i += 2; // escaped brace
            continue;
        }
        let mut j = i + 1;
        while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
            j += 1;
        }
        if j > i + 1
            && !b[i + 1].is_ascii_digit()
            && j < b.len()
            && (b[j] == b'}' || b[j] == b':')
        {
            out.push(text[i + 1..j].to_string());
        }
        i = j.max(i + 1);
    }
    out
}

/// Whether the secret identifier at `ts[j]` is immediately projected
/// through a non-secret accessor (`.len()`, `.is_empty()`).
fn projected_non_secret(ts: &[&Tok], j: usize) -> bool {
    j + 3 < ts.len()
        && ts[j + 1].is(".")
        && (ts[j + 2].is_ident("len") || ts[j + 2].is_ident("is_empty"))
        && ts[j + 3].is("(")
}

/// `crypto-secret-debug` + `crypto-secret-leak`.
pub fn check_secret_exposure(f: &SrcFile, out: &mut Vec<Finding>) {
    if !in_scope(&f.module, CRYPTO_SCOPE) {
        return;
    }
    let ts: Vec<&Tok> = f.lexed.toks.iter().filter(|t| !t.test).collect();

    // (a) #[derive(.. Debug ..)] on a struct with secret-named fields
    for i in 0..ts.len() {
        if !(ts[i].is_ident("derive") && i >= 2 && ts[i - 1].is("[") && ts[i - 2].is("#")) {
            continue;
        }
        let mut j = i + 1;
        let mut d = 0usize;
        let mut has_debug = false;
        while j < ts.len() {
            if ts[j].is("(") {
                d += 1;
            } else if ts[j].is(")") {
                d -= 1;
                if d == 0 {
                    break;
                }
            } else if ts[j].is_ident("Debug") {
                has_debug = true;
            }
            j += 1;
        }
        if !has_debug {
            continue;
        }
        // the following item must be a struct (enums with secret payloads
        // are caught through their naming at the use sites)
        let mut k = j;
        while k < ts.len() && !ts[k].is_ident("struct") && !ts[k].is_ident("enum") {
            if ts[k].is("{") {
                break;
            }
            k += 1;
        }
        if k >= ts.len() || !ts[k].is_ident("struct") {
            continue;
        }
        let name = ts.get(k + 1).map(|t| t.text.as_str()).unwrap_or("?");
        let mut m = k;
        while m < ts.len() && !ts[m].is("{") {
            if ts[m].is(";") {
                break;
            }
            m += 1;
        }
        if m >= ts.len() || !ts[m].is("{") {
            continue;
        }
        let mut d = 1usize;
        m += 1;
        let mut secret_fields: Vec<&str> = Vec::new();
        while m < ts.len() && d > 0 {
            if ts[m].is("{") {
                d += 1;
            } else if ts[m].is("}") {
                d -= 1;
            } else if d == 1
                && ts[m].is(":")
                && m > 0
                && ts[m - 1].kind == TokKind::Ident
                && is_secret_ident(&ts[m - 1].text)
            {
                secret_fields.push(&ts[m - 1].text);
            }
            m += 1;
        }
        if !secret_fields.is_empty() {
            out.push(Finding {
                rule: "crypto-secret-debug",
                file: f.rel.clone(),
                line: ts[i].line,
                col: ts[i].col,
                message: format!(
                    "#[derive(Debug)] on `{name}` exposes secret field(s) {}; \
                     write a redacting manual impl",
                    secret_fields.join(", ")
                ),
            });
        }
    }

    // (b) secret identifiers in format!/log-macro arguments
    for i in 0..ts.len() {
        if !(ts[i].kind == TokKind::Ident
            && FMT_MACROS.contains(&ts[i].text.as_str())
            && ts.get(i + 1).map(|t| t.is("!")).unwrap_or(false))
        {
            continue;
        }
        let Some(open) = ts.get(i + 2) else { continue };
        let (opn, close) = match open.text.as_str() {
            "(" => ("(", ")"),
            "[" => ("[", "]"),
            _ => continue,
        };
        let mut d = 1usize;
        let mut j = i + 3;
        while j < ts.len() && d > 0 {
            let t = ts[j];
            if t.is(opn) {
                d += 1;
            } else if t.is(close) {
                d -= 1;
            } else if t.kind == TokKind::Ident
                && is_secret_ident(&t.text)
                && !projected_non_secret(&ts, j)
            {
                out.push(Finding {
                    rule: "crypto-secret-leak",
                    file: f.rel.clone(),
                    line: t.line,
                    col: t.col,
                    message: format!("secret-bearing `{}` formatted/logged", t.text),
                });
            } else if t.kind == TokKind::Str {
                for cap in inline_captures(&t.text) {
                    if is_secret_ident(&cap) {
                        out.push(Finding {
                            rule: "crypto-secret-leak",
                            file: f.rel.clone(),
                            line: t.line,
                            col: t.col,
                            message: format!("secret-bearing `{cap}` formatted/logged"),
                        });
                    }
                }
            }
            j += 1;
        }
    }
}

/// `crypto-weak-rng`: deterministic `Rng::new` in a key-material module.
pub fn check_weak_rng(f: &SrcFile, out: &mut Vec<Finding>) {
    if !in_scope(&f.module, WEAK_RNG_SCOPE) {
        return;
    }
    let ts: Vec<&Tok> = f.lexed.toks.iter().filter(|t| !t.test).collect();
    for i in 0..ts.len() {
        if ts[i].is_ident("Rng")
            && i + 2 < ts.len()
            && ts[i + 1].is("::")
            && ts[i + 2].is_ident("new")
        {
            out.push(Finding {
                rule: "crypto-weak-rng",
                file: f.rel.clone(),
                line: ts[i].line,
                col: ts[i].col,
                message: "deterministic util::rng::Rng in a key-material module; \
                          use OsRng / the NoiseSource seam"
                    .to_string(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_all(rel: &str, src: &str) -> Vec<Finding> {
        let f = SrcFile::from_source(rel, src);
        let mut out = Vec::new();
        check_ct_eq(&f, &mut out);
        check_secret_exposure(&f, &mut out);
        check_weak_rng(&f, &mut out);
        out
    }

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn secret_ident_classification() {
        for s in ["secret", "round_seed", "enc_shares", "sk", "node_key", "keys", "my_privkey"] {
            assert!(is_secret_ident(s), "{s} should be secret");
        }
        for s in ["pubkey", "public_key", "keyspace_id", "monkey", "index", "value"] {
            assert!(!is_secret_ident(s), "{s} should NOT be secret");
        }
    }

    #[test]
    fn flags_secret_equality_but_not_ct_eq_or_calls() {
        let src = "fn f() { if mac_key == other { } if ct_eq(&a_secret, &b) { } \
                   if derive_key(x) == y.tag() { } }";
        let got = run_all("rust/src/privacy/secagg.rs", src);
        assert_eq!(rules(&got), vec!["crypto-ct-eq"]);
    }

    #[test]
    fn flags_derive_debug_on_secret_struct_only() {
        let src = "#[derive(Debug, Clone)] pub struct RoundKeys { pub secret: [u8; 32] }\n\
                   #[derive(Debug)] struct Meta { pub round_id: u64 }\n\
                   #[derive(Clone)] struct AlsoSecret { seed: u64 }";
        let got = run_all("rust/src/privacy/keys.rs", src);
        assert_eq!(rules(&got), vec!["crypto-secret-debug"]);
        assert!(got[0].message.contains("RoundKeys"));
    }

    #[test]
    fn flags_secret_in_format_args_and_inline_captures() {
        let src = "fn f() { let m = format!(\"seed={}\", round_seed); \
                   debug!(\"k {mask_key}\"); }";
        let got = run_all("rust/src/privacy/dp.rs", src);
        assert_eq!(rules(&got), vec!["crypto-secret-leak", "crypto-secret-leak"]);
    }

    #[test]
    fn len_projection_and_nonsecret_args_are_fine() {
        let src = "fn f() { let m = format!(\"n={} k={}\", shares.len(), count); \
                   info!(\"round {round_id} done\"); }";
        assert!(run_all("rust/src/privacy/shamir.rs", src).is_empty());
    }

    #[test]
    fn flags_weak_rng_only_in_key_modules() {
        let src = "fn f(seed_val: u64) { let mut r = Rng::new(seed_val); }";
        let got = run_all("rust/src/privacy/keys.rs", src);
        assert_eq!(rules(&got), vec!["crypto-weak-rng"]);
        // privacy::accountant does bookkeeping, not key material
        let got2 = run_all("rust/src/privacy/accountant.rs", src);
        assert!(rules(&got2).contains(&"crypto-weak-rng") == false);
    }

    #[test]
    fn out_of_scope_module_is_ignored() {
        let src = "fn f() { if session_key == other { } }";
        assert!(run_all("rust/src/dart/scheduler.rs", src).is_empty());
    }
}
