//! `feddart lint` — the in-tree project-invariant static analyzer.
//!
//! The compiler proves memory safety; it cannot prove the *project's*
//! invariants: that wire-facing code never panics on attacker-controlled
//! bytes, that secret material is compared in constant time and never
//! `Debug`-printed, that locks are taken in the declared order, or that
//! the durability/observability seams (round-event coverage, trace-dump
//! ordering, metric documentation) stay in sync as the tree grows.  This
//! module machine-checks those invariants with a lightweight tokenizer
//! ([`lexer`]), a module-path-aware file walker, and four rule families:
//!
//! | family   | rules | invariant |
//! |----------|-------|-----------|
//! | `panic`  | [`panic_rules`]  | panic-freedom in untrusted-input / hot-path modules |
//! | `crypto` | [`crypto_rules`] | constant-time secret compares, no secret Debug/logging, CSPRNG for key material |
//! | `lock`   | [`lock_rules`]   | declared lock-order hierarchy, no fsync under a held guard |
//! | `drift`  | [`drift_rules`]  | round-event arm coverage, trace-before-charge ordering, metric↔docs sync |
//!
//! The analyzer **self-hosts**: `tests/lint_self.rs` asserts this
//! repository is lint-clean, and CI runs `feddart lint` as a blocking
//! job.  Deliberate violations are suppressed inline with a justified
//! pragma (`// feddart-lint: allow(rule-id): why this is sound`); see
//! docs/ANALYSIS.md for the rule catalog and rationale.
//!
//! The rules are token-pattern checks, not a type system: they are tuned
//! for high precision on *this* codebase's idioms (fixture tests in each
//! rule file pin both directions), and they are intra-procedural — a
//! guard passed into a helper function is not tracked across the call.

pub mod crypto_rules;
pub mod drift_rules;
pub mod lexer;
pub mod lock_rules;
pub mod panic_rules;
pub mod report;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{FedError, Result};
use lexer::{lex, Lexed};

/// One rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Stable rule id (e.g. `panic-unwrap`).
    pub rule: &'static str,
    /// Repo-relative path (`rust/src/...` or `docs/...`).
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// 1-based column.
    pub col: u32,
    /// What is wrong and what to do instead.
    pub message: String,
}

/// Result of a lint run.
#[derive(Debug)]
pub struct Report {
    /// Surviving findings (pragma-suppressed ones removed), in file order.
    pub findings: Vec<Finding>,
    /// Number of Rust source files scanned.
    pub files_scanned: usize,
    /// Rule ids that ran (after `--rule` filtering).
    pub rules_run: Vec<&'static str>,
}

/// Every rule id, grouped by family prefix.
pub const ALL_RULES: &[&str] = &[
    "panic-unwrap",
    "panic-macro",
    "panic-index",
    "crypto-ct-eq",
    "crypto-secret-debug",
    "crypto-secret-leak",
    "crypto-weak-rng",
    "lock-order",
    "lock-io",
    "drift-event-coverage",
    "drift-trace-order",
    "drift-metrics-doc",
];

/// A tokenized source file with its repo-relative path and module path.
pub struct SrcFile {
    /// Repo-relative path, forward slashes (`rust/src/http/server.rs`).
    pub rel: String,
    /// Rust module path (`http::server`; `mod.rs`/`lib.rs`/`main.rs`
    /// collapse onto their directory).
    pub module: String,
    /// Tokens + pragmas.
    pub lexed: Lexed,
}

impl SrcFile {
    /// Build from a repo-relative path and source text (fixture tests use
    /// this directly with synthetic paths).
    pub fn from_source(rel: &str, src: &str) -> SrcFile {
        SrcFile {
            rel: rel.to_string(),
            module: module_of(rel),
            lexed: lex(src),
        }
    }
}

/// Map a repo-relative file path to its Rust module path.
pub fn module_of(rel: &str) -> String {
    let p = rel.replace('\\', "/");
    let p = p.strip_prefix("rust/src/").unwrap_or(&p);
    let p = p.strip_suffix(".rs").unwrap_or(p);
    let mut parts: Vec<&str> = p.split('/').collect();
    if matches!(parts.last().copied(), Some("mod" | "lib" | "main")) {
        parts.pop();
    }
    parts.join("::")
}

/// Whether `module` is `scope` or a submodule of it.
pub fn in_scope(module: &str, scopes: &[&str]) -> bool {
    scopes.iter().any(|s| module == *s || module.starts_with(&format!("{s}::")))
}

/// The lint engine: a loaded source tree plus the repo root.
pub struct Linter {
    root: PathBuf,
    files: Vec<SrcFile>,
}

impl Linter {
    /// Load every `.rs` file under `<root>/rust/src` (the vendored crates
    /// under `rust/vendor` are third-party stubs and are not scanned).
    pub fn load(root: &Path) -> Result<Linter> {
        let src_root = root.join("rust").join("src");
        if !src_root.is_dir() {
            return Err(FedError::Lint(format!(
                "{} has no rust/src — point --root at the repository root",
                root.display()
            )));
        }
        let mut paths = Vec::new();
        walk(&src_root, &mut paths)?;
        paths.sort();
        let mut files = Vec::with_capacity(paths.len());
        for p in paths {
            let src = std::fs::read_to_string(&p)?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            files.push(SrcFile::from_source(&rel, &src));
        }
        Ok(Linter { root: root.to_path_buf(), files })
    }

    /// The loaded files (rule unit tests inspect these).
    pub fn files(&self) -> &[SrcFile] {
        &self.files
    }

    /// Run all rules (or only those matching `filter` — an exact rule id
    /// or a family prefix like `panic`), apply pragmas, and report.
    pub fn run(&self, filter: Option<&str>) -> Result<Report> {
        let selected: Vec<&'static str> = ALL_RULES
            .iter()
            .copied()
            .filter(|r| match filter {
                None => true,
                Some(f) => *r == f || r.starts_with(&format!("{f}-")),
            })
            .collect();
        if selected.is_empty() {
            return Err(FedError::Lint(format!(
                "no rule matches '{}' (known: {})",
                filter.unwrap_or(""),
                ALL_RULES.join(", ")
            )));
        }
        let on = |rule: &str| selected.contains(&rule);
        let mut findings: Vec<Finding> = Vec::new();

        for f in &self.files {
            if on("panic-unwrap") || on("panic-macro") {
                panic_rules::check_panic_calls(f, &mut findings);
            }
            if on("panic-index") {
                panic_rules::check_indexing(f, &mut findings);
            }
            if on("crypto-ct-eq") {
                crypto_rules::check_ct_eq(f, &mut findings);
            }
            if on("crypto-secret-debug") || on("crypto-secret-leak") {
                crypto_rules::check_secret_exposure(f, &mut findings);
            }
            if on("crypto-weak-rng") {
                crypto_rules::check_weak_rng(f, &mut findings);
            }
            if on("lock-order") || on("lock-io") {
                lock_rules::check_locks(f, &mut findings);
            }
        }
        if on("drift-event-coverage") {
            drift_rules::check_event_coverage(&self.files, &mut findings);
        }
        if on("drift-trace-order") {
            drift_rules::check_trace_order(&self.files, &mut findings);
        }
        if on("drift-metrics-doc") {
            drift_rules::check_metrics_doc(
                &self.files,
                &self.root.join("docs").join("OPERATIONS.md"),
                &mut findings,
            );
        }

        // keep only rules that ran, drop pragma-suppressed findings
        let pragmas: BTreeMap<&str, &lexer::Pragmas> = self
            .files
            .iter()
            .map(|f| (f.rel.as_str(), &f.lexed.pragmas))
            .collect();
        let findings: Vec<Finding> = findings
            .into_iter()
            .filter(|fd| on(fd.rule))
            .filter(|fd| {
                pragmas
                    .get(fd.file.as_str())
                    .map(|p| !p.allows(fd.rule, fd.line))
                    .unwrap_or(true)
            })
            .collect();
        Ok(Report {
            findings,
            files_scanned: self.files.len(),
            rules_run: selected,
        })
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Ascend from `start` to the first directory containing `rust/src`.
pub fn find_repo_root(start: &Path) -> Result<PathBuf> {
    let mut cur = start.to_path_buf();
    loop {
        if cur.join("rust").join("src").is_dir() {
            return Ok(cur);
        }
        if !cur.pop() {
            return Err(FedError::Lint(format!(
                "no rust/src found in or above {}",
                start.display()
            )));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_paths() {
        assert_eq!(module_of("rust/src/http/server.rs"), "http::server");
        assert_eq!(module_of("rust/src/json/mod.rs"), "json");
        assert_eq!(module_of("rust/src/lib.rs"), "");
        assert_eq!(module_of("rust/src/cli.rs"), "cli");
        assert_eq!(
            module_of("rust/src/coordinator/round_store.rs"),
            "coordinator::round_store"
        );
    }

    #[test]
    fn scope_matching() {
        assert!(in_scope("http::server", &["http"]));
        assert!(in_scope("http", &["http"]));
        assert!(!in_scope("http2", &["http"]));
        assert!(in_scope("dart::transport", &["dart::transport"]));
        assert!(!in_scope("dart::rest", &["dart::transport"]));
    }

    #[test]
    fn rule_filter_selects_families_and_ids() {
        // a Linter over zero files still validates the filter
        let l = Linter { root: PathBuf::from("."), files: Vec::new() };
        assert!(l.run(Some("panic")).is_ok());
        assert!(l.run(Some("panic-unwrap")).is_ok());
        assert!(l.run(Some("nope")).is_err());
        let r = l.run(Some("crypto")).map(|r| r.rules_run.len());
        assert_eq!(r.ok(), Some(4));
    }
}
