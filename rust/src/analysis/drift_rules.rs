//! Durability / observability drift rules.
//!
//! These are repo-level checks (they look across files and into
//! docs/OPERATIONS.md) that keep three seams from silently drifting as
//! the tree grows:
//!
//! * `drift-event-coverage` — every `EventKind` variant in the round
//!   store must have an arm in both the `transition` legality check and
//!   the `absorb` replay path.  A variant added to one but not the
//!   other replays differently than it commits.  When the event schema
//!   carries the durable server-optimizer state (`opt_state`), `absorb`
//!   must also materialize it — else replay silently drops momentum.
//! * `drift-trace-order` — in `fact::server` and the `fact::rounds`
//!   pipeline, any function that both dumps round traces and appends
//!   ε-charges must dump first: the flight recorder write must land
//!   before the accountant mutates, so a crash between the two leaves
//!   evidence, not a silent charge.
//! * `drift-metrics-doc` — every emitted `fact.*` / `dart.*` metric
//!   name must be documented in docs/OPERATIONS.md, and every
//!   documented name must still be emitted (both directions).
//!
//! Metric *emission* is any `"fact.…"` / `"dart.…"` dotted string
//! literal in non-test source — metric fns take names directly and
//! helpers (e.g. the scheduler's `bump(name, n)`) forward them, so any
//! such literal names a live series.  *Documentation* is a full dotted
//! name in a code span anywhere in OPERATIONS.md, or a bare suffix in
//! the first cell of a table row under a `### `fact.x.*`` section
//! heading (the suffix joins the section prefix).

use std::path::Path;

use super::lexer::{Tok, TokKind};
use super::{Finding, SrcFile};

const ROUND_STORE: &str = "rust/src/coordinator/round_store.rs";
const FACT_SERVER: &str = "rust/src/fact/server.rs";
const ROUNDS_DIR: &str = "rust/src/fact/rounds/";
const OPS_DOC: &str = "docs/OPERATIONS.md";

fn live(f: &SrcFile) -> Vec<&Tok> {
    f.lexed.toks.iter().filter(|t| !t.test).collect()
}

fn by_rel<'a>(files: &'a [SrcFile], rel: &str) -> Option<&'a SrcFile> {
    files.iter().find(|f| f.rel == rel)
}

/// The `{ … }` body tokens of `enum <name>` (fields included).
fn enum_body<'s, 'a>(ts: &'s [&'a Tok], name: &str) -> &'s [&'a Tok] {
    let mut i = 0usize;
    while i + 1 < ts.len() {
        if ts[i].is_ident("enum") && ts[i + 1].is_ident(name) {
            let mut j = i + 2;
            while j < ts.len() && !ts[j].is("{") {
                j += 1;
            }
            let mut k = j + 1;
            let mut d = 1usize;
            while k < ts.len() && d > 0 {
                if ts[k].is("{") {
                    d += 1;
                } else if ts[k].is("}") {
                    d -= 1;
                }
                k += 1;
            }
            return &ts[j..k];
        }
        i += 1;
    }
    &[]
}

/// Variant names of `enum <name>` (unit and struct variants).
fn enum_variants<'a>(ts: &[&'a Tok], name: &str) -> Vec<&'a str> {
    let mut i = 0usize;
    while i + 1 < ts.len() {
        if ts[i].is_ident("enum") && ts[i + 1].is_ident(name) {
            let mut j = i + 2;
            while j < ts.len() && !ts[j].is("{") {
                j += 1;
            }
            let mut d = 1usize;
            j += 1;
            let mut variants = Vec::new();
            let mut expect = true;
            while j < ts.len() && d > 0 {
                if ts[j].is("{") {
                    d += 1;
                } else if ts[j].is("}") {
                    d -= 1;
                } else if d == 1 {
                    if expect && ts[j].kind == TokKind::Ident {
                        variants.push(ts[j].text.as_str());
                        expect = false;
                    } else if ts[j].is(",") {
                        expect = true;
                    }
                }
                j += 1;
            }
            return variants;
        }
        i += 1;
    }
    Vec::new()
}

/// Body tokens (the `{ … }` block) of the first `fn <name>` in `ts`.
fn fn_body<'s, 'a>(ts: &'s [&'a Tok], name: &str) -> &'s [&'a Tok] {
    let mut i = 0usize;
    while i + 1 < ts.len() {
        if ts[i].is_ident("fn") && ts[i + 1].is_ident(name) {
            let mut j = i + 2;
            let mut d = 0isize;
            while j < ts.len() {
                let t = ts[j];
                if t.is("(") || t.is("[") || t.is("<") {
                    d += 1;
                } else if t.is(")") || t.is("]") || t.is(">") {
                    d -= 1;
                } else if t.is("{") && d <= 0 {
                    let mut k = j + 1;
                    let mut bd = 1usize;
                    while k < ts.len() && bd > 0 {
                        if ts[k].is("{") {
                            bd += 1;
                        } else if ts[k].is("}") {
                            bd -= 1;
                        }
                        k += 1;
                    }
                    return &ts[j..k];
                }
                j += 1;
            }
        }
        i += 1;
    }
    &[]
}

/// `drift-event-coverage`: every EventKind variant has a `transition`
/// arm and an `absorb` replay arm.
pub fn check_event_coverage(files: &[SrcFile], out: &mut Vec<Finding>) {
    let Some(f) = by_rel(files, ROUND_STORE) else { return };
    let ts = live(f);
    if ts.is_empty() {
        return;
    }
    let variants = enum_variants(&ts, "EventKind");
    if variants.is_empty() {
        out.push(Finding {
            rule: "drift-event-coverage",
            file: f.rel.clone(),
            line: ts[0].line,
            col: ts[0].col,
            message: "enum EventKind not found".to_string(),
        });
        return;
    }
    for fname in ["transition", "absorb"] {
        let body = fn_body(&ts, fname);
        let mut referenced: Vec<&str> = Vec::new();
        for i in 0..body.len().saturating_sub(2) {
            if body[i].is_ident("EventKind") && body[i + 1].is("::") {
                referenced.push(body[i + 2].text.as_str());
            }
        }
        for v in &variants {
            if !referenced.contains(v) {
                out.push(Finding {
                    rule: "drift-event-coverage",
                    file: f.rel.clone(),
                    line: ts[0].line,
                    col: ts[0].col,
                    message: format!("EventKind::{v} has no arm in `{fname}`"),
                });
            }
        }
    }
    // Durable optimizer state: when the event schema carries `opt_state`
    // (the `Aggregated` payload persisting server-optimizer buffers), the
    // `absorb` replay path must materialize it — an absorb that pattern-
    // matches the field away replays a crash into a state that silently
    // forgot its momentum/Adam buffers.  Guarded on the enum declaration
    // so schemas without the field are not held to it.
    if enum_body(&ts, "EventKind").iter().any(|t| t.is_ident("opt_state")) {
        let absorb = fn_body(&ts, "absorb");
        if !absorb.iter().any(|t| t.is_ident("opt_state")) {
            out.push(Finding {
                rule: "drift-event-coverage",
                file: f.rel.clone(),
                line: ts[0].line,
                col: ts[0].col,
                message: "EventKind carries `opt_state` but `absorb` never \
                          touches it: optimizer state would be dropped on replay"
                    .to_string(),
            });
        }
    }
}

/// `drift-trace-order`: the flight-recorder dump must precede ε-charge
/// appends inside any fact::server / fact::rounds function using both.
pub fn check_trace_order(files: &[SrcFile], out: &mut Vec<Finding>) {
    for f in files {
        if f.rel != FACT_SERVER && !f.rel.starts_with(ROUNDS_DIR) {
            continue;
        }
        check_trace_order_file(f, out);
    }
}

fn check_trace_order_file(f: &SrcFile, out: &mut Vec<Finding>) {
    let ts = live(f);
    let mut i = 0usize;
    while i < ts.len() {
        if ts[i].is_ident("fn") && i + 1 < ts.len() {
            let fname = ts[i + 1].text.clone();
            let body = fn_body(&ts[i..], &fname);
            let dump = body.iter().position(|t| t.is_ident("dump_round"));
            let charge = body.iter().position(|t| t.is_ident("append_charge"));
            if let (Some(di), Some(ci)) = (dump, charge) {
                if ci < di {
                    out.push(Finding {
                        rule: "drift-trace-order",
                        file: f.rel.clone(),
                        line: body[ci].line,
                        col: body[ci].col,
                        message: format!(
                            "`append_charge` precedes `dump_round` in fn `{fname}`: \
                             the trace dump must land before ε-charge appends"
                        ),
                    });
                }
            }
            i += body.len().max(1);
        }
        i += 1;
    }
}

/// Whether `s` is a well-formed dotted metric name (`fact.x.y`, `dart.x`).
fn is_metric_literal(s: &str) -> bool {
    let rest = match s.strip_prefix("fact.").or_else(|| s.strip_prefix("dart.")) {
        Some(r) => r,
        None => return false,
    };
    let b = rest.as_bytes();
    !b.is_empty()
        && (b[0] == b'_' || b[0].is_ascii_lowercase())
        && b.iter().all(|c| {
            *c == b'_' || *c == b'.' || c.is_ascii_lowercase() || c.is_ascii_digit()
        })
}

/// Looser form for documented names (`fact.[a-z_.]+`).
fn is_documented_name(s: &str) -> bool {
    let rest = match s.strip_prefix("fact.").or_else(|| s.strip_prefix("dart.")) {
        Some(r) => r,
        None => return false,
    };
    !rest.is_empty()
        && rest
            .bytes()
            .all(|c| c == b'_' || c == b'.' || c.is_ascii_lowercase())
}

/// Every emitted metric name → first emission site.
fn emitted_metrics<'a>(files: &'a [SrcFile]) -> Vec<(&'a str, &'a SrcFile, &'a Tok)> {
    let mut out: Vec<(&str, &SrcFile, &Tok)> = Vec::new();
    for f in files {
        if !f.rel.starts_with("rust/src/") {
            continue;
        }
        for t in f.lexed.toks.iter().filter(|t| !t.test) {
            if t.kind != TokKind::Str || !t.text.starts_with('"') || t.text.len() < 2 {
                continue;
            }
            let name = t.text.trim_matches('"');
            if is_metric_literal(name) && !out.iter().any(|(n, _, _)| *n == name) {
                out.push((name, f, t));
            }
        }
    }
    out.sort_by_key(|(n, _, _)| *n);
    out
}

/// A section heading's metric prefix (`### `fact.round.*`` → `fact.round`).
fn heading_prefix(line: &str) -> Option<Option<String>> {
    let hashes = line.bytes().take_while(|b| *b == b'#').count();
    if hashes == 0 {
        return None; // not a heading at all
    }
    if (2..=4).contains(&hashes) {
        let rest = &line[hashes..];
        if rest.starts_with(' ') || rest.starts_with('\t') {
            let rest = rest.trim_start();
            if let Some(body) = rest.strip_prefix('`') {
                if let Some(end) = body.find('`') {
                    let span = &body[..end];
                    if let Some(base) = span.strip_suffix(".*") {
                        let valid = base == "fact"
                            || base == "dart"
                            || is_documented_name(base);
                        if valid {
                            return Some(Some(base.to_string()));
                        }
                    }
                }
            }
        }
    }
    Some(None) // a heading, but not a prefix section — clears the prefix
}

/// The bare-suffix first cell of a table row (`| `closes` | …`).
fn table_row_suffix(line: &str) -> Option<&str> {
    let rest = line.strip_prefix('|')?;
    let rest = rest.trim_start();
    let body = rest.strip_prefix('`')?;
    let end = body.find('`')?;
    let suffix = &body[..end];
    if suffix.is_empty() || !suffix.bytes().all(|c| c == b'_' || c.is_ascii_lowercase()) {
        return None;
    }
    let after = body[end + 1..].trim_start();
    after.starts_with('|').then_some(suffix)
}

/// Full metric names documented in OPERATIONS.md text.
fn documented_metrics(ops: &str) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    let mut prefix: Option<String> = None;
    for line in ops.lines() {
        if let Some(p) = heading_prefix(line) {
            prefix = p;
            if prefix.is_some() {
                continue;
            }
        }
        // full dotted names in code spans document themselves anywhere
        for (idx, span) in line.split('`').enumerate() {
            if idx % 2 == 0 {
                continue;
            }
            let core = span.split('{').next().unwrap_or("").trim();
            if is_documented_name(core) && !names.iter().any(|n| n == core) {
                names.push(core.to_string());
            }
        }
        // bare suffixes join the active section prefix via table rows
        if let (Some(p), Some(suffix)) = (&prefix, table_row_suffix(line)) {
            let full = format!("{p}.{suffix}");
            if !names.iter().any(|n| n == &full) {
                names.push(full);
            }
        }
    }
    names
}

/// `drift-metrics-doc` against the OPERATIONS.md on disk.
pub fn check_metrics_doc(files: &[SrcFile], ops_path: &Path, out: &mut Vec<Finding>) {
    match std::fs::read_to_string(ops_path) {
        Ok(text) => check_metrics_doc_text(files, &text, out),
        Err(_) => out.push(Finding {
            rule: "drift-metrics-doc",
            file: OPS_DOC.to_string(),
            line: 1,
            col: 1,
            message: format!("{OPS_DOC} missing"),
        }),
    }
}

/// `drift-metrics-doc` against in-memory doc text (fixtures use this).
pub fn check_metrics_doc_text(files: &[SrcFile], ops: &str, out: &mut Vec<Finding>) {
    let emitted = emitted_metrics(files);
    let documented = documented_metrics(ops);
    for (name, f, t) in &emitted {
        if name.contains('{') || name.ends_with('.') {
            continue;
        }
        if !documented.iter().any(|d| d == name) {
            out.push(Finding {
                rule: "drift-metrics-doc",
                file: f.rel.clone(),
                line: t.line,
                col: t.col,
                message: format!(
                    "metric `{name}` is emitted but not documented in {OPS_DOC}"
                ),
            });
        }
    }
    for name in &documented {
        if !emitted.iter().any(|(n, _, _)| n == name) {
            out.push(Finding {
                rule: "drift-metrics-doc",
                file: OPS_DOC.to_string(),
                line: 1,
                col: 1,
                message: format!("metric `{name}` is documented but never emitted"),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msgs(findings: &[Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.message.as_str()).collect()
    }

    #[test]
    fn event_coverage_flags_missing_arms_both_ways() {
        let src = "pub enum EventKind { Configured { t: u64 }, Voided, }\n\
                   fn transition(k: &EventKind) { match k { EventKind::Configured { .. } => {}, \
                   EventKind::Voided => {}, } }\n\
                   fn absorb(k: EventKind) { match k { EventKind::Configured { .. } => {}, _ => {} } }";
        let f = SrcFile::from_source(ROUND_STORE, src);
        let mut out = Vec::new();
        check_event_coverage(&[f], &mut out);
        assert_eq!(msgs(&out), vec!["EventKind::Voided has no arm in `absorb`"]);
    }

    #[test]
    fn event_coverage_clean_when_both_cover_all() {
        let src = "pub enum EventKind { A, B }\n\
                   fn transition(k: &EventKind) { match k { EventKind::A => {}, EventKind::B => {} } }\n\
                   fn absorb(k: EventKind) { match k { EventKind::A => {}, EventKind::B => {} } }";
        let f = SrcFile::from_source(ROUND_STORE, src);
        let mut out = Vec::new();
        check_event_coverage(&[f], &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn event_coverage_requires_opt_state_in_absorb() {
        // schema carries opt_state but absorb pattern-matches it away
        let src = "pub enum EventKind { Aggregated { params: u64, opt_state: u64 } }\n\
                   fn transition(k: &EventKind) { match k { EventKind::Aggregated { .. } => {} } }\n\
                   fn absorb(k: EventKind) { match k { EventKind::Aggregated { .. } => {} } }";
        let f = SrcFile::from_source(ROUND_STORE, src);
        let mut out = Vec::new();
        check_event_coverage(&[f], &mut out);
        assert_eq!(
            msgs(&out),
            vec![
                "EventKind carries `opt_state` but `absorb` never touches it: \
                 optimizer state would be dropped on replay"
            ]
        );

        // destructuring the field in absorb satisfies the rule
        let src = "pub enum EventKind { Aggregated { params: u64, opt_state: u64 } }\n\
                   fn transition(k: &EventKind) { match k { EventKind::Aggregated { .. } => {} } }\n\
                   fn absorb(k: EventKind) { match k { EventKind::Aggregated { opt_state, .. } => { use_it(opt_state); } } }";
        let f = SrcFile::from_source(ROUND_STORE, src);
        let mut out = Vec::new();
        check_event_coverage(&[f], &mut out);
        assert!(out.is_empty(), "unexpected: {:?}", msgs(&out));

        // schemas without the field are not held to it
        let src = "pub enum EventKind { Aggregated { params: u64 } }\n\
                   fn transition(k: &EventKind) { match k { EventKind::Aggregated { .. } => {} } }\n\
                   fn absorb(k: EventKind) { match k { EventKind::Aggregated { .. } => {} } }";
        let f = SrcFile::from_source(ROUND_STORE, src);
        let mut out = Vec::new();
        check_event_coverage(&[f], &mut out);
        assert!(out.is_empty(), "unexpected: {:?}", msgs(&out));
    }

    #[test]
    fn trace_order_scans_rounds_pipeline_files() {
        let src = "fn finish(&mut self) { self.acct.append_charge(c); self.rec.dump_round(id); }";
        let f = SrcFile::from_source("rust/src/fact/rounds/phases.rs", src);
        let mut out = Vec::new();
        check_trace_order(&[f], &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("fn `finish`"));
    }

    #[test]
    fn trace_order_flags_charge_before_dump() {
        let src = "impl S { fn close(&mut self) { self.acct.append_charge(c); \
                   self.rec.dump_round(id); } \
                   fn fine(&mut self) { self.rec.dump_round(id); self.acct.append_charge(c); } }";
        let f = SrcFile::from_source(FACT_SERVER, src);
        let mut out = Vec::new();
        check_trace_order(&[f], &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("fn `close`"));
    }

    #[test]
    fn metrics_doc_flags_both_directions() {
        let f = SrcFile::from_source(
            "rust/src/metrics/mod.rs",
            "fn f(m: &M) { m.counter(\"fact.rounds_open\", 1); }",
        );
        let ops = "## Counters\n\n`fact.rounds.closed` is incremented on close.\n";
        let mut out = Vec::new();
        check_metrics_doc_text(&[f], ops, &mut out);
        assert_eq!(
            msgs(&out),
            vec![
                "metric `fact.rounds_open` is emitted but not documented in docs/OPERATIONS.md",
                "metric `fact.rounds.closed` is documented but never emitted",
            ]
        );
    }

    #[test]
    fn metrics_doc_joins_table_rows_under_prefix_sections() {
        let f = SrcFile::from_source(
            "rust/src/fact/server.rs",
            "fn f(m: &M) { m.counter(\"fact.participation.deadline_closes\", 1); }",
        );
        let ops = "### `fact.participation.*`\n\n\
                   | counter | meaning |\n|---|---|\n\
                   | `deadline_closes` | rounds closed at deadline |\n";
        let mut out = Vec::new();
        check_metrics_doc_text(&[f], ops, &mut out);
        assert!(out.is_empty(), "unexpected: {:?}", msgs(&out));
    }

    #[test]
    fn metrics_doc_prefix_scope_ends_at_next_heading() {
        let f = SrcFile::from_source("rust/src/fact/server.rs", "fn f() {}");
        let ops = "### `fact.round.*`\n\n## Other\n\n| `orphan` | row outside a prefix section |\n";
        let mut out = Vec::new();
        check_metrics_doc_text(&[f], ops, &mut out);
        // `orphan` must NOT be documented as fact.round.orphan
        assert!(out.is_empty(), "unexpected: {:?}", msgs(&out));
    }

    #[test]
    fn metric_literals_in_test_code_do_not_count() {
        let f = SrcFile::from_source(
            "rust/src/metrics/mod.rs",
            "#[cfg(test)]\nmod tests { fn t(m: &M) { m.counter(\"fact.test_only\", 1); } }",
        );
        let mut out = Vec::new();
        check_metrics_doc_text(&[f], "", &mut out);
        assert!(out.is_empty());
    }
}
