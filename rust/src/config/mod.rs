//! Configuration files — the paper's server / device configs (Listings 2-3)
//! plus the federated-learning hyperparameter block used by examples and the
//! CLI.
//!
//! ```json
//! { "server": "https://dart-server:7777", "client_key": "000" }
//! ```
//!
//! ```json
//! [ {"name": "client-0", "ipAddress": "127.0.0.1", "port": 2883,
//!    "hardware_config": {"cpus": 4, "mem_gb": 8, "accelerator": "none"}} ]
//! ```

use std::path::Path;

use crate::error::{FedError, Result};
use crate::json::Json;

/// Server configuration (paper Listing 2).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// `host:port` of the https-server (scheme stripped).
    pub server: String,
    /// Shared client key presented on the REST-API.
    pub client_key: String,
}

impl ServerConfig {
    pub fn from_json(j: &Json) -> Result<ServerConfig> {
        let server = j
            .need("server")?
            .as_str()
            .ok_or_else(|| FedError::Config("'server' must be a string".into()))?
            .to_string();
        let client_key = j
            .get("client_key")
            .and_then(Json::as_str)
            .unwrap_or("000")
            .to_string();
        Ok(ServerConfig { server, client_key })
    }

    pub fn load(path: &Path) -> Result<ServerConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("server", self.server.as_str())
            .set("client_key", self.client_key.as_str())
    }
}

/// Hardware description used by the Task `check` function (§A.2:
/// "verifies the task requirements to ensure that hardware requirements
/// and device availability are fulfilled").
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    pub cpus: usize,
    pub mem_gb: usize,
    pub accelerator: String,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        HardwareConfig { cpus: 1, mem_gb: 1, accelerator: "none".into() }
    }
}

impl HardwareConfig {
    pub fn from_json(j: &Json) -> HardwareConfig {
        if j.is_null() {
            return HardwareConfig::default();
        }
        HardwareConfig {
            cpus: j.get("cpus").and_then(Json::as_usize).unwrap_or(1),
            mem_gb: j.get("mem_gb").and_then(Json::as_usize).unwrap_or(1),
            accelerator: j
                .get("accelerator")
                .and_then(Json::as_str)
                .unwrap_or("none")
                .to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("cpus", self.cpus)
            .set("mem_gb", self.mem_gb)
            .set("accelerator", self.accelerator.as_str())
    }

    /// Does this hardware satisfy `req`?
    pub fn satisfies(&self, req: &HardwareConfig) -> bool {
        self.cpus >= req.cpus
            && self.mem_gb >= req.mem_gb
            && (req.accelerator == "none" || req.accelerator == self.accelerator)
    }
}

/// One device entry (paper Listing 3).
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    pub name: String,
    pub ip_address: String,
    pub port: u16,
    pub hardware: HardwareConfig,
}

impl DeviceConfig {
    pub fn from_json(idx: usize, j: &Json) -> Result<DeviceConfig> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .map(String::from)
            .unwrap_or_else(|| format!("client-{idx}"));
        let ip = j
            .need("ipAddress")?
            .as_str()
            .ok_or_else(|| FedError::Config("'ipAddress' must be a string".into()))?
            .to_string();
        let port = j
            .need("port")?
            .as_usize()
            .ok_or_else(|| FedError::Config("'port' must be an integer".into()))?
            as u16;
        let hardware = j
            .get("hardware_config")
            .map(HardwareConfig::from_json)
            .unwrap_or_default();
        Ok(DeviceConfig { name, ip_address: ip, port, hardware })
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("ipAddress", self.ip_address.as_str())
            .set("port", self.port as usize)
            .set("hardware_config", self.hardware.to_json())
    }
}

/// Parse a device file: a JSON array of device configs.
pub fn load_devices(path: &Path) -> Result<Vec<DeviceConfig>> {
    let text = std::fs::read_to_string(path)?;
    parse_devices(&Json::parse(&text)?)
}

pub fn parse_devices(j: &Json) -> Result<Vec<DeviceConfig>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| FedError::Config("device file must be a JSON array".into()))?;
    arr.iter()
        .enumerate()
        .map(|(i, d)| DeviceConfig::from_json(i, d))
        .collect()
}

/// Federated-learning run settings shared by the CLI and examples.
#[derive(Debug, Clone)]
pub struct FlConfig {
    pub model: String,
    pub rounds: usize,
    pub local_steps: usize,
    pub lr: f32,
    /// FedProx proximal coefficient; 0 disables (plain FedAvg local step).
    pub mu: f32,
    pub seed: u64,
    pub aggregation: String,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig {
            model: "mlp_default".into(),
            rounds: 20,
            local_steps: 4,
            lr: 0.1,
            mu: 0.0,
            seed: 42,
            aggregation: "weighted_fedavg".into(),
        }
    }
}

impl FlConfig {
    pub fn from_json(j: &Json) -> FlConfig {
        let d = FlConfig::default();
        FlConfig {
            model: j.get("model").and_then(Json::as_str).unwrap_or(&d.model).into(),
            rounds: j.get("rounds").and_then(Json::as_usize).unwrap_or(d.rounds),
            local_steps: j
                .get("local_steps")
                .and_then(Json::as_usize)
                .unwrap_or(d.local_steps),
            lr: j.get("lr").and_then(Json::as_f64).unwrap_or(d.lr as f64) as f32,
            mu: j.get("mu").and_then(Json::as_f64).unwrap_or(d.mu as f64) as f32,
            seed: j.get("seed").and_then(Json::as_i64).unwrap_or(d.seed as i64) as u64,
            aggregation: j
                .get("aggregation")
                .and_then(Json::as_str)
                .unwrap_or(&d.aggregation)
                .into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_config_minimal() {
        let j = Json::parse(
            r#"{"server": "https://dart-server:7777", "client_key": "000"}"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&j).unwrap();
        assert_eq!(c.server, "https://dart-server:7777");
        assert_eq!(c.client_key, "000");
    }

    #[test]
    fn server_config_requires_server_key() {
        let j = Json::parse(r#"{"client_key": "000"}"#).unwrap();
        assert!(ServerConfig::from_json(&j).is_err());
    }

    #[test]
    fn device_file_with_null_hardware() {
        // the paper: "In test mode, these can be set to dummy values and
        // the hardware_config can be set to null" (§C.1.2)
        let j = Json::parse(
            r#"[{"ipAddress": "0.0.0.0", "port": 1, "hardware_config": null},
                {"name": "edge-7", "ipAddress": "10.0.0.7", "port": 2883,
                 "hardware_config": {"cpus": 8, "mem_gb": 16,
                                     "accelerator": "tpu"}}]"#,
        )
        .unwrap();
        let devs = parse_devices(&j).unwrap();
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[0].name, "client-0");
        assert_eq!(devs[0].hardware, HardwareConfig::default());
        assert_eq!(devs[1].name, "edge-7");
        assert_eq!(devs[1].hardware.cpus, 8);
        assert_eq!(devs[1].hardware.accelerator, "tpu");
    }

    #[test]
    fn hardware_satisfies() {
        let big = HardwareConfig { cpus: 8, mem_gb: 16, accelerator: "tpu".into() };
        let small = HardwareConfig { cpus: 2, mem_gb: 4, accelerator: "none".into() };
        assert!(big.satisfies(&small));
        assert!(!small.satisfies(&big));
        let need_tpu = HardwareConfig { cpus: 1, mem_gb: 1, accelerator: "tpu".into() };
        assert!(big.satisfies(&need_tpu));
        assert!(!small.satisfies(&need_tpu));
    }

    #[test]
    fn fl_config_defaults_and_overrides() {
        let j = Json::parse(r#"{"rounds": 5, "mu": 0.1}"#).unwrap();
        let c = FlConfig::from_json(&j);
        assert_eq!(c.rounds, 5);
        assert!((c.mu - 0.1).abs() < 1e-6);
        assert_eq!(c.model, "mlp_default");
        assert_eq!(c.local_steps, 4);
    }

    #[test]
    fn config_roundtrip_through_files() {
        let dir = std::env::temp_dir().join("feddart-config-test");
        std::fs::create_dir_all(&dir).unwrap();
        let sc = ServerConfig { server: "127.0.0.1:7777".into(), client_key: "abc".into() };
        let p = dir.join("server.json");
        std::fs::write(&p, sc.to_json().to_string()).unwrap();
        let back = ServerConfig::load(&p).unwrap();
        assert_eq!(back.server, sc.server);
        assert_eq!(back.client_key, sc.client_key);
    }
}
