//! Configuration files — the paper's server / device configs (Listings 2-3)
//! plus the federated-learning hyperparameter block used by examples and the
//! CLI.
//!
//! ```json
//! { "server": "https://dart-server:7777", "client_key": "000" }
//! ```
//!
//! ```json
//! [ {"name": "client-0", "ipAddress": "127.0.0.1", "port": 2883,
//!    "hardware_config": {"cpus": 4, "mem_gb": 8, "accelerator": "none"}} ]
//! ```

use std::path::Path;

use crate::error::{FedError, Result};
use crate::json::Json;

/// Server configuration (paper Listing 2).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// `host:port` of the https-server (scheme stripped).
    pub server: String,
    /// Shared client key presented on the REST-API.
    pub client_key: String,
}

impl ServerConfig {
    pub fn from_json(j: &Json) -> Result<ServerConfig> {
        let server = j
            .need("server")?
            .as_str()
            .ok_or_else(|| FedError::Config("'server' must be a string".into()))?
            .to_string();
        let client_key = j
            .get("client_key")
            .and_then(Json::as_str)
            .unwrap_or("000")
            .to_string();
        Ok(ServerConfig { server, client_key })
    }

    pub fn load(path: &Path) -> Result<ServerConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("server", self.server.as_str())
            .set("client_key", self.client_key.as_str())
    }
}

/// Hardware description used by the Task `check` function (§A.2:
/// "verifies the task requirements to ensure that hardware requirements
/// and device availability are fulfilled").
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    pub cpus: usize,
    pub mem_gb: usize,
    pub accelerator: String,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        HardwareConfig { cpus: 1, mem_gb: 1, accelerator: "none".into() }
    }
}

impl HardwareConfig {
    pub fn from_json(j: &Json) -> HardwareConfig {
        if j.is_null() {
            return HardwareConfig::default();
        }
        HardwareConfig {
            cpus: j.get("cpus").and_then(Json::as_usize).unwrap_or(1),
            mem_gb: j.get("mem_gb").and_then(Json::as_usize).unwrap_or(1),
            accelerator: j
                .get("accelerator")
                .and_then(Json::as_str)
                .unwrap_or("none")
                .to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("cpus", self.cpus)
            .set("mem_gb", self.mem_gb)
            .set("accelerator", self.accelerator.as_str())
    }

    /// Does this hardware satisfy `req`?
    pub fn satisfies(&self, req: &HardwareConfig) -> bool {
        self.cpus >= req.cpus
            && self.mem_gb >= req.mem_gb
            && (req.accelerator == "none" || req.accelerator == self.accelerator)
    }
}

/// One device entry (paper Listing 3).
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    pub name: String,
    pub ip_address: String,
    pub port: u16,
    pub hardware: HardwareConfig,
}

impl DeviceConfig {
    pub fn from_json(idx: usize, j: &Json) -> Result<DeviceConfig> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .map(String::from)
            .unwrap_or_else(|| format!("client-{idx}"));
        let ip = j
            .need("ipAddress")?
            .as_str()
            .ok_or_else(|| FedError::Config("'ipAddress' must be a string".into()))?
            .to_string();
        let port = j
            .need("port")?
            .as_usize()
            .ok_or_else(|| FedError::Config("'port' must be an integer".into()))?
            as u16;
        let hardware = j
            .get("hardware_config")
            .map(HardwareConfig::from_json)
            .unwrap_or_default();
        Ok(DeviceConfig { name, ip_address: ip, port, hardware })
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("ipAddress", self.ip_address.as_str())
            .set("port", self.port as usize)
            .set("hardware_config", self.hardware.to_json())
    }
}

/// Parse a device file: a JSON array of device configs.
pub fn load_devices(path: &Path) -> Result<Vec<DeviceConfig>> {
    let text = std::fs::read_to_string(path)?;
    parse_devices(&Json::parse(&text)?)
}

pub fn parse_devices(j: &Json) -> Result<Vec<DeviceConfig>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| FedError::Config("device file must be a JSON array".into()))?;
    arr.iter()
        .enumerate()
        .map(|(i, d)| DeviceConfig::from_json(i, d))
        .collect()
}

/// Federated-learning run settings shared by the CLI and examples.
#[derive(Debug, Clone)]
pub struct FlConfig {
    pub model: String,
    pub rounds: usize,
    pub local_steps: usize,
    pub lr: f32,
    /// FedProx proximal coefficient; 0 disables (plain FedAvg local step).
    pub mu: f32,
    pub seed: u64,
    pub aggregation: String,
    /// Server-side optimizer applied to the aggregate: `plain`,
    /// `fedavgm[:momentum[:lr]]`, or `fedadam[:lr[:b1[:b2[:eps]]]]`.
    pub server_opt: String,
    /// Client local-update strategy: `plain`, `fedprox[:mu]`, or
    /// `fednova`.
    pub local_strategy: String,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig {
            model: "mlp_default".into(),
            rounds: 20,
            local_steps: 4,
            lr: 0.1,
            mu: 0.0,
            seed: 42,
            aggregation: "weighted_fedavg".into(),
            server_opt: "plain".into(),
            local_strategy: "plain".into(),
        }
    }
}

impl FlConfig {
    pub fn from_json(j: &Json) -> FlConfig {
        let d = FlConfig::default();
        FlConfig {
            model: j.get("model").and_then(Json::as_str).unwrap_or(&d.model).into(),
            rounds: j.get("rounds").and_then(Json::as_usize).unwrap_or(d.rounds),
            local_steps: j
                .get("local_steps")
                .and_then(Json::as_usize)
                .unwrap_or(d.local_steps),
            lr: j.get("lr").and_then(Json::as_f64).unwrap_or(d.lr as f64) as f32,
            mu: j.get("mu").and_then(Json::as_f64).unwrap_or(d.mu as f64) as f32,
            seed: j.get("seed").and_then(Json::as_i64).unwrap_or(d.seed as i64) as u64,
            aggregation: j
                .get("aggregation")
                .and_then(Json::as_str)
                .unwrap_or(&d.aggregation)
                .into(),
            server_opt: j
                .get("server_opt")
                .and_then(Json::as_str)
                .unwrap_or(&d.server_opt)
                .into(),
            local_strategy: j
                .get("local_strategy")
                .and_then(Json::as_str)
                .unwrap_or(&d.local_strategy)
                .into(),
        }
    }
}

/// Tracing / flight-recorder settings.  Applied process-wide via
/// [`TelemetryConfig::apply`] (the global recorder); tracing is on by
/// default because a disabled-check costs one atomic load per span site.
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Master switch for live span/event recording.
    pub enabled: bool,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { enabled: true }
    }
}

impl TelemetryConfig {
    pub fn from_json(j: &Json) -> TelemetryConfig {
        TelemetryConfig {
            enabled: j
                .get("enabled")
                .and_then(Json::as_bool)
                .unwrap_or(true),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj().set("enabled", self.enabled)
    }

    /// Apply to the process-wide flight recorder.
    pub fn apply(&self) {
        crate::telemetry::set_enabled(self.enabled);
    }
}

/// How the participation cohort of a round is drawn from the client pool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SamplingStrategy {
    /// Fixed-size uniform without replacement: exactly
    /// `dispatch_size(N)` clients per round.  Earns DP amplification at
    /// the realized rate — NOTE this applies the Poisson-subsampling RDP
    /// bound to a fixed-size draw, the standard production approximation
    /// (tf-privacy / Opacus practice); [`SamplingStrategy::Poisson`]
    /// matches the bound's hypothesis exactly, and an exact
    /// without-replacement accountant is a ROADMAP follow-up.
    Uniform,
    /// Poisson subsampling: every client is included independently with
    /// probability `sample_rate`, which is *exactly* the sampled
    /// Gaussian mechanism the accountant's RDP bound is proved for.
    /// Cohort size varies round to round (`over_provision`/`min_cohort`
    /// do not apply); an empty draw falls back to one uniformly chosen
    /// client so a round cannot abort.
    Poisson,
    /// Sample-count-weighted without replacement (Efraimidis–Spirakis
    /// keys); weights are the last-known per-client sample counts.
    WeightedBySamples,
    /// Clients are hashed into `strata` buckets; each round takes a
    /// session-stable priority slice round-robin across buckets, so the
    /// cohort is *sticky* (stable round over round) while still spread
    /// across strata.
    StickyStratified { strata: usize },
}

impl SamplingStrategy {
    /// Parse the wire/CLI string:
    /// `uniform | poisson | weighted | stratified[:k]`.
    pub fn parse(s: &str) -> Result<SamplingStrategy> {
        match s {
            "uniform" => Ok(SamplingStrategy::Uniform),
            "poisson" => Ok(SamplingStrategy::Poisson),
            "weighted" => Ok(SamplingStrategy::WeightedBySamples),
            "stratified" => Ok(SamplingStrategy::StickyStratified { strata: 4 }),
            s if s.starts_with("stratified:") => {
                // a malformed strata count must error, not silently run a
                // different stratification than the user asked for
                let k = &s["stratified:".len()..];
                let strata: usize = k.parse().map_err(|_| {
                    FedError::Config(format!(
                        "bad strata count '{k}' in sampling strategy '{s}'"
                    ))
                })?;
                Ok(SamplingStrategy::StickyStratified { strata: strata.max(1) })
            }
            other => Err(FedError::Config(format!(
                "unknown sampling strategy '{other}' \
                 (expected uniform | poisson | weighted | stratified[:k])"
            ))),
        }
    }

    pub fn as_string(&self) -> String {
        match self {
            SamplingStrategy::Uniform => "uniform".into(),
            SamplingStrategy::Poisson => "poisson".into(),
            SamplingStrategy::WeightedBySamples => "weighted".into(),
            SamplingStrategy::StickyStratified { strata } => {
                format!("stratified:{strata}")
            }
        }
    }

    /// Whether the sampling rate may be claimed as DP
    /// amplification-by-subsampling.  Poisson sampling satisfies the
    /// subsampled-Gaussian RDP theorem exactly; fixed-size uniform
    /// applies the same bound as the standard production approximation
    /// (see the variant docs).  Weighted sampling is data-dependent and
    /// sticky cohorts are not resampled at all, so both account at q = 1
    /// (no amplification — conservative).
    pub fn amplifies(&self) -> bool {
        matches!(self, SamplingStrategy::Uniform | SamplingStrategy::Poisson)
    }
}

/// How the learn-phase deadline of a round is resolved.
///
/// `Static` always uses the configured `deadline_ms`.  The percentile
/// modes close the round at that percentile of the cohort's recently
/// observed learn latencies × `deadline_margin`, clamped into
/// `[deadline_min_ms, deadline_max_ms]` — falling back to the static
/// `deadline_ms` until the latency tracker is warm (see
/// `coordinator::latency`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeadlineMode {
    /// Always use the static `deadline_ms`.
    Static,
    /// Median of observed learn latencies × margin.
    P50,
    /// 90th percentile of observed learn latencies × margin.
    P90,
    /// 99th percentile of observed learn latencies × margin.
    P99,
}

impl DeadlineMode {
    /// Parse the wire/CLI string: `static | p50 | p90 | p99`.
    pub fn parse(s: &str) -> Result<DeadlineMode> {
        Ok(match s {
            "static" => DeadlineMode::Static,
            "p50" => DeadlineMode::P50,
            "p90" => DeadlineMode::P90,
            "p99" => DeadlineMode::P99,
            other => {
                return Err(FedError::Config(format!(
                    "unknown deadline mode '{other}' \
                     (expected static | p50 | p90 | p99)"
                )))
            }
        })
    }

    /// Stable lowercase name used in the serialized form and the CLI.
    pub fn as_str(&self) -> &'static str {
        match self {
            DeadlineMode::Static => "static",
            DeadlineMode::P50 => "p50",
            DeadlineMode::P90 => "p90",
            DeadlineMode::P99 => "p99",
        }
    }

    /// The tracked quantile this mode closes at; `None` for `Static`.
    pub fn quantile(&self) -> Option<f64> {
        match self {
            DeadlineMode::Static => None,
            DeadlineMode::P50 => Some(0.50),
            DeadlineMode::P90 => Some(0.90),
            DeadlineMode::P99 => Some(0.99),
        }
    }
}

/// Partial-participation round configuration: cohort sampling, quorum and
/// deadline semantics.  Shared by the FACT server, the CLI, and the DART
/// REST round-config endpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct ParticipationConfig {
    /// Target sampling rate q ∈ (0, 1]: each round addresses ⌈q·N⌉ of the
    /// N pool clients.
    pub sample_rate: f64,
    /// Over-provisioning factor ≥ 1 applied to the target cohort before
    /// dispatch (extra clients absorb expected dropouts).
    pub over_provision: f64,
    /// Fraction of the dispatched cohort that must report before the
    /// round closes early (K-of-N).
    pub quorum: f64,
    /// Round deadline in milliseconds; 0 falls back to the server's
    /// round timeout.  The round closes at quorum or deadline, whichever
    /// comes first; results arriving later are dropped.
    pub deadline_ms: u64,
    /// Post-close window in which late arrivals are still *observed* (and
    /// counted in metrics) before being discarded.  0 skips the sweep.
    pub late_grace_ms: u64,
    /// How the effective learn deadline is resolved (static or a tracked
    /// latency percentile).
    pub deadline: DeadlineMode,
    /// Safety margin ≥ 1 multiplied onto the tracked percentile when
    /// `deadline` is adaptive.
    pub deadline_margin: f64,
    /// Floor on an adaptive deadline in milliseconds (0 = no floor).
    pub deadline_min_ms: u64,
    /// Cap on an adaptive deadline in milliseconds (0 = no cap).
    pub deadline_max_ms: u64,
    /// Floor on the cohort size (clamped to the pool size).
    pub min_cohort: usize,
    pub strategy: SamplingStrategy,
    /// Session seed; every round's draw is `splitmix64`-derived from it,
    /// so cohorts are reproducible given (seed, clustering round,
    /// cluster, round).
    pub seed: u64,
}

impl Default for ParticipationConfig {
    fn default() -> Self {
        ParticipationConfig {
            sample_rate: 1.0,
            over_provision: 1.0,
            quorum: 1.0,
            deadline_ms: 0,
            late_grace_ms: 0,
            deadline: DeadlineMode::Static,
            deadline_margin: 1.5,
            deadline_min_ms: 0,
            deadline_max_ms: 0,
            min_cohort: 1,
            strategy: SamplingStrategy::Uniform,
            seed: 0x5eed_c0c0_a11e_d000,
        }
    }
}

impl ParticipationConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.sample_rate > 0.0 && self.sample_rate <= 1.0) {
            return Err(FedError::Config(format!(
                "sample_rate must be in (0, 1], got {}",
                self.sample_rate
            )));
        }
        if !(self.quorum > 0.0 && self.quorum <= 1.0) {
            return Err(FedError::Config(format!(
                "quorum must be in (0, 1], got {}",
                self.quorum
            )));
        }
        if !(self.over_provision >= 1.0) {
            return Err(FedError::Config(format!(
                "over_provision must be >= 1, got {}",
                self.over_provision
            )));
        }
        if self.min_cohort == 0 {
            return Err(FedError::Config("min_cohort must be >= 1".into()));
        }
        if !(self.deadline_margin >= 1.0) {
            return Err(FedError::Config(format!(
                "deadline_margin must be >= 1, got {}",
                self.deadline_margin
            )));
        }
        if self.deadline_max_ms > 0 && self.deadline_max_ms < self.deadline_min_ms {
            return Err(FedError::Config(format!(
                "deadline_max_ms ({}) must be >= deadline_min_ms ({})",
                self.deadline_max_ms, self.deadline_min_ms
            )));
        }
        Ok(())
    }

    /// Clamp every field into its valid range (the server-side grant for
    /// REST-negotiated configs — the granted values are authoritative).
    pub fn normalized(mut self) -> ParticipationConfig {
        self.sample_rate = self.sample_rate.clamp(1e-6, 1.0);
        self.quorum = self.quorum.clamp(1e-6, 1.0);
        self.over_provision = self.over_provision.max(1.0);
        self.min_cohort = self.min_cohort.max(1);
        self.deadline_margin = self.deadline_margin.max(1.0);
        if self.deadline_max_ms > 0 {
            self.deadline_max_ms = self.deadline_max_ms.max(self.deadline_min_ms);
        }
        self
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("sample_rate", self.sample_rate)
            .set("over_provision", self.over_provision)
            .set("quorum", self.quorum)
            .set("deadline_ms", self.deadline_ms)
            .set("late_grace_ms", self.late_grace_ms)
            .set("deadline", self.deadline.as_str())
            .set("deadline_margin", self.deadline_margin)
            .set("deadline_min_ms", self.deadline_min_ms)
            .set("deadline_max_ms", self.deadline_max_ms)
            .set("min_cohort", self.min_cohort)
            .set("strategy", self.strategy.as_string())
            // decimal string: JSON numbers are f64 and silently corrupt
            // u64 seeds above 2^53 (the round-id hex precedent)
            .set("seed", self.seed.to_string())
    }

    pub fn from_json(j: &Json) -> Result<ParticipationConfig> {
        let d = ParticipationConfig::default();
        Ok(ParticipationConfig {
            sample_rate: j
                .get("sample_rate")
                .and_then(Json::as_f64)
                .unwrap_or(d.sample_rate),
            over_provision: j
                .get("over_provision")
                .and_then(Json::as_f64)
                .unwrap_or(d.over_provision),
            quorum: j.get("quorum").and_then(Json::as_f64).unwrap_or(d.quorum),
            // negative wire values must clamp to 0, not wrap to ~u64::MAX
            // (a wrapped deadline never fires; a wrapped grace sleeps the
            // round thread effectively forever)
            deadline_ms: j
                .get("deadline_ms")
                .and_then(Json::as_i64)
                .unwrap_or(d.deadline_ms as i64)
                .max(0) as u64,
            late_grace_ms: j
                .get("late_grace_ms")
                .and_then(Json::as_i64)
                .unwrap_or(d.late_grace_ms as i64)
                .max(0) as u64,
            deadline: match j.get("deadline").and_then(Json::as_str) {
                Some(s) => DeadlineMode::parse(s)?,
                None => d.deadline,
            },
            deadline_margin: j
                .get("deadline_margin")
                .and_then(Json::as_f64)
                .unwrap_or(d.deadline_margin),
            deadline_min_ms: j
                .get("deadline_min_ms")
                .and_then(Json::as_i64)
                .unwrap_or(d.deadline_min_ms as i64)
                .max(0) as u64,
            deadline_max_ms: j
                .get("deadline_max_ms")
                .and_then(Json::as_i64)
                .unwrap_or(d.deadline_max_ms as i64)
                .max(0) as u64,
            min_cohort: j
                .get("min_cohort")
                .and_then(Json::as_usize)
                .unwrap_or(d.min_cohort),
            strategy: match j.get("strategy").and_then(Json::as_str) {
                Some(s) => SamplingStrategy::parse(s)?,
                None => d.strategy,
            },
            seed: match j.get("seed") {
                None => d.seed,
                // string form is exact for the full u64 range
                Some(v) => match v.as_str() {
                    Some(s) => s.parse().map_err(|_| {
                        FedError::Config(format!("bad participation seed '{s}'"))
                    })?,
                    // legacy numeric form: best effort, negatives clamp
                    None => v.as_i64().unwrap_or(d.seed as i64).max(0) as u64,
                },
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_config_minimal() {
        let j = Json::parse(
            r#"{"server": "https://dart-server:7777", "client_key": "000"}"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&j).unwrap();
        assert_eq!(c.server, "https://dart-server:7777");
        assert_eq!(c.client_key, "000");
    }

    #[test]
    fn server_config_requires_server_key() {
        let j = Json::parse(r#"{"client_key": "000"}"#).unwrap();
        assert!(ServerConfig::from_json(&j).is_err());
    }

    #[test]
    fn device_file_with_null_hardware() {
        // the paper: "In test mode, these can be set to dummy values and
        // the hardware_config can be set to null" (§C.1.2)
        let j = Json::parse(
            r#"[{"ipAddress": "0.0.0.0", "port": 1, "hardware_config": null},
                {"name": "edge-7", "ipAddress": "10.0.0.7", "port": 2883,
                 "hardware_config": {"cpus": 8, "mem_gb": 16,
                                     "accelerator": "tpu"}}]"#,
        )
        .unwrap();
        let devs = parse_devices(&j).unwrap();
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[0].name, "client-0");
        assert_eq!(devs[0].hardware, HardwareConfig::default());
        assert_eq!(devs[1].name, "edge-7");
        assert_eq!(devs[1].hardware.cpus, 8);
        assert_eq!(devs[1].hardware.accelerator, "tpu");
    }

    #[test]
    fn hardware_satisfies() {
        let big = HardwareConfig { cpus: 8, mem_gb: 16, accelerator: "tpu".into() };
        let small = HardwareConfig { cpus: 2, mem_gb: 4, accelerator: "none".into() };
        assert!(big.satisfies(&small));
        assert!(!small.satisfies(&big));
        let need_tpu = HardwareConfig { cpus: 1, mem_gb: 1, accelerator: "tpu".into() };
        assert!(big.satisfies(&need_tpu));
        assert!(!small.satisfies(&need_tpu));
    }

    #[test]
    fn fl_config_defaults_and_overrides() {
        let j = Json::parse(r#"{"rounds": 5, "mu": 0.1}"#).unwrap();
        let c = FlConfig::from_json(&j);
        assert_eq!(c.rounds, 5);
        assert!((c.mu - 0.1).abs() < 1e-6);
        assert_eq!(c.model, "mlp_default");
        assert_eq!(c.local_steps, 4);
        assert_eq!(c.server_opt, "plain");
        assert_eq!(c.local_strategy, "plain");
        let j = Json::parse(
            r#"{"server_opt": "fedavgm:0.9:1.0", "local_strategy": "fednova"}"#,
        )
        .unwrap();
        let c = FlConfig::from_json(&j);
        assert_eq!(c.server_opt, "fedavgm:0.9:1.0");
        assert_eq!(c.local_strategy, "fednova");
    }

    #[test]
    fn sampling_strategy_parse_roundtrip() {
        for s in [
            SamplingStrategy::Uniform,
            SamplingStrategy::Poisson,
            SamplingStrategy::WeightedBySamples,
            SamplingStrategy::StickyStratified { strata: 3 },
        ] {
            assert_eq!(SamplingStrategy::parse(&s.as_string()).unwrap(), s);
        }
        assert_eq!(
            SamplingStrategy::parse("stratified").unwrap(),
            SamplingStrategy::StickyStratified { strata: 4 }
        );
        assert!(SamplingStrategy::parse("lottery").is_err());
        // malformed strata counts error instead of silently defaulting
        assert!(SamplingStrategy::parse("stratified:ten").is_err());
        assert!(SamplingStrategy::parse("stratified-8").is_err());
        assert_eq!(
            SamplingStrategy::parse("stratified:0").unwrap(),
            SamplingStrategy::StickyStratified { strata: 1 }
        );
        assert!(SamplingStrategy::Uniform.amplifies());
        assert!(SamplingStrategy::Poisson.amplifies());
        assert!(!SamplingStrategy::WeightedBySamples.amplifies());
        assert!(!SamplingStrategy::StickyStratified { strata: 2 }.amplifies());
    }

    #[test]
    fn participation_config_json_roundtrip_and_validation() {
        let cfg = ParticipationConfig {
            sample_rate: 0.25,
            over_provision: 1.5,
            quorum: 0.75,
            deadline_ms: 2_000,
            late_grace_ms: 100,
            deadline: DeadlineMode::P90,
            deadline_margin: 2.0,
            deadline_min_ms: 250,
            deadline_max_ms: 5_000,
            min_cohort: 3,
            strategy: SamplingStrategy::StickyStratified { strata: 2 },
            // above 2^53 AND bit 63 set: a numeric JSON roundtrip would
            // corrupt this; the string form must carry it exactly
            seed: 0xC0FF_EE01_2345_6789,
        };
        cfg.validate().unwrap();
        let back = ParticipationConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(back.seed, 0xC0FF_EE01_2345_6789);
        // legacy numeric seeds still parse (best effort)
        let num = ParticipationConfig::from_json(&Json::obj().set("seed", 42))
            .unwrap();
        assert_eq!(num.seed, 42);
        assert!(ParticipationConfig::from_json(
            &Json::obj().set("seed", "not-a-number")
        )
        .is_err());
        // defaults fill missing fields and validate
        let d = ParticipationConfig::from_json(&Json::obj()).unwrap();
        assert_eq!(d, ParticipationConfig::default());
        d.validate().unwrap();
        // bad strategy string is an error, bad numbers fail validation
        assert!(ParticipationConfig::from_json(
            &Json::obj().set("strategy", "lottery")
        )
        .is_err());
        let bad = ParticipationConfig { sample_rate: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
        assert!(bad.clone().normalized().validate().is_ok());
        let bad_q = ParticipationConfig { quorum: 1.5, ..Default::default() };
        assert!(bad_q.validate().is_err());
        assert!((bad_q.normalized().quorum - 1.0).abs() < 1e-12);
        // negative millisecond fields clamp to 0 instead of wrapping
        let neg = ParticipationConfig::from_json(
            &Json::obj().set("deadline_ms", -1).set("late_grace_ms", -500),
        )
        .unwrap();
        assert_eq!(neg.deadline_ms, 0);
        assert_eq!(neg.late_grace_ms, 0);
    }

    #[test]
    fn deadline_mode_parse_and_validation() {
        for m in [
            DeadlineMode::Static,
            DeadlineMode::P50,
            DeadlineMode::P90,
            DeadlineMode::P99,
        ] {
            assert_eq!(DeadlineMode::parse(m.as_str()).unwrap(), m);
        }
        assert!(DeadlineMode::parse("p75").is_err());
        assert_eq!(DeadlineMode::Static.quantile(), None);
        assert!((DeadlineMode::P90.quantile().unwrap() - 0.9).abs() < 1e-12);
        // a bad deadline mode string errors through from_json like a bad
        // strategy does
        assert!(ParticipationConfig::from_json(
            &Json::obj().set("deadline", "p75")
        )
        .is_err());
        // margin below 1 is rejected, normalized() heals it
        let bad = ParticipationConfig {
            deadline_margin: 0.5,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        assert!((bad.clone().normalized().deadline_margin - 1.0).abs() < 1e-12);
        // an inverted clamp window is rejected (max > 0 only)
        let inv = ParticipationConfig {
            deadline_min_ms: 500,
            deadline_max_ms: 100,
            ..Default::default()
        };
        assert!(inv.validate().is_err());
        assert_eq!(inv.clone().normalized().deadline_max_ms, 500);
        let uncapped = ParticipationConfig {
            deadline_min_ms: 500,
            deadline_max_ms: 0,
            ..Default::default()
        };
        uncapped.validate().unwrap();
        // adaptive fields survive the wire; missing fields default Static
        let j = Json::obj()
            .set("deadline", "p99")
            .set("deadline_margin", 3.0)
            .set("deadline_min_ms", -5)
            .set("deadline_max_ms", 9_000);
        let c = ParticipationConfig::from_json(&j).unwrap();
        assert_eq!(c.deadline, DeadlineMode::P99);
        assert!((c.deadline_margin - 3.0).abs() < 1e-12);
        assert_eq!(c.deadline_min_ms, 0); // negative clamps, never wraps
        assert_eq!(c.deadline_max_ms, 9_000);
        let d = ParticipationConfig::from_json(&Json::obj()).unwrap();
        assert_eq!(d.deadline, DeadlineMode::Static);
    }

    #[test]
    fn config_roundtrip_through_files() {
        let dir = std::env::temp_dir().join("feddart-config-test");
        std::fs::create_dir_all(&dir).unwrap();
        let sc = ServerConfig { server: "127.0.0.1:7777".into(), client_key: "abc".into() };
        let p = dir.join("server.json");
        std::fs::write(&p, sc.to_json().to_string()).unwrap();
        let back = ServerConfig::load(&p).unwrap();
        assert_eq!(back.server, sc.server);
        assert_eq!(back.client_key, sc.client_key);
    }
}
