//! Metrics registry: counters, gauges, and histograms with a JSON snapshot.
//!
//! The Rust coordinator owns "the event loop, process topology, metrics, CLI"
//! (session architecture); every subsystem reports here and the REST-API
//! exposes `/metrics` for scraping.

pub mod logserver;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::json::Json;

/// A monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge that can go up and down.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Histogram storing raw samples (bounded reservoir) + running aggregates
/// + cumulative fixed buckets.
///
/// The reservoir gives exact-ish quantiles for the JSON snapshot but is
/// unsuitable for scraping (a scraper cannot merge or rate() sampled
/// quantiles); the fixed powers-of-2 bucket ladder gives Prometheus the
/// cumulative counts it needs for `histogram_quantile()`.
pub struct Histogram {
    inner: Mutex<HistInner>,
}

struct HistInner {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// bounded sample reservoir for quantiles
    samples: Vec<f64>,
    /// non-cumulative counts per fixed bucket; `buckets[i]` counts
    /// observations `v <= BUCKET_BOUNDS[i]` (and greater than the
    /// previous bound), the last slot is the +Inf overflow
    buckets: [u64; NBUCKETS],
    /// per-histogram reservoir RNG.  A shared `splitmix64(count)` stream
    /// made every histogram at the same count overwrite the *same* index
    /// (correlated reservoirs) and skewed the acceptance probability away
    /// from the unbiased `RESERVOIR / count` of Vitter's algorithm R.
    rng: crate::util::rng::Rng,
}

const RESERVOIR: usize = 4096;

/// Fixed bucket upper bounds: a powers-of-2 millisecond ladder from 1 ms
/// to ~17.5 min.  One ladder for every histogram keeps scraped series
/// mergeable across instances.
pub const BUCKET_BOUNDS: [f64; 21] = [
    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0,
    16384.0, 32768.0, 65536.0, 131072.0, 262144.0, 524288.0, 1048576.0,
];
/// `BUCKET_BOUNDS.len() + 1` — the extra slot is the +Inf overflow bucket.
const NBUCKETS: usize = BUCKET_BOUNDS.len() + 1;

/// Distinct seed per histogram instance.
static HIST_SEED: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(0x9E37_79B9_7F4A_7C15);

impl Default for Histogram {
    fn default() -> Self {
        let seed = HIST_SEED.fetch_add(0x6A09_E667_F3BC_C909, Ordering::Relaxed);
        Histogram {
            inner: Mutex::new(HistInner {
                count: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
                samples: Vec::new(),
                buckets: [0; NBUCKETS],
                rng: crate::util::rng::Rng::new(seed),
            }),
        }
    }
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        let mut h = self.inner.lock().unwrap();
        h.count += 1;
        h.sum += v;
        h.min = h.min.min(v);
        h.max = h.max.max(v);
        let bucket = BUCKET_BOUNDS
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(NBUCKETS - 1);
        h.buckets[bucket] += 1;
        if h.samples.len() < RESERVOIR {
            h.samples.push(v);
        } else {
            // Vitter's algorithm R: replace a uniformly drawn index of
            // [0, count); acceptance probability is exactly RESERVOIR/count,
            // keeping the reservoir a uniform sample of everything seen
            let n = h.count as usize;
            let idx = h.rng.below(n);
            if idx < RESERVOIR {
                h.samples[idx] = v;
            }
        }
    }

    #[cfg(test)]
    fn raw_samples(&self) -> Vec<f64> {
        self.inner.lock().unwrap().samples.clone()
    }

    pub fn count(&self) -> u64 {
        self.inner.lock().unwrap().count
    }

    pub fn mean(&self) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.count == 0 {
            0.0
        } else {
            h.sum / h.count as f64
        }
    }

    /// Quantile estimate from the reservoir (q in [0,1]).
    pub fn quantile(&self, q: f64) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.samples.is_empty() {
            return 0.0;
        }
        let mut s = h.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        s[idx]
    }

    pub fn sum(&self) -> f64 {
        self.inner.lock().unwrap().sum
    }

    /// Cumulative fixed-bucket counts: `(upper_bound, count_le_bound)`
    /// pairs ending with `(f64::INFINITY, total_count)` — the Prometheus
    /// `_bucket{le=...}` series.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let h = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(NBUCKETS);
        let mut cum = 0u64;
        for (i, &bound) in BUCKET_BOUNDS.iter().enumerate() {
            cum += h.buckets[i];
            out.push((bound, cum));
        }
        cum += h.buckets[NBUCKETS - 1];
        out.push((f64::INFINITY, cum));
        out
    }

    pub fn snapshot(&self) -> Json {
        let h = self.inner.lock().unwrap();
        let (min, max) = if h.count == 0 {
            (0.0, 0.0)
        } else {
            (h.min, h.max)
        };
        drop(h);
        // cumulative buckets ride alongside the reservoir quantiles; the
        // pre-existing keys stay byte-identical for old consumers
        let mut buckets = Json::obj();
        for (bound, cum) in self.cumulative_buckets() {
            let le = if bound.is_infinite() {
                "+Inf".to_string()
            } else {
                format!("{bound}")
            };
            buckets = buckets.set(&le, cum);
        }
        Json::obj()
            .set("count", self.count())
            .set("mean", self.mean())
            .set("min", min)
            .set("max", max)
            .set("p50", self.quantile(0.5))
            .set("p95", self.quantile(0.95))
            .set("p99", self.quantile(0.99))
            .set("buckets", buckets)
    }
}

/// Canonical storage key for a labeled metric: `name{k="v",...}` with
/// label keys sorted, so the same label set always maps to one series.
pub fn labeled_key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut ls: Vec<(&str, &str)> = labels.to_vec();
    ls.sort();
    let body: Vec<String> = ls
        .iter()
        .map(|(k, v)| {
            format!(
                "{k}=\"{}\"",
                v.replace('\\', "\\\\").replace('"', "\\\"")
            )
        })
        .collect();
    format!("{name}{{{}}}", body.join(","))
}

/// Named metrics registry shared across the process.
#[derive(Default, Clone)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.inner
                .counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            self.inner
                .gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.inner
                .histograms
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Observe a duration in milliseconds under `name`.
    pub fn time_ms(&self, name: &str, ms: f64) {
        self.histogram(name).observe(ms);
    }

    // ------------------------------------------------ labeled variants
    //
    // Labeled series share the name maps with plain ones under canonical
    // `name{k="v",...}` keys, so snapshots and exposition need no second
    // bookkeeping path.  Label cardinality is the caller's problem: keep
    // label values bounded (phase names, retry kinds, cohort members).

    pub fn counter_labeled(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        self.counter(&labeled_key(name, labels))
    }

    pub fn gauge_labeled(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        self.gauge(&labeled_key(name, labels))
    }

    pub fn histogram_labeled(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        self.histogram(&labeled_key(name, labels))
    }

    /// Snapshot of every histogram whose base name starts with `prefix`
    /// (labeled keys included) — `GET /rounds/recovery` phase timings.
    pub fn histograms_with_prefix(&self, prefix: &str) -> Vec<(String, Arc<Histogram>)> {
        self.inner
            .histograms
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect()
    }

    /// JSON snapshot of everything (served at `/metrics`).
    pub fn snapshot(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in self.inner.counters.lock().unwrap().iter() {
            counters = counters.set(k, v.get());
        }
        let mut gauges = Json::obj();
        for (k, v) in self.inner.gauges.lock().unwrap().iter() {
            gauges = gauges.set(k, v.get());
        }
        let mut hists = Json::obj();
        for (k, v) in self.inner.histograms.lock().unwrap().iter() {
            hists = hists.set(k, v.snapshot());
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
    }

    /// Prometheus text exposition (format 0.0.4) of everything — served
    /// at `GET /metrics` under `Accept: text/plain`.  Dotted names become
    /// underscore names; labeled series keep their labels; histograms
    /// expose the cumulative fixed buckets as `_bucket{le=...}` plus
    /// `_sum`/`_count`.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let mut typed: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        for (key, c) in self.inner.counters.lock().unwrap().iter() {
            let (name, labels) = prom_split(key);
            if typed.insert(name.clone()) {
                out.push_str(&format!("# TYPE {name} counter\n"));
            }
            out.push_str(&format!("{name}{labels} {}\n", c.get()));
        }
        for (key, g) in self.inner.gauges.lock().unwrap().iter() {
            let (name, labels) = prom_split(key);
            if typed.insert(name.clone()) {
                out.push_str(&format!("# TYPE {name} gauge\n"));
            }
            out.push_str(&format!("{name}{labels} {}\n", g.get()));
        }
        for (key, h) in self.inner.histograms.lock().unwrap().iter() {
            let (name, labels) = prom_split(key);
            if typed.insert(name.clone()) {
                out.push_str(&format!("# TYPE {name} histogram\n"));
            }
            let label_body = labels
                .strip_prefix('{')
                .and_then(|s| s.strip_suffix('}'))
                .unwrap_or("");
            for (bound, cum) in h.cumulative_buckets() {
                let le = if bound.is_infinite() {
                    "+Inf".to_string()
                } else {
                    format!("{bound}")
                };
                let merged = if label_body.is_empty() {
                    format!("le=\"{le}\"")
                } else {
                    format!("{label_body},le=\"{le}\"")
                };
                out.push_str(&format!("{name}_bucket{{{merged}}} {cum}\n"));
            }
            out.push_str(&format!("{name}_sum{labels} {}\n", h.sum()));
            out.push_str(&format!("{name}_count{labels} {}\n", h.count()));
        }
        out
    }
}

/// Split a canonical storage key into a Prometheus-sanitized base name
/// and its verbatim `{...}` label block (empty when unlabeled).
fn prom_split(key: &str) -> (String, String) {
    let (base, labels) = match key.find('{') {
        Some(i) => (&key[..i], key[i..].to_string()),
        None => (key, String::new()),
    };
    let name: String = base
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect();
    (name, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        r.counter("tasks.accepted").inc();
        r.counter("tasks.accepted").add(2);
        assert_eq!(r.counter("tasks.accepted").get(), 3);
        r.gauge("clients.connected").set(5);
        r.gauge("clients.connected").add(-2);
        assert_eq!(r.gauge("clients.connected").get(), 3);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        assert!((45.0..=56.0).contains(&p50), "p50 {p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 >= 98.0, "p99 {p99}");
    }

    #[test]
    fn histogram_reservoir_bounded() {
        let h = Histogram::default();
        for i in 0..20_000 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 20_000);
        // quantiles still sane after reservoir churn
        let p50 = h.quantile(0.5);
        assert!((5_000.0..15_000.0).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn reservoirs_are_decorrelated_across_histograms() {
        // regression: the old splitmix64(count) % count replacement index
        // was a pure function of the count, so every histogram at the same
        // count overwrote identical slots — two histograms fed the same
        // stream kept byte-identical reservoirs forever
        let a = Histogram::default();
        let b = Histogram::default();
        for i in 0..3 * RESERVOIR {
            a.observe(i as f64);
            b.observe(i as f64);
        }
        assert_eq!(a.count(), b.count());
        assert_ne!(
            a.raw_samples(),
            b.raw_samples(),
            "independent histograms must not share a replacement stream"
        );
    }

    #[test]
    fn reservoir_acceptance_is_uniform_over_stream() {
        // algorithm R keeps the reservoir a uniform sample of the whole
        // stream: after R zeros then R ones, the expected fraction of
        // ones in the reservoir is 1/2 (sd ≈ 1/(2√R) ≈ 0.008)
        let h = Histogram::default();
        for _ in 0..RESERVOIR {
            h.observe(0.0);
        }
        for _ in 0..RESERVOIR {
            h.observe(1.0);
        }
        let ones = h
            .raw_samples()
            .iter()
            .filter(|&&v| v == 1.0)
            .count() as f64;
        let frac = ones / RESERVOIR as f64;
        assert!(
            (0.42..=0.58).contains(&frac),
            "reservoir holds {frac:.3} ones, expected ~0.5"
        );
    }

    #[test]
    fn snapshot_shape() {
        let r = Registry::new();
        r.counter("c").inc();
        r.gauge("g").set(-1);
        r.histogram("h").observe(2.0);
        let s = r.snapshot();
        assert_eq!(
            s.get("counters").unwrap().get("c").unwrap().as_i64(),
            Some(1)
        );
        assert_eq!(s.get("gauges").unwrap().get("g").unwrap().as_i64(), Some(-1));
        assert_eq!(
            s.get("histograms")
                .unwrap()
                .get("h")
                .unwrap()
                .get("count")
                .unwrap()
                .as_i64(),
            Some(1)
        );
    }

    #[test]
    fn registry_is_shared_via_clone() {
        let r = Registry::new();
        let r2 = r.clone();
        r.counter("x").inc();
        assert_eq!(r2.counter("x").get(), 1);
    }

    #[test]
    fn labeled_keys_are_canonical() {
        assert_eq!(labeled_key("m", &[]), "m");
        assert_eq!(
            labeled_key("m", &[("b", "2"), ("a", "1")]),
            "m{a=\"1\",b=\"2\"}"
        );
        // label order does not split the series
        let r = Registry::new();
        r.counter_labeled("dart.wire.retries", &[("kind", "results")]).inc();
        r.counter_labeled("dart.wire.retries", &[("kind", "results")]).add(2);
        assert_eq!(
            r.counter("dart.wire.retries{kind=\"results\"}").get(),
            3
        );
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let h = Histogram::default();
        h.observe(0.5); // le=1
        h.observe(3.0); // le=4
        h.observe(3.5); // le=4
        h.observe(2_000_000.0); // +Inf overflow
        let b = h.cumulative_buckets();
        assert_eq!(b[0], (1.0, 1));
        assert_eq!(b[1], (2.0, 1));
        assert_eq!(b[2], (4.0, 3));
        let (inf, total) = *b.last().unwrap();
        assert!(inf.is_infinite());
        assert_eq!(total, 4);
        // snapshot carries them without disturbing the legacy keys
        let s = h.snapshot();
        assert_eq!(s.get("count").unwrap().as_i64(), Some(4));
        assert_eq!(
            s.get("buckets").unwrap().get("4").unwrap().as_i64(),
            Some(3)
        );
        assert_eq!(
            s.get("buckets").unwrap().get("+Inf").unwrap().as_i64(),
            Some(4)
        );
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::new();
        r.counter("dart.wire.retries").add(5);
        r.counter_labeled("dart.wire.retries", &[("kind", "results")]).add(2);
        r.gauge("clients.connected").set(3);
        r.histogram_labeled("fact.round.phase_ms", &[("phase", "keys"), ("cluster", "0")])
            .observe(3.0);
        let text = r.prometheus();
        assert!(text.contains("# TYPE dart_wire_retries counter\n"), "{text}");
        assert!(text.contains("dart_wire_retries 5\n"), "{text}");
        assert!(
            text.contains("dart_wire_retries{kind=\"results\"} 2\n"),
            "{text}"
        );
        assert!(text.contains("# TYPE clients_connected gauge\n"), "{text}");
        assert!(text.contains("# TYPE fact_round_phase_ms histogram\n"), "{text}");
        assert!(
            text.contains(
                "fact_round_phase_ms_bucket{cluster=\"0\",phase=\"keys\",le=\"4\"} 1\n"
            ),
            "{text}"
        );
        assert!(
            text.contains("fact_round_phase_ms_bucket{cluster=\"0\",phase=\"keys\",le=\"+Inf\"} 1\n"),
            "{text}"
        );
        assert!(
            text.contains("fact_round_phase_ms_count{cluster=\"0\",phase=\"keys\"} 1\n"),
            "{text}"
        );
        // every TYPE line precedes its samples exactly once
        assert_eq!(text.matches("# TYPE dart_wire_retries counter").count(), 1);
    }

    #[test]
    fn histograms_with_prefix_finds_labeled_series() {
        let r = Registry::new();
        r.histogram_labeled("fact.round.phase_ms", &[("phase", "keys"), ("cluster", "0")])
            .observe(1.0);
        r.histogram("other").observe(1.0);
        let found = r.histograms_with_prefix("fact.round.phase_ms");
        assert_eq!(found.len(), 1);
        assert!(found[0].0.contains("phase=\"keys\""));
    }
}
