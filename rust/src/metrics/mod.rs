//! Metrics registry: counters, gauges, and histograms with a JSON snapshot.
//!
//! The Rust coordinator owns "the event loop, process topology, metrics, CLI"
//! (session architecture); every subsystem reports here and the REST-API
//! exposes `/metrics` for scraping.

pub mod logserver;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::json::Json;

/// A monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge that can go up and down.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Histogram storing raw samples (bounded reservoir) + running aggregates.
pub struct Histogram {
    inner: Mutex<HistInner>,
}

struct HistInner {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// bounded sample reservoir for quantiles
    samples: Vec<f64>,
    /// per-histogram reservoir RNG.  A shared `splitmix64(count)` stream
    /// made every histogram at the same count overwrite the *same* index
    /// (correlated reservoirs) and skewed the acceptance probability away
    /// from the unbiased `RESERVOIR / count` of Vitter's algorithm R.
    rng: crate::util::rng::Rng,
}

const RESERVOIR: usize = 4096;

/// Distinct seed per histogram instance.
static HIST_SEED: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(0x9E37_79B9_7F4A_7C15);

impl Default for Histogram {
    fn default() -> Self {
        let seed = HIST_SEED.fetch_add(0x6A09_E667_F3BC_C909, Ordering::Relaxed);
        Histogram {
            inner: Mutex::new(HistInner {
                count: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
                samples: Vec::new(),
                rng: crate::util::rng::Rng::new(seed),
            }),
        }
    }
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        let mut h = self.inner.lock().unwrap();
        h.count += 1;
        h.sum += v;
        h.min = h.min.min(v);
        h.max = h.max.max(v);
        if h.samples.len() < RESERVOIR {
            h.samples.push(v);
        } else {
            // Vitter's algorithm R: replace a uniformly drawn index of
            // [0, count); acceptance probability is exactly RESERVOIR/count,
            // keeping the reservoir a uniform sample of everything seen
            let n = h.count as usize;
            let idx = h.rng.below(n);
            if idx < RESERVOIR {
                h.samples[idx] = v;
            }
        }
    }

    #[cfg(test)]
    fn raw_samples(&self) -> Vec<f64> {
        self.inner.lock().unwrap().samples.clone()
    }

    pub fn count(&self) -> u64 {
        self.inner.lock().unwrap().count
    }

    pub fn mean(&self) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.count == 0 {
            0.0
        } else {
            h.sum / h.count as f64
        }
    }

    /// Quantile estimate from the reservoir (q in [0,1]).
    pub fn quantile(&self, q: f64) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.samples.is_empty() {
            return 0.0;
        }
        let mut s = h.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        s[idx]
    }

    pub fn snapshot(&self) -> Json {
        let h = self.inner.lock().unwrap();
        let (min, max) = if h.count == 0 {
            (0.0, 0.0)
        } else {
            (h.min, h.max)
        };
        drop(h);
        Json::obj()
            .set("count", self.count())
            .set("mean", self.mean())
            .set("min", min)
            .set("max", max)
            .set("p50", self.quantile(0.5))
            .set("p95", self.quantile(0.95))
            .set("p99", self.quantile(0.99))
    }
}

/// Named metrics registry shared across the process.
#[derive(Default, Clone)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.inner
                .counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            self.inner
                .gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.inner
                .histograms
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Observe a duration in milliseconds under `name`.
    pub fn time_ms(&self, name: &str, ms: f64) {
        self.histogram(name).observe(ms);
    }

    /// JSON snapshot of everything (served at `/metrics`).
    pub fn snapshot(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in self.inner.counters.lock().unwrap().iter() {
            counters = counters.set(k, v.get());
        }
        let mut gauges = Json::obj();
        for (k, v) in self.inner.gauges.lock().unwrap().iter() {
            gauges = gauges.set(k, v.get());
        }
        let mut hists = Json::obj();
        for (k, v) in self.inner.histograms.lock().unwrap().iter() {
            hists = hists.set(k, v.snapshot());
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        r.counter("tasks.accepted").inc();
        r.counter("tasks.accepted").add(2);
        assert_eq!(r.counter("tasks.accepted").get(), 3);
        r.gauge("clients.connected").set(5);
        r.gauge("clients.connected").add(-2);
        assert_eq!(r.gauge("clients.connected").get(), 3);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        assert!((45.0..=56.0).contains(&p50), "p50 {p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 >= 98.0, "p99 {p99}");
    }

    #[test]
    fn histogram_reservoir_bounded() {
        let h = Histogram::default();
        for i in 0..20_000 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 20_000);
        // quantiles still sane after reservoir churn
        let p50 = h.quantile(0.5);
        assert!((5_000.0..15_000.0).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn reservoirs_are_decorrelated_across_histograms() {
        // regression: the old splitmix64(count) % count replacement index
        // was a pure function of the count, so every histogram at the same
        // count overwrote identical slots — two histograms fed the same
        // stream kept byte-identical reservoirs forever
        let a = Histogram::default();
        let b = Histogram::default();
        for i in 0..3 * RESERVOIR {
            a.observe(i as f64);
            b.observe(i as f64);
        }
        assert_eq!(a.count(), b.count());
        assert_ne!(
            a.raw_samples(),
            b.raw_samples(),
            "independent histograms must not share a replacement stream"
        );
    }

    #[test]
    fn reservoir_acceptance_is_uniform_over_stream() {
        // algorithm R keeps the reservoir a uniform sample of the whole
        // stream: after R zeros then R ones, the expected fraction of
        // ones in the reservoir is 1/2 (sd ≈ 1/(2√R) ≈ 0.008)
        let h = Histogram::default();
        for _ in 0..RESERVOIR {
            h.observe(0.0);
        }
        for _ in 0..RESERVOIR {
            h.observe(1.0);
        }
        let ones = h
            .raw_samples()
            .iter()
            .filter(|&&v| v == 1.0)
            .count() as f64;
        let frac = ones / RESERVOIR as f64;
        assert!(
            (0.42..=0.58).contains(&frac),
            "reservoir holds {frac:.3} ones, expected ~0.5"
        );
    }

    #[test]
    fn snapshot_shape() {
        let r = Registry::new();
        r.counter("c").inc();
        r.gauge("g").set(-1);
        r.histogram("h").observe(2.0);
        let s = r.snapshot();
        assert_eq!(
            s.get("counters").unwrap().get("c").unwrap().as_i64(),
            Some(1)
        );
        assert_eq!(s.get("gauges").unwrap().get("g").unwrap().as_i64(), Some(-1));
        assert_eq!(
            s.get("histograms")
                .unwrap()
                .get("h")
                .unwrap()
                .get("count")
                .unwrap()
                .as_i64(),
            Some(1)
        );
    }

    #[test]
    fn registry_is_shared_via_clone() {
        let r = Registry::new();
        let r2 = r.clone();
        r.counter("x").inc();
        assert_eq!(r2.counter("x").get(), 1);
    }
}
