//! LogServer — the paper's levelled logging component (§A.2):
//! "logs the communication between the DART-Server and the involved classes
//! ... The user can specify different log levels. Especially for debugging
//! distributed systems it is of essential advantage."
//!
//! Implements the `log` crate facade (so every module just uses
//! `log::info!` etc.) while additionally retaining recent records in a ring
//! buffer that the REST-API serves at `/logs`.

use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock};

use log::{Level, LevelFilter, Metadata, Record};

use crate::json::Json;
use crate::util::now_ms;

const RING_CAPACITY: usize = 4096;

/// One retained log record.
#[derive(Debug, Clone)]
pub struct LogRecord {
    pub ts_ms: u64,
    pub level: Level,
    pub target: String,
    pub message: String,
    /// Trace context active on the logging thread, if any — log lines
    /// emitted inside a round phase carry the round's trace/span ids so
    /// `/logs` output can be correlated with `/trace/{round_id}`.
    pub trace: Option<crate::telemetry::SpanContext>,
}

/// The global LogServer instance (install with [`LogServer::init`]).
pub struct LogServer {
    ring: Mutex<VecDeque<LogRecord>>,
    stderr_level: LevelFilter,
}

static INSTANCE: OnceLock<LogServer> = OnceLock::new();

impl LogServer {
    /// Install as the `log` crate's global logger.  Idempotent; later calls
    /// keep the first configuration.
    pub fn init(stderr_level: LevelFilter) -> &'static LogServer {
        let inst = INSTANCE.get_or_init(|| LogServer {
            ring: Mutex::new(VecDeque::with_capacity(RING_CAPACITY)),
            stderr_level,
        });
        let _ = log::set_logger(inst);
        log::set_max_level(LevelFilter::Debug);
        inst
    }

    /// The installed instance, if any.
    pub fn get() -> Option<&'static LogServer> {
        INSTANCE.get()
    }

    /// Most recent `n` records (newest last).
    pub fn tail(&self, n: usize) -> Vec<LogRecord> {
        let ring = self.ring.lock().unwrap();
        ring.iter().rev().take(n).cloned().collect::<Vec<_>>()
            .into_iter().rev().collect()
    }

    /// Number of retained records.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// JSON view for the REST-API `/logs` endpoint.
    pub fn snapshot(&self, n: usize) -> Json {
        Json::Arr(
            self.tail(n)
                .into_iter()
                .map(|r| {
                    let mut j = Json::obj()
                        .set("ts_ms", r.ts_ms)
                        .set("level", r.level.as_str())
                        .set("target", r.target.as_str())
                        .set("message", r.message.as_str());
                    if let Some(ctx) = r.trace {
                        j = j.set("trace", ctx.to_json());
                    }
                    j
                })
                .collect(),
        )
    }

    fn push(&self, rec: LogRecord) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == RING_CAPACITY {
            ring.pop_front();
        }
        ring.push_back(rec);
    }
}

impl log::Log for LogServer {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= Level::Debug
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let trace = crate::telemetry::current();
        let rec = LogRecord {
            ts_ms: now_ms(),
            level: record.level(),
            target: record.target().to_string(),
            message: record.args().to_string(),
            trace,
        };
        if record.level() <= self.stderr_level {
            // plain stderr when no span is active; trace-suffixed inside one
            match &rec.trace {
                None => eprintln!(
                    "[{:>8}ms {:>5} {}] {}",
                    rec.ts_ms, rec.level, rec.target, rec.message
                ),
                Some(ctx) => eprintln!(
                    "[{:>8}ms {:>5} {}] {} [trace={:x} span={:x} round={:x}]",
                    rec.ts_ms,
                    rec.level,
                    rec.target,
                    rec.message,
                    ctx.trace_id,
                    ctx.span_id,
                    ctx.round_id
                ),
            }
        }
        // mirror into the active trace so the flight recorder holds the
        // log line next to the spans it happened inside
        crate::telemetry::log_event(
            rec.level.as_str(),
            &rec.target,
            &rec.message,
        );
        self.push(rec);
    }

    fn flush(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_buffer_retains_and_bounds() {
        // Use a private instance to avoid global logger interference.
        let ls = LogServer {
            ring: Mutex::new(VecDeque::new()),
            stderr_level: LevelFilter::Off,
        };
        for i in 0..(RING_CAPACITY + 10) {
            ls.push(LogRecord {
                ts_ms: i as u64,
                level: Level::Info,
                target: "t".into(),
                message: format!("m{i}"),
                trace: None,
            });
        }
        assert_eq!(ls.len(), RING_CAPACITY);
        let tail = ls.tail(3);
        assert_eq!(tail.len(), 3);
        assert_eq!(tail[2].message, format!("m{}", RING_CAPACITY + 9));
        // newest-last ordering
        assert!(tail[0].ts_ms < tail[2].ts_ms);
    }

    #[test]
    fn snapshot_is_json_array() {
        let ls = LogServer {
            ring: Mutex::new(VecDeque::new()),
            stderr_level: LevelFilter::Off,
        };
        ls.push(LogRecord {
            ts_ms: 1,
            level: Level::Warn,
            target: "dart".into(),
            message: "client lost".into(),
            trace: None,
        });
        let j = ls.snapshot(10);
        assert_eq!(j.as_arr().unwrap().len(), 1);
        assert_eq!(
            j.idx(0).unwrap().get("level").unwrap().as_str(),
            Some("WARN")
        );
    }
}
