//! Aggregation algorithms (paper §B.3, §C.1.1).
//!
//! "FACT offers a variety of pre-implemented aggregation algorithms or if
//! needed, new ones can be added easily through the modular design."
//!
//! Shipped: (weighted) federated averaging [McMahan et al.], FedProx [Li et
//! al.] (server side identical to weighted FedAvg — the proximal term acts
//! in the *client* objective, carried by the `mu` hyperparameter), and two
//! robust rules (coordinate-wise median and trimmed mean) demonstrating the
//! "new ones can be added easily" extension point.  The HLO-fused variant
//! (L1 Pallas kernel) lives behind [`hlo_fedavg`] and is benched in E7.

use crate::coordinator::aggregator::{flat_reduce_weighted, parallel_reduce_weighted};
use crate::error::{FedError, Result};
use crate::runtime::{Engine, Tensor};
use crate::util::pool::ThreadPool;
use crate::util::tensorbuf::TensorBuf;

/// One client's round contribution.  `params` is the received tensor
/// buffer itself — aggregation reduces over zero-copy views of it, so a
/// binary-path update is never re-materialized as an owned `Vec<f32>`.
#[derive(Debug, Clone)]
pub struct ClientUpdate {
    pub device: String,
    pub params: TensorBuf,
    /// local sample count (the FedAvg weight)
    pub n_samples: f32,
    /// mean local training loss (observability / stopping criteria)
    pub loss: f32,
    /// client wall time in seconds (paper taskResult.duration)
    pub duration: f64,
    /// client-reported effective local step count (FedNova normalized
    /// averaging; 0 when the round did not run under FedNova)
    pub tau: f32,
}

/// The aggregation rule.
#[derive(Debug, Clone, PartialEq)]
pub enum Aggregation {
    /// uniform average over clients
    FedAvg,
    /// sample-count-weighted average (the McMahan et al. estimator)
    WeightedFedAvg,
    /// server side of FedProx == weighted FedAvg; clients add the proximal
    /// term (mu) to their local objective
    FedProx,
    /// coordinate-wise median (robust to outliers / poisoned clients)
    Median,
    /// coordinate-wise trimmed mean, discarding `trim` clients at each end
    TrimmedMean { trim: usize },
}

impl Aggregation {
    /// Whether this rule can run under secure aggregation.  Masked
    /// aggregation only ever recovers the weighted *sum* of updates, so
    /// linear rules (FedAvg / weighted / FedProx) compose with it, while
    /// the order-statistic rules (median, trimmed mean) need the
    /// individual updates the masking deliberately hides.
    pub fn supports_secure_sum(&self) -> bool {
        matches!(
            self,
            Aggregation::FedAvg | Aggregation::WeightedFedAvg | Aggregation::FedProx
        )
    }

    /// Whether client contributions are weighted by sample count (decides
    /// the client-side pre-weighting under secure aggregation).
    pub fn is_weighted(&self) -> bool {
        matches!(self, Aggregation::WeightedFedAvg | Aggregation::FedProx)
    }

    /// Parse from a config string.
    pub fn parse(s: &str) -> Result<Aggregation> {
        match s {
            "fedavg" => Ok(Aggregation::FedAvg),
            "weighted_fedavg" => Ok(Aggregation::WeightedFedAvg),
            "fedprox" => Ok(Aggregation::FedProx),
            "median" => Ok(Aggregation::Median),
            s if s.starts_with("trimmed_mean") => {
                let trim = s
                    .split(':')
                    .nth(1)
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(1);
                Ok(Aggregation::TrimmedMean { trim })
            }
            other => Err(FedError::Fact(format!("unknown aggregation '{other}'"))),
        }
    }

    /// Aggregate client updates into new global parameters.
    ///
    /// `pool` enables the Aggregator-tree parallel reduction for large K;
    /// pass `None` for the flat loop.
    pub fn aggregate(
        &self,
        updates: &[ClientUpdate],
        pool: Option<&ThreadPool>,
    ) -> Result<Vec<f32>> {
        if updates.is_empty() {
            return Err(FedError::Fact("no updates to aggregate".into()));
        }
        let p = updates[0].params.len();
        if updates.iter().any(|u| u.params.len() != p) {
            return Err(FedError::Fact("update length mismatch".into()));
        }
        match self {
            Aggregation::FedAvg => {
                let w = vec![1.0f32; updates.len()];
                Ok(reduce(updates, &w, pool))
            }
            Aggregation::WeightedFedAvg | Aggregation::FedProx => {
                let w: Vec<f32> =
                    updates.iter().map(|u| u.n_samples.max(0.0)).collect();
                if w.iter().sum::<f32>() <= 0.0 {
                    return Err(FedError::Fact("all sample weights zero".into()));
                }
                Ok(reduce(updates, &w, pool))
            }
            Aggregation::Median => Ok(coordinate_median(updates)),
            Aggregation::TrimmedMean { trim } => {
                if 2 * trim >= updates.len() {
                    return Err(FedError::Fact(format!(
                        "trim {trim} too large for {} clients",
                        updates.len()
                    )));
                }
                Ok(trimmed_mean(updates, *trim))
            }
        }
    }
}

fn reduce(
    updates: &[ClientUpdate],
    weights: &[f32],
    pool: Option<&ThreadPool>,
) -> Vec<f32> {
    // zero-copy views straight into the received buffers
    let vectors: Vec<&[f32]> =
        updates.iter().map(|u| u.params.as_f32_slice()).collect();
    match pool {
        // P-chunked parallel reduction; bit-identical to the flat loop
        Some(pool) => parallel_reduce_weighted(&vectors, weights, pool.worker_count()),
        None => flat_reduce_weighted(&vectors, weights),
    }
}

fn coordinate_median(updates: &[ClientUpdate]) -> Vec<f32> {
    let views: Vec<&[f32]> =
        updates.iter().map(|u| u.params.as_f32_slice()).collect();
    let p = views[0].len();
    let k = views.len();
    let mut out = vec![0.0f32; p];
    let mut col = vec![0.0f32; k];
    for j in 0..p {
        for (i, v) in views.iter().enumerate() {
            col[i] = v[j];
        }
        col.sort_by(f32::total_cmp);
        out[j] = if k % 2 == 1 {
            col[k / 2]
        } else {
            0.5 * (col[k / 2 - 1] + col[k / 2])
        };
    }
    out
}

fn trimmed_mean(updates: &[ClientUpdate], trim: usize) -> Vec<f32> {
    let views: Vec<&[f32]> =
        updates.iter().map(|u| u.params.as_f32_slice()).collect();
    let p = views[0].len();
    let k = views.len();
    let keep = k - 2 * trim;
    let mut out = vec![0.0f32; p];
    let mut col = vec![0.0f32; k];
    for j in 0..p {
        for (i, v) in views.iter().enumerate() {
            col[i] = v[j];
        }
        col.sort_by(f32::total_cmp);
        out[j] = col[trim..k - trim].iter().sum::<f32>() / keep as f32;
    }
    out
}

/// FedNova server-side correction (Wang et al. 2020).
///
/// Under the FedNova local strategy each client reports
/// `global + delta_i / tau_i` (its accumulated delta normalized by its
/// effective local step count) plus `tau_i` in the clear.  The merged
/// `target` therefore holds `global + normalized-mean-delta`; the true
/// FedNova update re-scales that mean by the weighted effective step
/// count `tau_eff = sum(w_i * tau_i) / sum(w_i)`:
///
/// ```text
/// target <- global + tau_eff * (target - global)
/// ```
///
/// With homogeneous `tau` this is exactly plain (weighted) FedAvg.  A
/// client that did not report `tau` (0) counts as `fallback_tau` — the
/// configured `local_steps` — so a mixed cohort stays well-defined.
/// `tau` rides outside the masked vector, so the rescale composes with
/// secure aggregation (it only ever touches the recovered sum).
pub fn fednova_rescale(
    target: &mut [f32],
    global: &[f32],
    updates: &[ClientUpdate],
    fallback_tau: f32,
) {
    if updates.is_empty() || target.len() != global.len() {
        return;
    }
    let fallback = if fallback_tau > 0.0 { fallback_tau } else { 1.0 };
    let mut wsum = 0.0f64;
    let mut wtau = 0.0f64;
    for u in updates {
        let w = f64::from(u.n_samples.max(0.0));
        let tau = if u.tau > 0.0 { u.tau } else { fallback };
        wsum += w;
        wtau += w * f64::from(tau);
    }
    let tau_eff = if wsum > 0.0 {
        (wtau / wsum) as f32
    } else {
        // all-zero weights: unweighted mean tau
        updates
            .iter()
            .map(|u| if u.tau > 0.0 { u.tau } else { fallback })
            .sum::<f32>()
            / updates.len() as f32
    };
    if !tau_eff.is_finite() || tau_eff <= 0.0 {
        return;
    }
    for (t, g) in target.iter_mut().zip(global) {
        *t = g + tau_eff * (*t - g);
    }
}

/// HLO-fused weighted FedAvg on the L1 Pallas kernel.
///
/// The compiled entries have fixed `(K, P)`; updates are padded with
/// zero-weight rows up to K and zero-padded up to P (zero weights are
/// ignored by the kernel — verified in `python/tests/test_kernels.py`).
pub fn hlo_fedavg(
    engine: &Engine,
    entry: &str,
    updates: &[ClientUpdate],
    weights: &[f32],
) -> Result<Vec<f32>> {
    let (k, p) = *engine
        .manifest()
        .aggregators
        .get(entry)
        .ok_or_else(|| FedError::Fact(format!("unknown aggregator entry '{entry}'")))?;
    if updates.len() > k {
        return Err(FedError::Fact(format!(
            "{} updates exceed compiled K={k}",
            updates.len()
        )));
    }
    let real_p = updates[0].params.len();
    if real_p > p {
        return Err(FedError::Fact(format!(
            "param count {real_p} exceeds compiled P={p}"
        )));
    }
    let mut stacked = vec![0.0f32; k * p];
    let mut w = vec![0.0f32; k];
    for (i, u) in updates.iter().enumerate() {
        stacked[i * p..i * p + real_p].copy_from_slice(u.params.as_f32_slice());
        w[i] = weights[i];
    }
    let out = engine.execute(
        entry,
        vec![
            Tensor::with_shape_f32(vec![k, p], stacked)?,
            Tensor::with_shape_f32(vec![k], w)?,
        ],
    )?;
    let mut full = out.into_iter().next().unwrap().into_f32s()?;
    full.truncate(real_p);
    Ok(full)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(device: &str, params: Vec<f32>, n: f32) -> ClientUpdate {
        ClientUpdate {
            device: device.into(),
            params: TensorBuf::from_f32_vec(params),
            n_samples: n,
            loss: 0.0,
            duration: 0.0,
            tau: 0.0,
        }
    }

    fn upd_tau(device: &str, params: Vec<f32>, n: f32, tau: f32) -> ClientUpdate {
        ClientUpdate { tau, ..upd(device, params, n) }
    }

    #[test]
    fn fednova_homogeneous_tau_is_plain_fedavg() {
        let global = vec![1.0f32, -1.0];
        // both clients normalized by the SAME tau=4: the rescale must
        // undo the normalization exactly
        let ups = vec![
            upd_tau("a", vec![1.0 + 2.0 / 4.0, -1.0], 1.0, 4.0),
            upd_tau("b", vec![1.0 + 6.0 / 4.0, -1.0], 1.0, 4.0),
        ];
        let mut t = Aggregation::FedAvg.aggregate(&ups, None).unwrap();
        fednova_rescale(&mut t, &global, &ups, 4.0);
        // raw deltas 2 and 6, mean 4 -> 1 + 4 = 5
        assert!((t[0] - 5.0).abs() < 1e-5, "got {}", t[0]);
        assert!((t[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn fednova_weights_tau_by_samples() {
        let global = vec![0.0f32];
        let ups = vec![
            upd_tau("a", vec![1.0], 3.0, 2.0),
            upd_tau("b", vec![1.0], 1.0, 6.0),
        ];
        // normalized deltas both 1.0 -> weighted target 1.0;
        // tau_eff = (3*2 + 1*6) / 4 = 3
        let mut t = Aggregation::WeightedFedAvg.aggregate(&ups, None).unwrap();
        fednova_rescale(&mut t, &global, &ups, 1.0);
        assert!((t[0] - 3.0).abs() < 1e-5, "got {}", t[0]);
    }

    #[test]
    fn fednova_unreported_tau_uses_fallback() {
        let global = vec![0.0f32];
        let ups = vec![upd("a", vec![1.0], 1.0)]; // tau 0 -> fallback 5
        let mut t = vec![1.0f32];
        fednova_rescale(&mut t, &global, &ups, 5.0);
        assert!((t[0] - 5.0).abs() < 1e-6);
        // degenerate inputs leave the target untouched
        let mut t2 = vec![1.0f32];
        fednova_rescale(&mut t2, &global, &[], 5.0);
        assert_eq!(t2, vec![1.0]);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Aggregation::parse("fedavg").unwrap(), Aggregation::FedAvg);
        assert_eq!(
            Aggregation::parse("weighted_fedavg").unwrap(),
            Aggregation::WeightedFedAvg
        );
        assert_eq!(Aggregation::parse("fedprox").unwrap(), Aggregation::FedProx);
        assert_eq!(Aggregation::parse("median").unwrap(), Aggregation::Median);
        assert_eq!(
            Aggregation::parse("trimmed_mean:2").unwrap(),
            Aggregation::TrimmedMean { trim: 2 }
        );
        assert!(Aggregation::parse("maxpool").is_err());
    }

    #[test]
    fn secure_sum_compatibility() {
        assert!(Aggregation::FedAvg.supports_secure_sum());
        assert!(Aggregation::WeightedFedAvg.supports_secure_sum());
        assert!(Aggregation::FedProx.supports_secure_sum());
        assert!(!Aggregation::Median.supports_secure_sum());
        assert!(!Aggregation::TrimmedMean { trim: 1 }.supports_secure_sum());
        assert!(!Aggregation::FedAvg.is_weighted());
        assert!(Aggregation::WeightedFedAvg.is_weighted());
    }

    #[test]
    fn fedavg_uniform() {
        let ups = vec![upd("a", vec![0.0, 2.0], 1.0), upd("b", vec![2.0, 4.0], 99.0)];
        let out = Aggregation::FedAvg.aggregate(&ups, None).unwrap();
        assert_eq!(out, vec![1.0, 3.0]); // ignores n_samples
    }

    #[test]
    fn weighted_fedavg_by_samples() {
        let ups = vec![upd("a", vec![0.0], 1.0), upd("b", vec![4.0], 3.0)];
        let out = Aggregation::WeightedFedAvg.aggregate(&ups, None).unwrap();
        assert!((out[0] - 3.0).abs() < 1e-6);
        // FedProx server-side is identical
        let out2 = Aggregation::FedProx.aggregate(&ups, None).unwrap();
        assert_eq!(out, out2);
    }

    #[test]
    fn median_resists_poisoned_client() {
        let mut ups: Vec<ClientUpdate> =
            (0..9).map(|i| upd(&format!("c{i}"), vec![1.0, -1.0], 1.0)).collect();
        ups.push(upd("evil", vec![1e9, -1e9], 1.0));
        let med = Aggregation::Median.aggregate(&ups, None).unwrap();
        assert!((med[0] - 1.0).abs() < 1e-6);
        let avg = Aggregation::FedAvg.aggregate(&ups, None).unwrap();
        assert!(avg[0] > 1e7, "mean should be poisoned");
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let ups = vec![
            upd("lo", vec![-100.0], 1.0),
            upd("a", vec![1.0], 1.0),
            upd("b", vec![2.0], 1.0),
            upd("c", vec![3.0], 1.0),
            upd("hi", vec![100.0], 1.0),
        ];
        let out = Aggregation::TrimmedMean { trim: 1 }.aggregate(&ups, None).unwrap();
        assert!((out[0] - 2.0).abs() < 1e-6);
        assert!(Aggregation::TrimmedMean { trim: 3 }.aggregate(&ups, None).is_err());
    }

    #[test]
    fn median_even_count() {
        let ups = vec![
            upd("a", vec![1.0], 1.0),
            upd("b", vec![3.0], 1.0),
            upd("c", vec![5.0], 1.0),
            upd("d", vec![7.0], 1.0),
        ];
        let out = Aggregation::Median.aggregate(&ups, None).unwrap();
        assert!((out[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn errors_on_degenerate_input() {
        assert!(Aggregation::FedAvg.aggregate(&[], None).is_err());
        let mismatched = vec![upd("a", vec![1.0], 1.0), upd("b", vec![1.0, 2.0], 1.0)];
        assert!(Aggregation::FedAvg.aggregate(&mismatched, None).is_err());
        let zero_w = vec![upd("a", vec![1.0], 0.0)];
        assert!(Aggregation::WeightedFedAvg.aggregate(&zero_w, None).is_err());
    }

    #[test]
    fn pooled_reduction_matches_flat() {
        let pool = ThreadPool::new(4);
        let ups: Vec<ClientUpdate> = (0..24)
            .map(|i| {
                upd(
                    &format!("c{i}"),
                    (0..100).map(|j| ((i * j) % 7) as f32).collect(),
                    (i + 1) as f32,
                )
            })
            .collect();
        let flat = Aggregation::WeightedFedAvg.aggregate(&ups, None).unwrap();
        let tree = Aggregation::WeightedFedAvg.aggregate(&ups, Some(&pool)).unwrap();
        for (a, b) in flat.iter().zip(tree.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn hlo_fedavg_matches_rust_if_artifacts_built() {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let engine = Engine::load(&dir, 1).unwrap();
        let p_real = 1000;
        let ups: Vec<ClientUpdate> = (0..5)
            .map(|i| {
                upd(
                    &format!("c{i}"),
                    crate::util::rng::golden_f32(i as u32 + 1, p_real),
                    (i + 1) as f32,
                )
            })
            .collect();
        let weights: Vec<f32> = ups.iter().map(|u| u.n_samples).collect();
        let hlo = hlo_fedavg(&engine, "fedavg_k8_p1048576", &ups, &weights).unwrap();
        let rust = Aggregation::WeightedFedAvg.aggregate(&ups, None).unwrap();
        assert_eq!(hlo.len(), p_real);
        for (a, b) in hlo.iter().zip(rust.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        engine.shutdown();
    }
}
