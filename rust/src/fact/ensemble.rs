//! Ensemble FL by stacking (paper §B.3 ScikitEnsembleFLModel).
//!
//! "We introduced a new method named ensemble FL to use further model types
//! for FL which makes use of the stacking technique. It allows to use
//! arbitrary ML models like decision trees, random forests, support vector
//! machine etc. in a federated setup. ... Implemented aggregation
//! algorithm: it inherits the aggregation algorithms [of the NN model] via
//! applying the aggregation only to the final model."
//!
//! Mechanics here: each client first fits a **local base learner** (a
//! class-prototype / nearest-centroid model — a non-gradient model family
//! standing in for trees/SVMs) on its own data; the base never leaves the
//! client.  The *federated* part is the stacking head, a softmax regression
//! over the base learner's per-class scores, trained with the standard
//! FedAvg loop — only the head's parameters are aggregated.

use std::sync::Arc;

use crate::error::{FedError, Result};
use crate::fact::aggregation::Aggregation;
use crate::fact::client::FactClientRuntime;
use crate::fact::data::ClientData;
use crate::fact::model::{FactModel, LinearModel};
use crate::json::Json;
use crate::util::tensorbuf::TensorBuf;
use crate::dart::TaskRegistry;

/// Server-side handle: a linear stacking head over `classes` base scores.
pub struct EnsembleFlModel {
    name: String,
    head: LinearModel,
    pub classes: usize,
}

impl EnsembleFlModel {
    pub fn new(classes: usize, aggregation: Aggregation) -> EnsembleFlModel {
        EnsembleFlModel {
            name: format!("ensemble_{classes}"),
            // head input = the base learner's per-class score vector
            head: LinearModel::new(classes, classes, aggregation),
            classes,
        }
    }

    pub fn arc(classes: usize, agg: Aggregation) -> Arc<dyn FactModel> {
        Arc::new(Self::new(classes, agg))
    }
}

impl FactModel for EnsembleFlModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn param_count(&self) -> usize {
        self.head.param_count()
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        self.head.init_params(seed)
    }

    fn aggregation(&self) -> &Aggregation {
        self.head.aggregation()
    }

    fn init_task_params(&self) -> Json {
        Json::obj()
            .set("model", self.name())
            .set("classes", self.classes)
    }
}

/// Nearest-centroid base learner: per-class feature centroids; score of a
/// sample for class c = -||x - centroid_c||^2.  Trained in one pass, no
/// gradients — the "arbitrary ML model" role.
pub struct CentroidBase {
    pub centroids: Vec<f32>,
    pub dim: usize,
    pub classes: usize,
}

impl CentroidBase {
    pub fn fit(data: &ClientData, classes: usize) -> CentroidBase {
        let dim = data.dim;
        let mut sums = vec![0.0f32; classes * dim];
        let mut counts = vec![0.0f32; classes];
        for i in 0..data.n() {
            let c = data.y[i] as usize;
            counts[c] += 1.0;
            for j in 0..dim {
                sums[c * dim + j] += data.x[i * dim + j];
            }
        }
        for c in 0..classes {
            let denom = counts[c].max(1.0);
            for j in 0..dim {
                sums[c * dim + j] /= denom;
            }
        }
        CentroidBase { centroids: sums, dim, classes }
    }

    pub fn from_flat(flat: &[f32], dim: usize, classes: usize) -> CentroidBase {
        CentroidBase { centroids: flat.to_vec(), dim, classes }
    }

    /// Per-class scores for one sample: negative squared distances,
    /// standardized per sample so the stacking head sees well-conditioned
    /// features (raw -||x-c||^2 has magnitude ~dim and a large shared
    /// offset, which cripples a softmax-regression head).
    pub fn scores(&self, x: &[f32]) -> Vec<f32> {
        let raw: Vec<f32> = (0..self.classes)
            .map(|c| {
                let mut d = 0.0f32;
                for j in 0..self.dim {
                    let diff = x[j] - self.centroids[c * self.dim + j];
                    d += diff * diff;
                }
                -d
            })
            .collect();
        let mean = raw.iter().sum::<f32>() / raw.len() as f32;
        let var = raw.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
            / raw.len() as f32;
        let sd = var.sqrt().max(1e-6);
        raw.iter().map(|v| (v - mean) / sd).collect()
    }

    /// Transform a dataset into head-space (scores as features).
    pub fn transform(&self, data: &ClientData) -> ClientData {
        let mut x = Vec::with_capacity(data.n() * self.classes);
        for i in 0..data.n() {
            let s = self.scores(&data.x[i * data.dim..(i + 1) * data.dim]);
            x.extend(s);
        }
        ClientData { x, y: data.y.clone(), dim: self.classes, group: data.group }
    }
}

/// Register the ensemble `@feddart` functions (`ensemble_learn`,
/// `ensemble_evaluate`) on a registry backed by the shared client runtime.
/// The base learner is fitted once per device on first use and cached.
pub fn register_ensemble_tasks(rt: &Arc<FactClientRuntime>, registry: &TaskRegistry) {
    let rt_learn = Arc::clone(rt);
    registry.register("ensemble_learn", move |p| ensemble_learn(&rt_learn, p));
    let rt_eval = Arc::clone(rt);
    registry.register("ensemble_evaluate", move |p| ensemble_evaluate(&rt_eval, p));
}

fn device_data(
    rt: &FactClientRuntime,
    p: &Json,
) -> Result<(String, ClientData, ClientData, usize)> {
    let device = p
        .get("_device")
        .and_then(Json::as_str)
        .ok_or_else(|| FedError::Fact("missing _device".into()))?
        .to_string();
    let classes = p.need("classes")?.as_usize().unwrap_or(0);
    let (train, test) = rt.supervised_of(&device)?;
    Ok((device, train, test, classes))
}

/// Fit-or-fetch the cached base learner for a device.
fn base_for(
    rt: &FactClientRuntime,
    device: &str,
    model: &str,
    train: &ClientData,
    classes: usize,
) -> CentroidBase {
    match rt.cached_base_params(device, model) {
        Some(flat) => CentroidBase::from_flat(&flat, train.dim, classes),
        None => {
            let base = CentroidBase::fit(train, classes);
            rt.cache_base_params(device, model, base.centroids.clone());
            base
        }
    }
}

fn ensemble_learn(rt: &FactClientRuntime, p: &Json) -> Result<Json> {
    let (device, train, _test, classes) = device_data(rt, p)?;
    let model = p.need("model")?.as_str().unwrap_or("").to_string();
    let mut head = TensorBuf::from_json(p.need("params")?)
        .map_err(|e| FedError::Fact(format!("bad ensemble params: {e}")))?
        .to_vec();
    let global = head.clone();
    let lr = p.get("lr").and_then(Json::as_f64).unwrap_or(0.1) as f32;
    let mu = p.get("mu").and_then(Json::as_f64).unwrap_or(0.0) as f32;
    let steps = p.get("local_steps").and_then(Json::as_usize).unwrap_or(1).max(1);
    let round = p.get("round").and_then(Json::as_i64).unwrap_or(0) as u64;

    let base = base_for(rt, &device, &model, &train, classes);
    let head_space = base.transform(&train);
    let b = 32.min(head_space.n()).max(1);
    let mut loss_acc = 0.0f32;
    for s in 0..steps {
        let seed = crate::util::rng::splitmix64(
            (round << 16) ^ s as u64 ^ device.len() as u64,
        );
        let (x, y) = head_space.sample_batch(seed, b);
        loss_acc += LinearModel::sgd_step(
            &mut head, &x, &y, classes, classes, lr, mu, &global,
        );
    }
    Ok(Json::obj()
        .set("params", TensorBuf::from_f32_vec(head))
        .set("n_samples", train.n())
        .set("loss", loss_acc / steps as f32))
}

fn ensemble_evaluate(rt: &FactClientRuntime, p: &Json) -> Result<Json> {
    let (device, train, test, classes) = device_data(rt, p)?;
    let model = p.need("model")?.as_str().unwrap_or("").to_string();
    let head = TensorBuf::from_json(p.need("params")?)
        .map_err(|e| FedError::Fact(format!("bad ensemble params: {e}")))?;
    let base = base_for(rt, &device, &model, &train, classes);
    let head_space = base.transform(&test);
    let (loss_sum, correct) = LinearModel::evaluate(
        head.as_f32_slice(),
        &head_space.x,
        &head_space.y,
        classes,
        classes,
    );
    Ok(Json::obj()
        .set("loss_sum", loss_sum)
        .set("correct", correct)
        .set("n", test.n()))
}

/// Baseline for E8: base learner alone (no federated head) — accuracy on
/// the local test set using argmax of base scores.
pub fn local_only_accuracy(train: &ClientData, test: &ClientData, classes: usize) -> f64 {
    let base = CentroidBase::fit(train, classes);
    let mut correct = 0usize;
    for i in 0..test.n() {
        let s = base.scores(&test.x[i * test.dim..(i + 1) * test.dim]);
        let pred = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0 as i32;
        if pred == test.y[i] {
            correct += 1;
        }
    }
    correct as f64 / test.n().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fact::data::{synthesize, SyntheticConfig};

    fn data() -> ClientData {
        synthesize(&SyntheticConfig {
            clients: 1,
            samples_per_client: 300,
            dim: 6,
            classes: 3,
            ..Default::default()
        })
        .unwrap()
        .remove("client-0")
        .unwrap()
    }

    #[test]
    fn centroid_base_learns_something() {
        let d = data();
        let (train, test) = d.train_test_split(0.3);
        let acc = local_only_accuracy(&train, &test, 3);
        assert!(acc > 1.0 / 3.0 + 0.05, "base accuracy {acc} not above chance");
    }

    #[test]
    fn transform_shapes() {
        let d = data();
        let base = CentroidBase::fit(&d, 3);
        let t = base.transform(&d);
        assert_eq!(t.dim, 3);
        assert_eq!(t.n(), d.n());
        assert_eq!(t.y, d.y);
    }

    #[test]
    fn ensemble_model_trait_surface() {
        let m = EnsembleFlModel::new(4, Aggregation::WeightedFedAvg);
        assert_eq!(m.param_count(), 4 * 4 + 4);
        assert_eq!(m.init_params(1).unwrap().len(), 20);
        let j = m.init_task_params();
        assert_eq!(j.get("classes").unwrap().as_usize(), Some(4));
        assert!(m.name().starts_with("ensemble"));
    }

    #[test]
    fn scores_prefer_own_centroid() {
        let d = ClientData {
            x: vec![0.0, 0.0, 10.0, 10.0],
            y: vec![0, 1],
            dim: 2,
            group: 0,
        };
        let base = CentroidBase::fit(&d, 2);
        let s0 = base.scores(&[0.1, -0.1]);
        assert!(s0[0] > s0[1]);
        let s1 = base.scores(&[9.5, 10.2]);
        assert!(s1[1] > s1[0]);
    }
}
