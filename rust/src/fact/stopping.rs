//! Stopping criteria (paper §B.4).
//!
//! Two abstract families: one for the FL rounds within a cluster, one for
//! the outer clustering loop.  The paper ships fixed-round subclasses of
//! each; we add a loss-plateau FL criterion as the documented extension
//! path ("to create new stopping criteria, one only has to implement a
//! subclass ... further information, such as how much the weights ...
//! have changed, [is passed] via keyword arguments" — here, the loss
//! history slice).

/// AbstractFLStoppingCriterion: decides after each training round of one
/// cluster.  `losses` is the cluster's mean-client-loss history including
/// the round just finished.
pub trait FlStoppingCriterion: Send + Sync {
    fn should_stop(&self, rounds_done: usize, losses: &[f32]) -> bool;
    fn name(&self) -> &'static str;
}

/// AbstractClusteringStoppingCriterion: decides after each clustering round.
pub trait ClusteringStoppingCriterion: Send + Sync {
    fn should_stop(&self, clustering_rounds_done: usize) -> bool;
    fn name(&self) -> &'static str;
}

/// The paper's FixedRoundFLStoppingCriterion.
pub struct FixedRoundFl(pub usize);

impl FlStoppingCriterion for FixedRoundFl {
    fn should_stop(&self, rounds_done: usize, _losses: &[f32]) -> bool {
        rounds_done >= self.0
    }
    fn name(&self) -> &'static str {
        "fixed_round"
    }
}

/// Stop when the loss has not improved by `min_delta` for `patience`
/// consecutive rounds (the extension example).
pub struct LossPlateauFl {
    pub patience: usize,
    pub min_delta: f32,
    /// hard cap regardless of plateau
    pub max_rounds: usize,
}

impl FlStoppingCriterion for LossPlateauFl {
    fn should_stop(&self, rounds_done: usize, losses: &[f32]) -> bool {
        if rounds_done >= self.max_rounds {
            return true;
        }
        if losses.len() <= self.patience {
            return false;
        }
        let recent = &losses[losses.len() - self.patience..];
        let best_before = losses[..losses.len() - self.patience]
            .iter()
            .fold(f32::INFINITY, |a, &b| a.min(b));
        recent.iter().all(|&l| l > best_before - self.min_delta)
    }
    fn name(&self) -> &'static str {
        "loss_plateau"
    }
}

/// The paper's fixed-iteration clustering criterion; `1` (the default from
/// `initialization_by_model`, Alg 3) makes the setup "equivalent to
/// standard FL".
pub struct FixedClusteringRounds(pub usize);

impl ClusteringStoppingCriterion for FixedClusteringRounds {
    fn should_stop(&self, clustering_rounds_done: usize) -> bool {
        clustering_rounds_done >= self.0
    }
    fn name(&self) -> &'static str {
        "fixed_clustering_rounds"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_round_counts() {
        let c = FixedRoundFl(3);
        assert!(!c.should_stop(0, &[]));
        assert!(!c.should_stop(2, &[1.0, 0.9]));
        assert!(c.should_stop(3, &[1.0, 0.9, 0.8]));
        assert!(c.should_stop(4, &[]));
    }

    #[test]
    fn plateau_stops_on_stagnation() {
        let c = LossPlateauFl { patience: 3, min_delta: 0.01, max_rounds: 100 };
        // improving: never stops
        let improving: Vec<f32> = (0..10).map(|i| 1.0 - 0.1 * i as f32).collect();
        assert!(!c.should_stop(10, &improving));
        // stagnant after round 4
        let mut stagnant = vec![1.0, 0.8, 0.6, 0.5];
        stagnant.extend([0.5001, 0.4999, 0.5002]);
        assert!(c.should_stop(7, &stagnant));
        // not enough history
        assert!(!c.should_stop(2, &[1.0, 1.0]));
    }

    #[test]
    fn plateau_hard_cap() {
        let c = LossPlateauFl { patience: 3, min_delta: 0.01, max_rounds: 5 };
        let improving: Vec<f32> = (0..6).map(|i| 1.0 - 0.1 * i as f32).collect();
        assert!(c.should_stop(5, &improving));
    }

    #[test]
    fn clustering_rounds() {
        let c = FixedClusteringRounds(1);
        assert!(!c.should_stop(0));
        assert!(c.should_stop(1));
    }
}
