//! FACT — the Federated Aggregation and Clustering Toolkit (paper §2.2).
//!
//! * [`server::FactServer`] — the user entry point (Alg 3-5).
//! * [`model`] — the AbstractModel layer: [`model::HloModel`] (MLP ≙
//!   KerasModel/ScikitNNModel, transformer LM), [`model::LinearModel`]
//!   (native), [`ensemble::EnsembleFlModel`] (stacking ensemble FL).
//! * [`aggregation`] — FedAvg / weighted / FedProx / median / trimmed mean
//!   + the HLO-fused kernel variant.
//! * [`clustering`] — ClusterContainer / Cluster, static / k-means /
//!   cosine-threshold algorithms (personalized FL).
//! * [`stopping`] — FL and clustering stopping criteria.
//! * [`client`] — the client-side runtime registering the `@feddart`
//!   functions (init / learn / evaluate).
//! * [`data`] — federated data synthesis (IID / label-skew / latent
//!   groups) and the DataImporter abstraction.

pub mod aggregation;
pub mod client;
pub mod clustering;
pub mod data;
pub mod ensemble;
pub mod model;
pub mod rounds;
pub mod server;
pub mod stopping;
pub mod store;

pub use aggregation::{Aggregation, ClientUpdate};
pub use client::FactClientRuntime;
pub use clustering::{Cluster, ClusterContainer, ClusteringAlgorithm};
pub use model::{FactModel, HloModel, Hyper, LinearModel};
pub use server::{EvalRecord, FactServer, RoundRecord};
