//! Federated data synthesis + the data-importer abstraction (paper §C.2.1).
//!
//! Real deployments bring their own per-client data; this testbed
//! synthesizes it with controllable heterogeneity — the knob every FL
//! experiment in DESIGN.md turns:
//!
//! * **IID** — samples drawn from one global task, split uniformly (E1).
//! * **Label skew** — Dirichlet(α) class proportions per client (E5; small
//!   α = strongly non-IID, the FedProx regime).
//! * **Latent groups** — clients belong to hidden groups with *different*
//!   conditional distributions (label permutations of a shared task); the
//!   personalized-FL / clustering workload (E4).
//!
//! Classification features come from a random two-layer teacher network so
//! the task is learnable but not linearly trivial.  Token streams for the
//! LM workload come from per-client Markov chains over a shared transition
//! core with group-specific perturbations.

use std::collections::BTreeMap;

use crate::error::{FedError, Result};
use crate::util::rng::Rng;

/// One client's supervised dataset.
#[derive(Debug, Clone, Default)]
pub struct ClientData {
    /// row-major `[n, dim]`
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub dim: usize,
    /// latent group the client belongs to (ground truth for E4 scoring)
    pub group: usize,
}

impl ClientData {
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Deterministically sample a batch of `b` rows (with replacement).
    pub fn sample_batch(&self, seed: u64, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let mut xb = Vec::with_capacity(b * self.dim);
        let mut yb = Vec::with_capacity(b);
        for _ in 0..b {
            let i = rng.below(self.n());
            xb.extend_from_slice(&self.x[i * self.dim..(i + 1) * self.dim]);
            yb.push(self.y[i]);
        }
        (xb, yb)
    }

    /// Split off the last `frac` fraction as a held-out set.
    pub fn train_test_split(&self, frac: f64) -> (ClientData, ClientData) {
        let n_test = ((self.n() as f64) * frac).round() as usize;
        let n_train = self.n() - n_test;
        let cut = n_train * self.dim;
        (
            ClientData {
                x: self.x[..cut].to_vec(),
                y: self.y[..n_train].to_vec(),
                dim: self.dim,
                group: self.group,
            },
            ClientData {
                x: self.x[cut..].to_vec(),
                y: self.y[n_train..].to_vec(),
                dim: self.dim,
                group: self.group,
            },
        )
    }
}

/// How samples/labels are distributed across clients.
#[derive(Debug, Clone)]
pub enum Partition {
    /// one global distribution, uniform split
    Iid,
    /// Dirichlet(α) label proportions per client
    LabelSkew { alpha: f64 },
    /// `groups` latent groups; within a group labels are permuted by a
    /// group-specific permutation of the shared teacher's classes
    LatentGroups { groups: usize },
}

/// Configuration for the synthetic classification workload.
#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    pub clients: usize,
    pub samples_per_client: usize,
    pub dim: usize,
    pub classes: usize,
    pub partition: Partition,
    pub seed: u64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            clients: 8,
            samples_per_client: 512,
            dim: 32,
            classes: 10,
            partition: Partition::Iid,
            seed: 42,
        }
    }
}

/// A random two-layer teacher: logits = relu(x W1) W2.
struct Teacher {
    w1: Vec<f32>,
    w2: Vec<f32>,
    dim: usize,
    hidden: usize,
    classes: usize,
}

impl Teacher {
    fn new(rng: &mut Rng, dim: usize, classes: usize) -> Teacher {
        let hidden = 2 * dim;
        Teacher {
            w1: rng.normal_vec(dim * hidden),
            w2: rng.normal_vec(hidden * classes),
            dim,
            hidden,
            classes,
        }
    }

    fn label(&self, x: &[f32]) -> usize {
        let mut h = vec![0.0f32; self.hidden];
        for (j, hj) in h.iter_mut().enumerate() {
            let mut s = 0.0;
            for i in 0..self.dim {
                s += x[i] * self.w1[i * self.hidden + j];
            }
            *hj = s.max(0.0);
        }
        let mut best = 0;
        let mut best_v = f32::NEG_INFINITY;
        for c in 0..self.classes {
            let mut s = 0.0;
            for (j, &hj) in h.iter().enumerate() {
                s += hj * self.w2[j * self.classes + c];
            }
            if s > best_v {
                best_v = s;
                best = c;
            }
        }
        best
    }
}

/// Generate per-client datasets according to the partition scheme.
/// Returned map is keyed by client name `client-0..`.
pub fn synthesize(cfg: &SyntheticConfig) -> Result<BTreeMap<String, ClientData>> {
    if cfg.classes < 2 || cfg.clients == 0 {
        return Err(FedError::Fact("need >=2 classes and >=1 client".into()));
    }
    let mut rng = Rng::new(cfg.seed);
    let teacher = Teacher::new(&mut rng, cfg.dim, cfg.classes);

    // group-specific label permutations for LatentGroups
    let (ngroups, perms): (usize, Vec<Vec<usize>>) = match cfg.partition {
        Partition::LatentGroups { groups } => {
            let perms = (0..groups)
                .map(|g| {
                    let mut p: Vec<usize> = (0..cfg.classes).collect();
                    if g > 0 {
                        let mut r = Rng::new(cfg.seed ^ (g as u64) << 17);
                        r.shuffle(&mut p);
                    }
                    p
                })
                .collect();
            (groups, perms)
        }
        _ => (1, vec![(0..cfg.classes).collect()]),
    };

    let mut out = BTreeMap::new();
    for c in 0..cfg.clients {
        let group = c % ngroups;
        let mut crng = Rng::new(cfg.seed ^ 0x9E3779B9 ^ (c as u64) << 20);
        let mut x = Vec::with_capacity(cfg.samples_per_client * cfg.dim);
        let mut y = Vec::with_capacity(cfg.samples_per_client);

        // per-client class acceptance probabilities for label skew
        let probs: Option<Vec<f64>> = match cfg.partition {
            Partition::LabelSkew { alpha } => Some(crng.dirichlet(alpha, cfg.classes)),
            _ => None,
        };

        while y.len() < cfg.samples_per_client {
            let xi = crng.normal_vec(cfg.dim);
            let base = teacher.label(&xi);
            if let Some(p) = &probs {
                // rejection-sample towards the client's class profile
                if !crng.chance(p[base] * cfg.classes as f64) {
                    continue;
                }
            }
            let label = perms[group][base];
            x.extend_from_slice(&xi);
            y.push(label as i32);
        }
        out.insert(
            format!("client-{c}"),
            ClientData { x, y, dim: cfg.dim, group },
        );
    }
    Ok(out)
}

/// Empirical label distribution of a dataset (tests / diagnostics).
pub fn label_histogram(d: &ClientData, classes: usize) -> Vec<f64> {
    let mut h = vec![0.0; classes];
    for &y in &d.y {
        h[y as usize] += 1.0;
    }
    let n = d.n() as f64;
    h.iter_mut().for_each(|v| *v /= n);
    h
}

// ---------------------------------------------------------------------------
// Token streams for the federated LM workload (E2E driver)
// ---------------------------------------------------------------------------

/// Configuration for the synthetic corpus.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub clients: usize,
    pub tokens_per_client: usize,
    pub vocab: usize,
    /// latent dialect groups: each group perturbs the shared Markov core
    pub groups: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            clients: 8,
            tokens_per_client: 1 << 15,
            vocab: 256,
            groups: 1,
            seed: 7,
        }
    }
}

/// One client's token stream.
#[derive(Debug, Clone)]
pub struct ClientCorpus {
    pub tokens: Vec<i32>,
    pub vocab: usize,
    pub group: usize,
}

impl ClientCorpus {
    /// Deterministically sample a batch of `b` windows of length `s + 1`.
    pub fn sample_windows(&self, seed: u64, b: usize, s: usize) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(b * (s + 1));
        let max_start = self.tokens.len().saturating_sub(s + 1).max(1);
        for _ in 0..b {
            let start = rng.below(max_start);
            out.extend_from_slice(&self.tokens[start..start + s + 1]);
        }
        out
    }
}

/// Per-client Markov chains: a shared low-entropy core (so a global model
/// helps every client) plus group-specific transition noise.
pub fn synthesize_corpus(cfg: &CorpusConfig) -> BTreeMap<String, ClientCorpus> {
    let mut out = BTreeMap::new();
    // Shared sparse "grammar": each token has a few favoured successors.
    let mut core = Rng::new(cfg.seed);
    let succ: Vec<[usize; 4]> = (0..cfg.vocab)
        .map(|_| {
            [
                core.below(cfg.vocab),
                core.below(cfg.vocab),
                core.below(cfg.vocab),
                core.below(cfg.vocab),
            ]
        })
        .collect();
    for c in 0..cfg.clients {
        let group = c % cfg.groups.max(1);
        let mut rng = Rng::new(cfg.seed ^ 0xABCD ^ (c as u64) << 24);
        let mut grp = Rng::new(cfg.seed ^ 0x1234 ^ (group as u64) << 16);
        // group-specific successor override table
        let gsucc: Vec<usize> = (0..cfg.vocab).map(|_| grp.below(cfg.vocab)).collect();
        let mut tokens = Vec::with_capacity(cfg.tokens_per_client);
        let mut t = rng.below(cfg.vocab);
        for _ in 0..cfg.tokens_per_client {
            tokens.push(t as i32);
            t = if rng.chance(0.75) {
                succ[t][rng.below(4)] // shared structure
            } else if rng.chance(0.6) {
                gsucc[t] // dialect structure
            } else {
                rng.below(cfg.vocab) // noise
            };
        }
        out.insert(
            format!("client-{c}"),
            ClientCorpus { tokens, vocab: cfg.vocab, group },
        );
    }
    out
}

// ---------------------------------------------------------------------------
// The data-importer abstraction (paper §C.2.1)
// ---------------------------------------------------------------------------

/// "existing data loading and pre-processing code can be used almost as is
/// by creating a concrete subclass of the AbstractDataImporter" — load,
/// preprocess, split.
pub trait DataImporter: Send + Sync {
    fn load_data(&self) -> Result<ClientData>;
    fn preprocess_data(&self, data: ClientData) -> Result<ClientData> {
        Ok(data)
    }
    fn split_data_into_train_and_test(
        &self,
        data: ClientData,
    ) -> Result<(ClientData, ClientData)> {
        Ok(data.train_test_split(0.2))
    }

    /// The composed pipeline.
    fn import(&self) -> Result<(ClientData, ClientData)> {
        let raw = self.load_data()?;
        let pre = self.preprocess_data(raw)?;
        self.split_data_into_train_and_test(pre)
    }
}

/// Importer serving one client's slice of a synthetic federation.
pub struct SyntheticImporter {
    pub data: ClientData,
}

impl DataImporter for SyntheticImporter {
    fn load_data(&self) -> Result<ClientData> {
        Ok(self.data.clone())
    }

    fn preprocess_data(&self, mut data: ClientData) -> Result<ClientData> {
        // standardize features (the usual preprocessing step)
        let n = data.n().max(1);
        for j in 0..data.dim {
            let mut mean = 0.0f64;
            for i in 0..n {
                mean += data.x[i * data.dim + j] as f64;
            }
            mean /= n as f64;
            let mut var = 0.0f64;
            for i in 0..n {
                let d = data.x[i * data.dim + j] as f64 - mean;
                var += d * d;
            }
            let sd = (var / n as f64).sqrt().max(1e-6);
            for i in 0..n {
                let v = &mut data.x[i * data.dim + j];
                *v = ((*v as f64 - mean) / sd) as f32;
            }
        }
        Ok(data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_split_is_balanced_and_deterministic() {
        let cfg = SyntheticConfig { clients: 4, samples_per_client: 300, ..Default::default() };
        let a = synthesize(&cfg).unwrap();
        let b = synthesize(&cfg).unwrap();
        assert_eq!(a.len(), 4);
        for (k, d) in &a {
            assert_eq!(d.n(), 300);
            assert_eq!(d.x.len(), 300 * d.dim);
            assert_eq!(d.x, b[k].x, "not deterministic");
            assert!(d.y.iter().all(|&y| (0..10).contains(&y)));
        }
        // IID: label histograms of two clients are similar
        let h0 = label_histogram(&a["client-0"], 10);
        let h1 = label_histogram(&a["client-1"], 10);
        let tv: f64 = h0.iter().zip(&h1).map(|(a, b)| (a - b).abs()).sum::<f64>() / 2.0;
        assert!(tv < 0.25, "IID clients too different: tv={tv}");
    }

    #[test]
    fn label_skew_is_skewed() {
        let mk = |alpha| SyntheticConfig {
            clients: 6,
            samples_per_client: 400,
            partition: Partition::LabelSkew { alpha },
            ..Default::default()
        };
        let skewed = synthesize(&mk(0.1)).unwrap();
        let even = synthesize(&mk(100.0)).unwrap();
        let max_share = |d: &ClientData| {
            label_histogram(d, 10).into_iter().fold(0.0f64, f64::max)
        };
        let avg_skew: f64 =
            skewed.values().map(max_share).sum::<f64>() / skewed.len() as f64;
        let avg_even: f64 =
            even.values().map(max_share).sum::<f64>() / even.len() as f64;
        assert!(avg_skew > avg_even + 0.1, "skew {avg_skew} vs even {avg_even}");
    }

    #[test]
    fn latent_groups_disagree_on_labels() {
        let cfg = SyntheticConfig {
            clients: 6,
            samples_per_client: 200,
            partition: Partition::LatentGroups { groups: 3 },
            ..Default::default()
        };
        let data = synthesize(&cfg).unwrap();
        // group assignment is round-robin
        assert_eq!(data["client-0"].group, 0);
        assert_eq!(data["client-4"].group, 1);
        // same-group clients share the permutation: a sample with the same
        // features would get the same label; different groups use different
        // permutations, so their label histograms on the shared teacher
        // differ systematically.  Indirect check: histograms within group
        // closer than across groups (on average).
        let h: Vec<Vec<f64>> = (0..6)
            .map(|i| label_histogram(&data[&format!("client-{i}")], 10))
            .collect();
        let dist = |a: &Vec<f64>, b: &Vec<f64>| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
        };
        let within = (dist(&h[0], &h[3]) + dist(&h[1], &h[4]) + dist(&h[2], &h[5])) / 3.0;
        let across = (dist(&h[0], &h[1]) + dist(&h[1], &h[2]) + dist(&h[3], &h[4])) / 3.0;
        assert!(within < across, "within {within} across {across}");
    }

    #[test]
    fn batch_sampling_is_deterministic_and_shaped() {
        let cfg = SyntheticConfig::default();
        let data = synthesize(&cfg).unwrap();
        let d = &data["client-0"];
        let (x1, y1) = d.sample_batch(99, 32);
        let (x2, y2) = d.sample_batch(99, 32);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        assert_eq!(x1.len(), 32 * d.dim);
        let (x3, _) = d.sample_batch(100, 32);
        assert_ne!(x1, x3);
    }

    #[test]
    fn train_test_split_partitions() {
        let cfg = SyntheticConfig { samples_per_client: 100, ..Default::default() };
        let data = synthesize(&cfg).unwrap();
        let (tr, te) = data["client-0"].train_test_split(0.2);
        assert_eq!(tr.n(), 80);
        assert_eq!(te.n(), 20);
        assert_eq!(tr.x.len(), 80 * tr.dim);
    }

    #[test]
    fn importer_pipeline_standardizes() {
        let cfg = SyntheticConfig { samples_per_client: 200, ..Default::default() };
        let data = synthesize(&cfg).unwrap();
        let imp = SyntheticImporter { data: data["client-0"].clone() };
        let (tr, te) = imp.import().unwrap();
        assert!(tr.n() > te.n());
        // standardized: column 0 ~ mean 0, sd 1 over the combined data
        let col0: Vec<f32> = (0..tr.n()).map(|i| tr.x[i * tr.dim]).collect();
        let mean: f32 = col0.iter().sum::<f32>() / col0.len() as f32;
        assert!(mean.abs() < 0.3, "mean {mean}");
    }

    #[test]
    fn corpus_generation_properties() {
        let cfg = CorpusConfig {
            clients: 4,
            tokens_per_client: 5000,
            vocab: 64,
            groups: 2,
            ..Default::default()
        };
        let corp = synthesize_corpus(&cfg);
        assert_eq!(corp.len(), 4);
        for d in corp.values() {
            assert_eq!(d.tokens.len(), 5000);
            assert!(d.tokens.iter().all(|&t| (0..64).contains(&t)));
        }
        assert_eq!(corp["client-0"].group, 0);
        assert_eq!(corp["client-1"].group, 1);
        let w = corp["client-0"].sample_windows(5, 8, 16);
        assert_eq!(w.len(), 8 * 17);
        assert_eq!(w, corp["client-0"].sample_windows(5, 8, 16));
        // structure: the stream should be far from uniform-random —
        // bigram repetition rate must exceed the uniform baseline
        let toks = &corp["client-0"].tokens;
        let repeats = toks
            .windows(2)
            .filter(|w| {
                toks.windows(2).take(200).any(|v| v == *w)
            })
            .take(500)
            .count();
        assert!(repeats > 50, "stream looks structureless");
    }
}
