//! The FACT Server — the user's entry point (paper §2.2.1, Alg 3-5).
//!
//! "The entry point for the user is the Server class. Internally it stores
//! an instance of the Workflowmanager of Fed-DART to do the communication
//! with the clients and sending tasks to them. The Server has two main
//! methods, one for initializing the server and the clients and one to
//! launch the training."

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::ParticipationConfig;
use crate::coordinator::participation::{
    participation_round_key, Candidate, CohortSampler,
};
use crate::coordinator::workflow::{RoundClose, WorkflowManager};
use crate::error::{FedError, Result};
use crate::fact::aggregation::ClientUpdate;
use crate::fact::clustering::{ClusterContainer, ClusteringAlgorithm, StaticClustering};
use crate::fact::model::{FactModel, Hyper};
use crate::fact::stopping::{
    ClusteringStoppingCriterion, FixedClusteringRounds, FlStoppingCriterion,
};
use crate::json::Json;
use crate::metrics::Registry;
use crate::privacy::dp::DpAccountant;
use crate::privacy::secagg::{unmask_aggregate, MaskedUpdate, RevealedSeed};
use crate::privacy::{
    from_hex, keys, resolve_reveal_threshold, round_id_to_hex, seed_from_hex,
    shamir, PrivacyConfig, PrivacyMode, RevealPolicy,
};
use crate::util::pool::ThreadPool;
use crate::util::rng::splitmix64;
use crate::util::Stopwatch;

/// Audit record of one secure-aggregation round's recovery (surfaced in
/// [`RoundRecord`] and counted in `fact.secagg.*` metrics).
#[derive(Debug, Clone)]
pub struct SecAggAudit {
    /// masking participants (clients that completed key + share setup)
    pub participants: usize,
    /// resolved t of the t-of-n share recovery
    pub threshold: usize,
    pub dropped: Vec<String>,
    /// (survivor, dropped) pairs covered by direct seed reveals
    pub direct_reveals: usize,
    /// dropped clients whose secret was reconstructed from >= t shares
    pub reconstructed: Vec<String>,
    /// dropped clients left unrecoverable (below threshold)
    pub unrecovered: Vec<String>,
    pub policy: RevealPolicy,
    /// "ok" | "recovered" | "skipped" (proceed policy voided the round)
    pub outcome: &'static str,
}

/// Per-round record (feeds EXPERIMENTS.md and the benches).
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub clustering_round: usize,
    pub cluster_id: usize,
    pub round: usize,
    /// clients that contributed this round
    pub n_clients: usize,
    /// cohort size dispatched this round (== cluster size without
    /// participation sampling)
    pub sampled: usize,
    /// sampled clients whose results arrived after the round closed
    /// (observed in the late-grace sweep, then discarded)
    pub late: usize,
    /// sampled clients that never delivered a counted result
    pub dropped: usize,
    /// realized sampling rate the DP accountant may claim for this round
    /// (1.0 without participation sampling or for non-amplifying
    /// strategies)
    pub sample_rate: f64,
    /// mean local training loss across contributing clients
    pub mean_loss: f32,
    /// wall time of the whole round (dispatch -> aggregated) in ms
    pub round_ms: f64,
    /// aggregation-only time in ms
    pub agg_ms: f64,
    /// mean client-reported duration (paper taskResult.duration), seconds
    pub mean_client_s: f64,
    /// secure-aggregation recovery audit (None outside secagg modes)
    pub secagg: Option<SecAggAudit>,
}

/// Evaluation summary for one cluster.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub cluster_id: usize,
    pub loss: f64,
    /// classification accuracy, or NaN for LM workloads
    pub accuracy: f64,
    /// per-token nll for LM workloads, or NaN
    pub nll_per_token: f64,
    pub n_clients: usize,
}

/// Server-side update rule applied to the aggregated target (FedAvgM,
/// Hsu et al. 2019 — the "new aggregation algorithms can be added easily"
/// extension point, paper §B.3).  `lr = 1, momentum = 0` is plain
/// parameter replacement (classic FedAvg) and takes a fast path that is
/// bit-identical to assignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerOpt {
    pub lr: f32,
    pub momentum: f32,
}

impl Default for ServerOpt {
    fn default() -> Self {
        ServerOpt { lr: 1.0, momentum: 0.0 }
    }
}

impl ServerOpt {
    /// params <- params + lr * buf, where buf <- momentum*buf + (target - params).
    pub fn apply(&self, params: &mut Vec<f32>, target: Vec<f32>, buf: &mut Vec<f32>) {
        if self.lr == 1.0 && self.momentum == 0.0 {
            *params = target; // exact FedAvg replacement
            return;
        }
        if buf.len() != params.len() {
            *buf = vec![0.0; params.len()];
        }
        for ((p, t), b) in params.iter_mut().zip(target).zip(buf.iter_mut()) {
            *b = self.momentum * *b + (t - *p);
            *p += self.lr * *b;
        }
    }
}

/// The FACT Server.
pub struct FactServer {
    wm: Arc<WorkflowManager>,
    container: ClusterContainer,
    clustering: Box<dyn ClusteringAlgorithm>,
    cluster_stop: Box<dyn ClusteringStoppingCriterion>,
    fl_stop: Arc<dyn FlStoppingCriterion>,
    pub hyper: Hyper,
    pub server_opt: ServerOpt,
    pub round_timeout: Duration,
    /// Negotiated privacy mode + parameters for every training round.
    pub privacy: PrivacyConfig,
    /// Partial-participation rounds: cohort sampling + quorum/deadline.
    /// `None` = the legacy loop (address everyone, wait for all).
    participation: Option<ParticipationConfig>,
    /// Last-known per-client sample counts (feeds weighted sampling).
    client_samples: BTreeMap<String, f64>,
    /// (ε, δ) ledger for DP-enabled sessions; persisted with snapshots.
    accountant: DpAccountant,
    /// Per-process tag mixed into round ids so pair seeds never repeat
    /// across server restarts (mask reuse across rounds would leak the
    /// difference of two updates).
    session_tag: u64,
    pool: Arc<ThreadPool>,
    metrics: Registry,
    history: Vec<RoundRecord>,
    /// latest local update per client (clustering input)
    latest_updates: BTreeMap<String, Vec<f32>>,
    initialized: bool,
}

impl FactServer {
    /// Construct around a WorkflowManager (test-mode or production).
    pub fn new(wm: WorkflowManager) -> FactServer {
        FactServer {
            wm: Arc::new(wm),
            container: ClusterContainer::default(),
            clustering: Box::new(StaticClustering),
            cluster_stop: Box::new(FixedClusteringRounds(1)),
            fl_stop: Arc::new(crate::fact::stopping::FixedRoundFl(10)),
            hyper: Hyper::default(),
            server_opt: ServerOpt::default(),
            round_timeout: Duration::from_secs(300),
            privacy: PrivacyConfig::default(),
            participation: None,
            client_samples: BTreeMap::new(),
            accountant: DpAccountant::new(1.0),
            session_tag: splitmix64(
                std::process::id() as u64
                    ^ std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_nanos() as u64)
                        .unwrap_or(0),
            ),
            pool: Arc::new(ThreadPool::default_size()),
            metrics: Registry::new(),
            history: Vec::new(),
            latest_updates: BTreeMap::new(),
            initialized: false,
        }
    }

    pub fn with_hyper(mut self, hyper: Hyper) -> FactServer {
        self.hyper = hyper;
        self
    }

    /// Enable a privacy mode for every subsequent training round.  The
    /// accountant restarts with the configured noise multiplier.
    pub fn with_privacy(mut self, cfg: PrivacyConfig) -> FactServer {
        self.accountant = DpAccountant::new(cfg.noise_multiplier as f64);
        self.privacy = cfg;
        self
    }

    /// The DP ledger accumulated so far (all zeros for non-DP modes).
    pub fn accountant(&self) -> &DpAccountant {
        &self.accountant
    }

    /// Enable partial-participation rounds: every training round samples
    /// a cohort, over-provisions it, and closes at quorum or deadline
    /// instead of waiting for every client.  Validated at `learn()`.
    pub fn with_participation(mut self, cfg: ParticipationConfig) -> FactServer {
        self.participation = Some(cfg);
        self
    }

    /// The active participation config, if partial rounds are enabled.
    pub fn participation(&self) -> Option<&ParticipationConfig> {
        self.participation.as_ref()
    }

    pub fn with_fl_stop(mut self, s: Arc<dyn FlStoppingCriterion>) -> FactServer {
        self.fl_stop = s;
        self
    }

    pub fn workflow_manager(&self) -> &WorkflowManager {
        &self.wm
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    pub fn history(&self) -> &[RoundRecord] {
        &self.history
    }

    pub fn container(&self) -> &ClusterContainer {
        &self.container
    }

    /// Latest per-client local updates (clustering / diagnostics).
    pub fn latest_updates(&self) -> &BTreeMap<String, Vec<f32>> {
        &self.latest_updates
    }

    /// Persist every cluster's current global parameters to an object
    /// store (the paper's MinIO/S3 role, §4.2).  Key layout:
    /// `models/<model>-c<cluster>/round-<n>.json`.
    pub fn checkpoint<S: crate::fact::store::ObjectStore>(
        &self,
        store: &crate::fact::store::ModelStore<S>,
        round: u64,
    ) -> Result<()> {
        // the accountant rides with every snapshot of a privacy-enabled
        // session so a restore resumes the ε ledger
        let privacy = if self.privacy.mode == PrivacyMode::Off {
            Json::Null
        } else {
            Json::obj()
                .set("mode", self.privacy.mode.as_str())
                .set("accountant", self.accountant.to_json())
                .set(
                    "epsilon",
                    self.accountant.epsilon(self.privacy.delta),
                )
                .set("delta", self.privacy.delta)
        };
        for cluster in &self.container.clusters {
            let meta = Json::obj()
                .set("cluster_id", cluster.id)
                .set("clients", cluster.clients.len())
                .set(
                    "last_loss",
                    cluster.loss_history.last().copied().unwrap_or(f32::NAN),
                );
            store.save(&crate::fact::store::Snapshot {
                model: format!("{}-c{}", cluster.model.name(), cluster.id),
                params: crate::util::tensorbuf::TensorBuf::from_f32_slice(
                    &cluster.params,
                ),
                round,
                meta,
                privacy: privacy.clone(),
            })?;
        }
        Ok(())
    }

    /// Restore a cluster's parameters from the latest snapshot, if one
    /// exists.  Returns whether a snapshot was applied.
    pub fn restore_latest<S: crate::fact::store::ObjectStore>(
        &mut self,
        store: &crate::fact::store::ModelStore<S>,
        cluster_idx: usize,
    ) -> Result<bool> {
        let cluster = self
            .container
            .clusters
            .get_mut(cluster_idx)
            .ok_or_else(|| FedError::Fact(format!("no cluster {cluster_idx}")))?;
        let key = format!("{}-c{}", cluster.model.name(), cluster.id);
        match store.load_latest(&key)? {
            Some(snap) if snap.params.len() == cluster.params.len() => {
                cluster.params = snap.params.to_vec();
                // resume the DP ledger recorded with the snapshot (never
                // backwards — a fresher in-memory ledger wins)
                if let Some(aj) = snap.privacy.get("accountant") {
                    if let Ok(acct) = DpAccountant::from_json(aj) {
                        if acct.steps > self.accountant.steps {
                            self.accountant = acct;
                        }
                    }
                }
                Ok(true)
            }
            Some(_) => Err(FedError::Fact("snapshot size mismatch".into())),
            None => Ok(false),
        }
    }

    // ----------------------------------------------------------- Alg 3

    /// `initialization_by_model`: standard FL — one cluster with every
    /// connected client, static clustering, one clustering round.
    pub fn initialization_by_model(
        &mut self,
        model: Arc<dyn FactModel>,
        fl_stop: Arc<dyn FlStoppingCriterion>,
        seed: i32,
    ) -> Result<()> {
        let clients = self.wm.get_all_device_names()?;
        if clients.is_empty() {
            return Err(FedError::Fact("no clients connected".into()));
        }
        let params = model.init_params(seed)?;
        let container = ClusterContainer::single(model, params, clients);
        self.initialization_by_cluster_container(
            container,
            Box::new(StaticClustering),
            Box::new(FixedClusteringRounds(1)),
            fl_stop,
        )
    }

    /// `initialization_by_cluster_container`: personalized FL with explicit
    /// clusters, clustering algorithm, and stopping criteria.
    pub fn initialization_by_cluster_container(
        &mut self,
        container: ClusterContainer,
        clustering: Box<dyn ClusteringAlgorithm>,
        cluster_stop: Box<dyn ClusteringStoppingCriterion>,
        fl_stop: Arc<dyn FlStoppingCriterion>,
    ) -> Result<()> {
        if container.clusters.is_empty() {
            return Err(FedError::Fact("empty cluster container".into()));
        }
        // Alg 3: register the init task and run it on every cluster's
        // clients ("Initialize the local models on the clients ... based on
        // the global model in the cluster").
        let model0 = Arc::clone(&container.clusters[0].model);
        self.wm.create_init_task(model0.init_task_params(), "fact_init");
        for cluster in &container.clusters {
            self.wm
                .selector()
                .ensure_initialized(&cluster.clients.to_vec())?;
        }
        self.container = container;
        self.clustering = clustering;
        self.cluster_stop = cluster_stop;
        self.fl_stop = fl_stop;
        self.initialized = true;
        log::info!(target: "fact::server",
            "initialized: {} cluster(s), {} client(s)",
            self.container.clusters.len(),
            self.container.client_count());
        Ok(())
    }

    // ----------------------------------------------------------- Alg 4/5

    /// The learning method (Alg 4): clustering rounds over parallel
    /// per-cluster training sessions.
    pub fn learn(&mut self) -> Result<()> {
        if !self.initialized {
            return Err(FedError::Fact("server not initialized".into()));
        }
        if self.privacy.mode.has_secagg() {
            // masked aggregation only recovers sums — order-statistic
            // rules (median / trimmed mean) cannot run under it, and the
            // per-client updates clustering would need stay hidden
            for cluster in &self.container.clusters {
                if !cluster.model.aggregation().supports_secure_sum() {
                    return Err(FedError::Privacy(format!(
                        "aggregation {:?} is incompatible with secure \
                         aggregation (only linear rules recover from sums)",
                        cluster.model.aggregation()
                    )));
                }
            }
        }
        if let Some(p) = &self.participation {
            p.validate()?;
            if self.privacy.mode.has_secagg() {
                if p.strategy == crate::config::SamplingStrategy::Poisson {
                    // a Poisson draw can produce a 1-client cohort, whose
                    // "masked" update would be the bare quantized vector
                    return Err(FedError::Privacy(
                        "secagg requires a fixed-size cohort (>= 2 for \
                         pairwise masks) — use the uniform strategy, not \
                         poisson"
                            .into(),
                    ));
                }
                if p.min_cohort < 2 {
                    // pairwise masking needs at least one peer per cohort
                    return Err(FedError::Privacy(
                        "secagg under participation sampling requires \
                         min_cohort >= 2 (pairwise masks need a peer)"
                            .into(),
                    ));
                }
            }
        }
        let mut clustering_round = 0;
        loop {
            // Alg 4 line 2: "foreach cluster ... do in parallel".
            let clusters = std::mem::take(&mut self.container.clusters);
            let wm = Arc::clone(&self.wm);
            let hyper = self.hyper.clone();
            let server_opt = self.server_opt;
            let timeout = self.round_timeout;
            let fl_stop = Arc::clone(&self.fl_stop);
            let pool_for_agg = Arc::clone(&self.pool);
            let privacy = self.privacy.clone();
            let participation = self.participation.clone();
            let known_samples = self.client_samples.clone();
            let metrics = self.metrics.clone();
            let session_tag = self.session_tag;
            let outputs = self.pool.map(clusters, move |mut cluster| {
                let ctx = RoundCtx {
                    wm: &wm,
                    hyper: &hyper,
                    server_opt,
                    fl_stop: fl_stop.as_ref(),
                    timeout,
                    clustering_round,
                    pool: &pool_for_agg,
                    privacy: &privacy,
                    participation: &participation,
                    known_samples: &known_samples,
                    metrics: &metrics,
                    session_tag,
                };
                let out = train_cluster(&ctx, &mut cluster);
                (cluster, out)
            });
            let mut latest = BTreeMap::new();
            let mut restored = Vec::new();
            let hist_before = self.history.len();
            // Collect EVERY cluster's outcome before propagating a
            // failure: completed rounds — including the failing cluster's
            // own rounds before the error (their noised aggregates were
            // already applied) — must be recorded and charged to the ε
            // ledger below.
            let mut first_err: Option<FedError> = None;
            for (cluster, out) in outputs {
                self.history.extend(out.records);
                for (dev, params) in out.latest {
                    latest.insert(dev, params);
                }
                self.client_samples.extend(out.samples);
                if let Some(e) = out.err {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                restored.push(cluster);
            }
            if self.privacy.mode.has_dp() {
                // one accountant step per aggregation round a model ran.
                // Clusters train in parallel on DISJOINT clients, so a
                // client's (and each model's) privacy loss composes over
                // its own cluster's rounds — summing records across
                // clusters would over-count ε by the cluster count.  Per
                // round index, the *max* realized sampling rate across
                // clusters upper-bounds every cluster's subsampled cost
                // (RDP of the sampled Gaussian is monotone in q).
                let mut per_round: BTreeMap<usize, f64> = BTreeMap::new();
                for r in &self.history[hist_before..] {
                    let q = per_round.entry(r.round).or_insert(0.0);
                    if r.sample_rate > *q {
                        *q = r.sample_rate;
                    }
                }
                for (_, q) in per_round {
                    self.accountant.add_round(q);
                }
            }
            self.container.clusters = restored;
            self.latest_updates.extend(latest);
            if let Some(e) = first_err {
                // state and ledger are consistent; surface the failure
                return Err(e);
            }
            self.metrics.counter("fact.clustering_rounds").inc();

            clustering_round += 1;
            if self.cluster_stop.should_stop(clustering_round) {
                break;
            }
            // Alg 4 line 5: apply the clustering algorithm.
            let container = std::mem::take(&mut self.container);
            self.container = self
                .clustering
                .recluster(container, &self.latest_updates)?;
            log::info!(target: "fact::server",
                "clustering round {clustering_round}: now {} cluster(s)",
                self.container.clusters.len());
        }
        Ok(())
    }

    /// Evaluate every cluster's model on its clients' held-out data.
    pub fn evaluate(&self) -> Result<Vec<EvalRecord>> {
        let mut out = Vec::new();
        for cluster in &self.container.clusters {
            // one shared buffer for the whole cluster (see train_cluster)
            let global =
                crate::util::tensorbuf::TensorBuf::from_f32_slice(&cluster.params);
            let dict: BTreeMap<String, Json> = cluster
                .clients
                .iter()
                .map(|c| (c.clone(), cluster.model.eval_params_buf(&global)))
                .collect();
            let results = self.wm.run_task(dict, "fact_evaluate", self.round_timeout)?;
            let mut loss_sum = 0.0f64;
            let mut correct = 0.0f64;
            let mut ntok = 0.0f64;
            let mut n = 0.0f64;
            for r in &results {
                loss_sum += r.result.get("loss_sum").and_then(Json::as_f64).unwrap_or(0.0);
                correct += r.result.get("correct").and_then(Json::as_f64).unwrap_or(0.0);
                ntok += r.result.get("ntok").and_then(Json::as_f64).unwrap_or(0.0);
                n += r.result.get("n").and_then(Json::as_f64).unwrap_or(0.0);
            }
            let is_lm = ntok > 0.0;
            out.push(EvalRecord {
                cluster_id: cluster.id,
                loss: if is_lm { loss_sum / ntok.max(1.0) } else { loss_sum / n.max(1.0) },
                accuracy: if is_lm { f64::NAN } else { correct / n.max(1.0) },
                nll_per_token: if is_lm { loss_sum / ntok } else { f64::NAN },
                n_clients: results.len(),
            });
        }
        Ok(out)
    }
}

/// Outcome of one cluster's training session: everything that completed
/// plus the first error.  Completed rounds ride OUTSIDE the error so a
/// failure in round k never discards rounds 0..k — those aggregates were
/// already applied to the cluster and must still be charged to the DP
/// ledger.
struct ClusterOutcome {
    records: Vec<RoundRecord>,
    latest: BTreeMap<String, Vec<f32>>,
    samples: BTreeMap<String, f64>,
    err: Option<FedError>,
}

/// The per-session invariants every cluster's round loop reads — one
/// bundle instead of a dozen parameters threaded through two signatures
/// and the dispatch closure (future round-loop features extend this
/// struct, not every call site).
struct RoundCtx<'a> {
    wm: &'a WorkflowManager,
    hyper: &'a Hyper,
    server_opt: ServerOpt,
    fl_stop: &'a dyn FlStoppingCriterion,
    timeout: Duration,
    clustering_round: usize,
    pool: &'a ThreadPool,
    privacy: &'a PrivacyConfig,
    participation: &'a Option<ParticipationConfig>,
    known_samples: &'a BTreeMap<String, f64>,
    metrics: &'a Registry,
    session_tag: u64,
}

/// Alg 5: the training session of one cluster.
fn train_cluster(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
) -> ClusterOutcome {
    let mut records = Vec::new();
    let mut latest = BTreeMap::new();
    let mut samples = BTreeMap::new();
    let err =
        train_cluster_rounds(ctx, cluster, &mut records, &mut latest, &mut samples)
            .err();
    ClusterOutcome { records, latest, samples, err }
}

/// The round loop behind [`train_cluster`]; completed rounds accumulate
/// into the out-params so they survive an error return.
fn train_cluster_rounds(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    let RoundCtx {
        wm,
        hyper,
        server_opt,
        fl_stop,
        timeout,
        clustering_round,
        pool,
        privacy,
        participation,
        known_samples,
        metrics,
        session_tag,
    } = *ctx;
    let mut round = 0usize;
    loop {
        let sw = Stopwatch::start();
        let hp = Hyper { round: round as u64, ..hyper.clone() };
        // --- participation: draw this round's cohort (everyone without) --
        let (cohort, realized_q, sampler) = match participation {
            Some(p) => {
                let sampler = CohortSampler::new(p.clone());
                let key = participation_round_key(
                    p.seed,
                    clustering_round,
                    cluster.id,
                    round,
                );
                let candidates: Vec<Candidate> = cluster
                    .clients
                    .iter()
                    .map(|n| Candidate {
                        name: n.clone(),
                        weight: seen_samples
                            .get(n)
                            .or_else(|| known_samples.get(n))
                            .copied()
                            .unwrap_or(1.0)
                            .max(1.0),
                    })
                    .collect();
                let cohort = sampler.sample(key, &candidates);
                let q = sampler
                    .amplification_rate(cohort.len(), cluster.clients.len());
                (cohort, q, Some(sampler))
            }
            None => (cluster.clients.clone(), 1.0, None),
        };
        // Alg 5 line 3: send a training task to each cohort client.
        // The global parameters are materialized into ONE shared buffer;
        // every client's dict holds a cheap clone of it, and the binary
        // wire encoding writes it once (envelope dedup) instead of one
        // base64 copy per client.
        let global = crate::util::tensorbuf::TensorBuf::from_f32_slice(&cluster.params);
        // privacy negotiation: the round's mode and a fresh round id ride
        // in every learn task; clients transform their update accordingly
        let round_id = splitmix64(
            session_tag
                ^ ((clustering_round as u64) << 42)
                ^ ((cluster.id as u64) << 21)
                ^ round as u64,
        );
        // secagg setup phases: per-pair key agreement + encrypted Shamir
        // share distribution run BEFORE the learn dispatch (clients that
        // fail either phase are excluded from the masking participant set)
        let secagg_setup = if privacy.mode.has_secagg() {
            Some(secagg_setup_phases(
                wm, cluster, &cohort, round_id, privacy, participation,
                timeout, metrics,
            )?)
        } else {
            None
        };
        let privacy_round = if privacy.mode == PrivacyMode::Off {
            None
        } else {
            let mut pj = privacy
                .to_json()
                .set("round_id", round_id_to_hex(round_id));
            if participation.is_some() {
                // pin the sampled cohort in the task: a client outside it
                // must refuse to contribute, or the accountant's
                // amplification claim (only sampled clients respond)
                // would be unsound
                pj = pj.set(
                    "cohort",
                    Json::Arr(
                        cohort.iter().map(|c| Json::Str(c.clone())).collect(),
                    ),
                );
            }
            if let Some(setup) = &secagg_setup {
                pj = pj
                    .set(
                        "participants",
                        Json::Arr(
                            setup
                                .participants
                                .iter()
                                .map(|c| Json::Str(c.clone()))
                                .collect(),
                        ),
                    )
                    .set("keys", setup.keys_json.clone())
                    .set("weighted", cluster.model.aggregation().is_weighted());
            }
            Some(pj)
        };
        // under secagg, only the key+share completers can mask: they are
        // the round's addressed set
        let addressed: &[String] = match &secagg_setup {
            Some(setup) => &setup.participants,
            None => &cohort,
        };
        let dict: BTreeMap<String, Json> = addressed
            .iter()
            .map(|c| {
                let mut params = cluster.model.learn_params_buf(&global, &hp);
                if let Some(pj) = &privacy_round {
                    params = params.set("privacy", pj.clone());
                }
                (c.clone(), params)
            })
            .collect();
        let t_start = Instant::now();
        let sampled = dict.len();
        let (results, late, dropped) = match (&sampler, participation) {
            (Some(sampler), Some(p)) => {
                // production round loop: close at quorum or deadline,
                // drop (and count) stragglers
                let quorum = sampler.quorum_count(sampled);
                let deadline = if p.deadline_ms > 0 {
                    Duration::from_millis(p.deadline_ms)
                } else {
                    timeout
                };
                let out = wm.run_task_quorum(
                    dict,
                    "fact_learn",
                    quorum,
                    deadline,
                    Duration::from_millis(p.late_grace_ms),
                )?;
                let late = out.late.len();
                let dropped =
                    sampled.saturating_sub(out.results.len() + late);
                metrics
                    .counter(match out.close {
                        RoundClose::Complete => {
                            "fact.participation.complete_closes"
                        }
                        RoundClose::Quorum => "fact.participation.quorum_closes",
                        RoundClose::Deadline => {
                            "fact.participation.deadline_closes"
                        }
                        RoundClose::Settled => {
                            "fact.participation.settled_closes"
                        }
                    })
                    .inc();
                if out.results.len() < quorum {
                    log::warn!(target: "fact::server",
                        "cluster {} round {round}: closed below quorum \
                         ({}/{quorum} of {sampled} sampled)",
                        cluster.id, out.results.len());
                }
                (out.results, late, dropped)
            }
            _ => {
                let results = wm.run_task(dict, "fact_learn", timeout)?;
                let dropped = sampled.saturating_sub(results.len());
                (results, 0usize, dropped)
            }
        };
        metrics.counter("fact.participation.sampled").add(sampled as u64);
        metrics
            .counter("fact.participation.reported")
            .add(results.len() as u64);
        metrics.counter("fact.participation.late").add(late as u64);
        metrics.counter("fact.participation.dropped").add(dropped as u64);
        if results.is_empty() {
            return Err(FedError::Fact(format!(
                "cluster {}: no client returned a result in round {round}",
                cluster.id
            )));
        }
        // Alg 5 line 5: fetch updated parameters and aggregate.
        let mut updates: Vec<ClientUpdate> = results
            .iter()
            .map(|r| cluster.model.parse_update(&r.device_name, r.duration, &r.result))
            .collect::<Result<Vec<_>>>()?;
        // deterministic aggregation order regardless of arrival order:
        // f32 reduction is order-sensitive, and mode parity (E6) demands
        // bit-identical results between test mode and the TCP path
        updates.sort_by(|a, b| a.device.cmp(&b.device));
        let agg_sw = Stopwatch::start();
        let (target, secagg_audit) = if let Some(setup) = &secagg_setup {
            let out = secagg_recover_aggregate(
                wm, cluster, setup, &updates, round_id, privacy, timeout,
                metrics,
            )?;
            (out.target, Some(out.audit))
        } else {
            (Some(cluster.model.aggregate(&updates, Some(pool))?), None)
        };
        match target {
            Some(target) => {
                let mut buf = std::mem::take(&mut cluster.momentum);
                server_opt.apply(&mut cluster.params, target, &mut buf);
                cluster.momentum = buf;
            }
            None => {
                // reveal policy `proceed`: the round is unrecoverable
                // below the share threshold — void it (parameters
                // unchanged), audit it, keep training
                metrics.counter("fact.secagg.rounds_voided").inc();
                log::warn!(target: "fact::server",
                    "cluster {} round {round}: secagg recovery below \
                     threshold, policy=proceed voids the round",
                    cluster.id);
            }
        }
        let agg_ms = agg_sw.elapsed_ms();

        let mean_loss =
            updates.iter().map(|u| u.loss).sum::<f32>() / updates.len() as f32;
        let mean_client_s =
            updates.iter().map(|u| u.duration).sum::<f64>() / updates.len() as f64;
        cluster.loss_history.push(mean_loss);
        for u in &updates {
            // n_samples is clear even under secagg (the protocol ships it
            // alongside the masked vector); it feeds weighted sampling
            seen_samples.insert(u.device.clone(), u.n_samples as f64);
        }
        if !privacy.mode.has_secagg() {
            // under secagg the per-client vectors are masked lattice noise
            // — recording them would feed garbage to the clustering input
            for u in &updates {
                latest.insert(u.device.clone(), u.params.to_vec());
            }
        }
        records.push(RoundRecord {
            clustering_round,
            cluster_id: cluster.id,
            round,
            n_clients: updates.len(),
            sampled,
            late,
            dropped,
            sample_rate: realized_q,
            mean_loss,
            round_ms: sw.elapsed_ms(),
            agg_ms,
            mean_client_s,
            secagg: secagg_audit,
        });
        log::debug!(target: "fact::server",
            "cluster {} round {round}: loss {mean_loss:.4} \
             ({}/{sampled} sampled clients, {:.1}ms)",
            cluster.id, updates.len(), t_start.elapsed().as_secs_f64() * 1e3);

        round += 1;
        // Alg 5 line 7: stopping criterion.
        if fl_stop.should_stop(round, &cluster.loss_history) {
            break;
        }
    }
    Ok(())
}

/// The artifacts of a round's secagg setup phases: who completed key
/// agreement + share distribution, their public keys, and the relayed
/// (still encrypted) shares + clear commitments.
struct SecAggSetup {
    /// sorted clients that completed BOTH setup phases — the masking
    /// participant set of the round
    participants: Vec<String>,
    /// participant -> hex DH public key
    keys: BTreeMap<String, String>,
    keys_json: Json,
    /// dealer -> recipient -> hex ciphertext (end-to-end encrypted)
    enc_shares: BTreeMap<String, BTreeMap<String, String>>,
    /// dealer -> recipient -> hex share commitment
    commits: BTreeMap<String, BTreeMap<String, String>>,
    /// resolved t of the t-of-n recovery (what the dealers split with)
    threshold: usize,
}

/// Run the two secagg setup phases before a learn dispatch:
///
/// 1. `fact_keys` — every cohort client posts its per-round DH public
///    key (validated here, so a malformed key fails fast).
/// 2. `fact_shares` — every key-poster Shamir-splits its round secret at
///    the resolved threshold and returns one end-to-end encrypted share
///    per peer plus a clear commitment per share.  The coordinator
///    relays ciphertext it cannot read — holding `t` *readable* shares
///    would let it reconstruct any client's masks.
///
/// Clients whose phase task errors — or misses the participation
/// deadline, when one is configured — are excluded from the masking
/// participant set (they never derived the round's pair masks).
/// Without a deadline, a client that hangs past the round timeout
/// stalls the task like any other task.
#[allow(clippy::too_many_arguments)]
fn secagg_setup_phases(
    wm: &WorkflowManager,
    cluster: &crate::fact::clustering::Cluster,
    cohort: &[String],
    round_id: u64,
    privacy: &PrivacyConfig,
    participation: &Option<ParticipationConfig>,
    timeout: Duration,
    metrics: &Registry,
) -> Result<SecAggSetup> {
    // setup phases want EVERY response but must not wait on a hung
    // client forever: under a participation deadline, close at the
    // deadline and exclude whoever had not answered (the straggler
    // tolerance the learn phase already has)
    let run_phase = |dict: BTreeMap<String, Json>,
                     func: &str|
     -> Result<Vec<crate::dart::scheduler::TaskResult>> {
        match participation {
            Some(p) if p.deadline_ms > 0 => {
                let expected = dict.len();
                Ok(wm
                    .run_task_quorum(
                        dict,
                        func,
                        expected, // close only when everyone reported...
                        Duration::from_millis(p.deadline_ms),
                        Duration::ZERO,
                    )?
                    .results) // ...or at the deadline, with whoever did
            }
            _ => wm.run_task(dict, func, timeout),
        }
    };
    let rid_hex = round_id_to_hex(round_id);
    // phase 1: key agreement
    let dict: BTreeMap<String, Json> = cohort
        .iter()
        .map(|c| (c.clone(), Json::obj().set("round_id", rid_hex.as_str())))
        .collect();
    let results = run_phase(dict, "fact_keys")?;
    let mut pubkeys: BTreeMap<String, String> = BTreeMap::new();
    for r in &results {
        if let Some(hex) = r.result.get("pubkey").and_then(Json::as_str) {
            // a malformed or degenerate key excludes THAT client from the
            // round (like a missing response) — it must not abort the
            // whole training session
            match keys::parse_pubkey_hex(hex) {
                Ok(_) => {
                    // lowercase: the reconstruction integrity check
                    // compares against regenerated (lowercase) hex
                    pubkeys.insert(r.device_name.clone(), hex.to_lowercase());
                }
                Err(e) => {
                    metrics.counter("fact.secagg.bad_keys").inc();
                    log::warn!(target: "fact::server",
                        "cluster {}: '{}' posted an invalid DH key ({e}) \
                         — excluded from the round",
                        cluster.id, r.device_name);
                }
            }
        }
    }
    if pubkeys.len() < 2 {
        return Err(FedError::Privacy(format!(
            "cluster {}: only {} client(s) completed secagg key agreement \
             (need >= 2)",
            cluster.id,
            pubkeys.len()
        )));
    }
    if pubkeys.len() > 255 {
        // GF(256) share x-coordinates are 1-based u8 positions: index
        // 255 would wrap to x = 0 (the secret itself), so the holder
        // list caps at 255 participants
        return Err(FedError::Privacy(format!(
            "cluster {}: {} secagg participants exceed the 255-participant \
             limit of GF(256) share coordinates — shard the cohort",
            cluster.id,
            pubkeys.len()
        )));
    }
    let threshold =
        resolve_reveal_threshold(privacy.reveal_threshold, pubkeys.len());
    let mut keys_json = Json::obj();
    for (name, hex) in &pubkeys {
        keys_json = keys_json.set(name, hex.as_str());
    }
    if pubkeys.len() < 3 {
        // a 2-client round has a single share holder per dealer — below
        // any meaningful threshold (t >= 2).  Skip share dealing and
        // rely on direct reveals, the pre-threshold recovery path.
        let participants: Vec<String> = pubkeys.keys().cloned().collect();
        return Ok(SecAggSetup {
            participants,
            keys: pubkeys,
            keys_json,
            enc_shares: BTreeMap::new(),
            commits: BTreeMap::new(),
            threshold,
        });
    }
    // phase 2: encrypted share distribution among the key posters
    let dict: BTreeMap<String, Json> = pubkeys
        .keys()
        .map(|c| {
            (
                c.clone(),
                Json::obj()
                    .set("round_id", rid_hex.as_str())
                    .set("keys", keys_json.clone())
                    .set("threshold", threshold),
            )
        })
        .collect();
    let results = run_phase(dict, "fact_shares")?;
    let mut enc_shares = BTreeMap::new();
    let mut commits = BTreeMap::new();
    for r in &results {
        let (Some(shares), Some(cs)) = (
            r.result.get("shares").and_then(Json::as_obj),
            r.result.get("commits").and_then(Json::as_obj),
        ) else {
            continue;
        };
        let to_map = |obj: &BTreeMap<String, Json>| -> BTreeMap<String, String> {
            obj.iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect()
        };
        enc_shares.insert(r.device_name.clone(), to_map(shares));
        commits.insert(r.device_name.clone(), to_map(cs));
    }
    let participants: Vec<String> = enc_shares.keys().cloned().collect();
    if participants.len() < 2 {
        return Err(FedError::Privacy(format!(
            "cluster {}: only {} client(s) dealt secagg shares (need >= 2)",
            cluster.id,
            participants.len()
        )));
    }
    if participants.len() < cohort.len() {
        metrics
            .counter("fact.secagg.setup_dropouts")
            .add((cohort.len() - participants.len()) as u64);
    }
    Ok(SecAggSetup {
        participants,
        keys: pubkeys,
        keys_json,
        enc_shares,
        commits,
        threshold,
    })
}

/// Outcome of [`secagg_recover_aggregate`]: `target` is `None` when the
/// round was unrecoverable and the `proceed` policy voided it.
struct SecAggOutcome {
    target: Option<Vec<f32>>,
    audit: SecAggAudit,
}

/// Secure-aggregation server path for one round: every masking
/// participant that answered is a survivor, everyone else dropped
/// mid-round (under partial participation the cohort — not the whole
/// cluster — was sampled first, so a straggler cut off at the deadline is
/// recovered exactly like a crash).  Recovery is **threshold-based**:
///
/// * each responsive survivor reveals its own DH-derived pair seed with
///   every dropped peer (covering its own pairs), and its decrypted
///   Shamir share of each dropped dealer's round secret;
/// * any `t` commitment-verified shares reconstruct a dropped client's
///   secret, from which the coordinator derives the pair seed with
///   *every* survivor — including survivors that never answered the
///   reveal task, the exact wedge the PR 3 all-survivors-must-reveal
///   protocol could not recover from;
/// * below `t`, [`PrivacyConfig::reveal_policy`] decides: `abort` fails
///   the session, `proceed` voids the round (audited either way).
///
/// The coordinator never materializes an unmasked individual update —
/// `unmask_aggregate` folds zero-copy views of the masked buffers
/// straight into the integer accumulator.
#[allow(clippy::too_many_arguments)]
fn secagg_recover_aggregate(
    wm: &WorkflowManager,
    cluster: &crate::fact::clustering::Cluster,
    setup: &SecAggSetup,
    updates: &[ClientUpdate],
    round_id: u64,
    privacy: &PrivacyConfig,
    timeout: Duration,
    metrics: &Registry,
) -> Result<SecAggOutcome> {
    let weighted = cluster.model.aggregation().is_weighted();
    let masked: Vec<MaskedUpdate> = updates
        .iter()
        .map(|u| MaskedUpdate {
            device: u.device.clone(),
            params: u.params.clone(),
            weight: if weighted {
                u.n_samples as f64 / privacy.weight_scale as f64
            } else {
                1.0
            },
        })
        .collect();
    let survivors: Vec<String> =
        updates.iter().map(|u| u.device.clone()).collect();
    let dropped: Vec<String> = setup
        .participants
        .iter()
        .filter(|c| !survivors.contains(c))
        .cloned()
        .collect();
    let mut audit = SecAggAudit {
        participants: setup.participants.len(),
        threshold: setup.threshold,
        dropped: dropped.clone(),
        direct_reveals: 0,
        reconstructed: Vec::new(),
        unrecovered: Vec::new(),
        policy: privacy.reveal_policy,
        outcome: "ok",
    };
    let mut revealed: Vec<RevealedSeed> = Vec::new();
    if !dropped.is_empty() {
        log::info!(target: "fact::server",
            "cluster {}: {} dropout(s) in secagg round, recovering masks \
             (t={} of {})",
            cluster.id, dropped.len(), setup.threshold,
            setup.participants.len());
        metrics.counter("fact.secagg.dropouts").add(dropped.len() as u64);
        let dropped_json =
            Json::Arr(dropped.iter().cloned().map(Json::Str).collect());
        let dict: BTreeMap<String, Json> = survivors
            .iter()
            .map(|s| {
                // the encrypted shares each dropped dealer addressed to
                // this survivor, relayed for client-side decryption
                let mut shares = Json::obj();
                for d in &dropped {
                    if let Some(ct) =
                        setup.enc_shares.get(d).and_then(|m| m.get(s))
                    {
                        shares = shares.set(d, ct.as_str());
                    }
                }
                (
                    s.clone(),
                    Json::obj()
                        .set("round_id", round_id_to_hex(round_id))
                        .set("dropped", dropped_json.clone())
                        .set("keys", setup.keys_json.clone())
                        .set("shares", shares),
                )
            })
            .collect();
        let reveals = wm.run_task(dict, "fact_reveal", timeout)?;
        // collect direct seed reveals and decrypted shares
        let mut shares_by_dealer: BTreeMap<String, Vec<shamir::Share>> =
            BTreeMap::new();
        for r in &reveals {
            if let Some(seeds) = r.result.get("seeds").and_then(Json::as_obj) {
                for (d, hex) in seeds {
                    let Some(hex) = hex.as_str() else { continue };
                    revealed.push(RevealedSeed {
                        survivor: r.device_name.clone(),
                        dropped: d.clone(),
                        seed: seed_from_hex(hex)?,
                    });
                    audit.direct_reveals += 1;
                }
            }
            if let Some(shares) = r.result.get("shares").and_then(Json::as_obj)
            {
                for (d, hex) in shares {
                    let Some(hex) = hex.as_str() else { continue };
                    // a malformed share is discarded exactly like a
                    // commitment-failing one — one bad reveal must not
                    // abort a recovery that t other valid shares can
                    // still complete
                    let share = match from_hex(hex)
                        .ok()
                        .and_then(|b| shamir::Share::from_bytes(&b).ok())
                    {
                        Some(s) => s,
                        None => {
                            metrics
                                .counter("fact.secagg.corrupt_shares")
                                .inc();
                            log::warn!(target: "fact::server",
                                "cluster {}: malformed share of '{d}' from \
                                 '{}' — discarded",
                                cluster.id, r.device_name);
                            continue;
                        }
                    };
                    // verify against the dealer's commitment for this
                    // holder — a corrupted share must not enter the pool
                    let commit_ok = setup
                        .commits
                        .get(d)
                        .and_then(|m| m.get(&r.device_name))
                        .and_then(|c| from_hex(c).ok())
                        .map(|want| {
                            want.len() == 32
                                && shamir::verify_share(
                                    &share,
                                    want.as_slice().try_into().unwrap(),
                                )
                        })
                        .unwrap_or(false);
                    if !commit_ok {
                        metrics.counter("fact.secagg.corrupt_shares").inc();
                        log::warn!(target: "fact::server",
                            "cluster {}: share of '{d}' revealed by '{}' \
                             fails its commitment — discarded",
                            cluster.id, r.device_name);
                        continue;
                    }
                    shares_by_dealer.entry(d.clone()).or_default().push(share);
                }
            }
        }
        // per dropped dealer: direct reveals may already cover every
        // survivor; otherwise reconstruct from >= t verified shares
        for d in &dropped {
            let uncovered: Vec<String> = survivors
                .iter()
                .filter(|s| {
                    !revealed
                        .iter()
                        .any(|rv| &rv.survivor == *s && &rv.dropped == d)
                })
                .cloned()
                .collect();
            if uncovered.is_empty() {
                continue;
            }
            let shares = shares_by_dealer.get(d).map(Vec::as_slice).unwrap_or(&[]);
            if shares.len() < setup.threshold {
                audit.unrecovered.push(d.clone());
                continue;
            }
            let Some(posted) = setup.keys.get(d) else {
                audit.unrecovered.push(d.clone());
                continue;
            };
            // shared with the REST board: reconstruct + length check +
            // posted-pubkey integrity check.  A failure here (duplicate
            // coordinates, or commitment-passing shares from a lying
            // dealer that fail the pubkey check) makes THIS dealer
            // unrecoverable — the reveal policy decides the round's
            // fate, not a hard error that would bypass `proceed`.
            let secret = match crate::privacy::secagg::reconstruct_dealer_secret(
                shares,
                setup.threshold,
                posted,
                d,
            ) {
                Ok(s) => s,
                Err(e) => {
                    metrics.counter("fact.secagg.corrupt_shares").inc();
                    log::warn!(target: "fact::server",
                        "cluster {}: reconstruction of '{d}' failed ({e}) \
                         — dealer unrecoverable",
                        cluster.id);
                    audit.unrecovered.push(d.clone());
                    continue;
                }
            };
            for s in &uncovered {
                let their = keys::parse_pubkey_hex(&setup.keys[s])?;
                let shared = keys::shared_key(&secret, &their);
                revealed.push(RevealedSeed {
                    survivor: s.clone(),
                    dropped: d.clone(),
                    seed: keys::pair_seed_from_shared(&shared, round_id, s, d),
                });
            }
            audit.reconstructed.push(d.clone());
        }
        metrics
            .counter("fact.secagg.reconstructions")
            .add(audit.reconstructed.len() as u64);
        if !audit.reconstructed.is_empty() {
            audit.outcome = "recovered";
        }
        if !audit.unrecovered.is_empty() {
            metrics.counter("fact.secagg.below_threshold").inc();
            let detail = format!(
                "cluster {}: secagg round below reveal threshold t={} for \
                 {:?} ({} dropout(s), {} direct reveal(s))",
                cluster.id,
                setup.threshold,
                audit.unrecovered,
                dropped.len(),
                audit.direct_reveals,
            );
            match privacy.reveal_policy {
                RevealPolicy::Abort => {
                    audit.outcome = "aborted";
                    return Err(FedError::Privacy(format!(
                        "{detail} — reveal policy abort"
                    )));
                }
                RevealPolicy::Proceed => {
                    audit.outcome = "skipped";
                    return Ok(SecAggOutcome { target: None, audit });
                }
            }
        }
    }
    let target = unmask_aggregate(&masked, &revealed, privacy.frac_bits)?;
    Ok(SecAggOutcome { target: Some(target), audit })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_opt_replacement_is_exact() {
        let opt = ServerOpt::default();
        let mut p = vec![1.0f32, 2.0];
        let mut buf = Vec::new();
        opt.apply(&mut p, vec![5.0, -1.0], &mut buf);
        assert_eq!(p, vec![5.0, -1.0]);
        assert!(buf.is_empty(), "fast path must not allocate a buffer");
    }

    #[test]
    fn server_opt_momentum_accumulates() {
        let opt = ServerOpt { lr: 1.0, momentum: 0.5 };
        let mut p = vec![0.0f32];
        let mut buf = Vec::new();
        // constant target 1.0: step1 delta=1 -> p=1; step2 buf=0.5*1+(1-1)=0.5 -> p=1.5
        opt.apply(&mut p, vec![1.0], &mut buf);
        assert!((p[0] - 1.0).abs() < 1e-6);
        opt.apply(&mut p, vec![1.0], &mut buf);
        assert!((p[0] - 1.5).abs() < 1e-6, "momentum overshoot expected, got {}", p[0]);
    }

    #[test]
    fn server_opt_small_lr_damps() {
        let opt = ServerOpt { lr: 0.1, momentum: 0.0 };
        let mut p = vec![0.0f32];
        let mut buf = Vec::new();
        opt.apply(&mut p, vec![1.0], &mut buf);
        assert!((p[0] - 0.1).abs() < 1e-6);
    }
    use crate::dart::TaskRegistry;
    use crate::fact::aggregation::Aggregation;
    use crate::fact::client::FactClientRuntime;
    use crate::fact::data::{synthesize, Partition, SyntheticConfig};
    use crate::fact::model::LinearModel;
    use crate::fact::stopping::FixedRoundFl;
    use crate::runtime::{default_artifacts_dir, Engine};

    /// Full FACT loop over test mode with the pure-Rust linear model
    /// (runs even without artifacts) — federated loss must decrease.
    fn linear_fixture(
        clients: usize,
        partition: Partition,
    ) -> Option<(FactServer, Arc<dyn FactModel>)> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None; // engine construction requires the manifest
        }
        let engine = Engine::load(&dir, 1).unwrap();
        let registry = TaskRegistry::new();
        let rt = FactClientRuntime::new(engine);
        let data = synthesize(&SyntheticConfig {
            clients,
            samples_per_client: 256,
            dim: 8,
            classes: 4,
            partition,
            ..Default::default()
        })
        .unwrap();
        for (name, d) in data {
            rt.add_supervised(&name, d);
        }
        rt.register(&registry);
        let wm = WorkflowManager::test_mode(clients, registry, 2);
        let model = LinearModel::arc(8, 4, Aggregation::WeightedFedAvg);
        Some((FactServer::new(wm), model))
    }

    #[test]
    fn standard_fl_loss_decreases() {
        let Some((mut server, model)) = linear_fixture(4, Partition::Iid) else {
            return;
        };
        server.hyper = Hyper { lr: 0.3, mu: 0.0, local_steps: 6, round: 0 };
        server
            .initialization_by_model(model, Arc::new(FixedRoundFl(10)), 42)
            .unwrap();
        server.learn().unwrap();
        let hist = server.history();
        assert_eq!(hist.len(), 10);
        let first = hist.first().unwrap().mean_loss;
        let last = hist.last().unwrap().mean_loss;
        assert!(
            last < 0.7 * first,
            "federated loss did not decrease: {first} -> {last}"
        );
        assert!(hist.iter().all(|r| r.n_clients == 4));
        // evaluation works and accuracy is above chance (0.25)
        let evals = server.evaluate().unwrap();
        assert_eq!(evals.len(), 1);
        assert!(evals[0].accuracy > 0.3, "accuracy {}", evals[0].accuracy);
    }

    #[test]
    fn learn_requires_initialization() {
        let Some((mut server, _)) = linear_fixture(2, Partition::Iid) else {
            return;
        };
        assert!(server.learn().is_err());
    }

    #[test]
    fn latest_updates_are_tracked_per_client() {
        let Some((mut server, model)) = linear_fixture(3, Partition::Iid) else {
            return;
        };
        server
            .initialization_by_model(model, Arc::new(FixedRoundFl(2)), 1)
            .unwrap();
        server.learn().unwrap();
        assert_eq!(server.latest_updates().len(), 3);
        for v in server.latest_updates().values() {
            assert_eq!(v.len(), 8 * 4 + 4);
        }
    }

    #[test]
    fn clustered_fl_runs_multiple_clustering_rounds() {
        use crate::fact::clustering::KMeansClustering;
        let Some((mut server, model)) =
            linear_fixture(6, Partition::LatentGroups { groups: 2 })
        else {
            return;
        };
        server.hyper = Hyper { lr: 0.3, mu: 0.0, local_steps: 4, round: 0 };
        let clients = server.workflow_manager().get_all_device_names().unwrap();
        let params = model.init_params(0).unwrap();
        let container = ClusterContainer::single(model, params, clients);
        server
            .initialization_by_cluster_container(
                container,
                Box::new(KMeansClustering::new(2)),
                Box::new(FixedClusteringRounds(2)),
                Arc::new(FixedRoundFl(3)),
            )
            .unwrap();
        server.learn().unwrap();
        // after round 1 the container was re-clustered into 2 clusters
        assert_eq!(server.container().clusters.len(), 2);
        // history spans both clustering rounds
        assert!(server.history().iter().any(|r| r.clustering_round == 0));
        assert!(server.history().iter().any(|r| r.clustering_round == 1));
        let evals = server.evaluate().unwrap();
        assert_eq!(evals.len(), 2);
    }
}
