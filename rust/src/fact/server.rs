//! The FACT Server — the user's entry point (paper §2.2.1, Alg 3-5).
//!
//! "The entry point for the user is the Server class. Internally it stores
//! an instance of the Workflowmanager of Fed-DART to do the communication
//! with the clients and sending tasks to them. The Server has two main
//! methods, one for initializing the server and the clients and one to
//! launch the training."

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use crate::config::ParticipationConfig;
use crate::coordinator::latency::LatencyTracker;
use crate::coordinator::round_store::{
    EventKind, LedgerCharge, MemRoundStore, RecoveryStatus, RoundEvent,
    RoundPhase, RoundState, RoundStore,
};
use crate::coordinator::workflow::WorkflowManager;
use crate::error::{FedError, Result};
use crate::fact::clustering::{ClusterContainer, ClusteringAlgorithm, StaticClustering};
use crate::fact::model::{FactModel, Hyper};
use crate::fact::rounds::ctx::RoundCtx;
use crate::fact::rounds::optimizer::{OptState, PlainReplace, ServerOptimizer};
use crate::fact::rounds::pipeline::train_cluster;
use crate::fact::rounds::strategy::LocalStrategy;
use crate::fact::stopping::{
    ClusteringStoppingCriterion, FixedClusteringRounds, FlStoppingCriterion,
};
use crate::json::Json;
use crate::metrics::Registry;
use crate::privacy::dp::DpAccountant;
use crate::privacy::{
    round_id_to_hex, PrivacyConfig, PrivacyMode, RevealPolicy,
};
use crate::telemetry::phase;
use crate::util::pool::ThreadPool;
use crate::util::rng::splitmix64;

/// Audit record of one secure-aggregation round's recovery (surfaced in
/// [`RoundRecord`] and counted in `fact.secagg.*` metrics).
#[derive(Debug, Clone)]
pub struct SecAggAudit {
    /// masking participants (clients that completed key + share setup)
    pub participants: usize,
    /// resolved t of the t-of-n share recovery
    pub threshold: usize,
    pub dropped: Vec<String>,
    /// (survivor, dropped) pairs covered by direct seed reveals
    pub direct_reveals: usize,
    /// dropped clients whose secret was reconstructed from >= t shares
    pub reconstructed: Vec<String>,
    /// dropped clients left unrecoverable (below threshold)
    pub unrecovered: Vec<String>,
    pub policy: RevealPolicy,
    /// "ok" | "recovered" | "skipped" (proceed policy voided the round)
    pub outcome: &'static str,
}

impl SecAggAudit {
    /// Serialize for the round store (`Revealed` events, `RoundRecord`s).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("participants", self.participants)
            .set("threshold", self.threshold)
            .set(
                "dropped",
                Json::Arr(self.dropped.iter().cloned().map(Json::Str).collect()),
            )
            .set("direct_reveals", self.direct_reveals)
            .set(
                "reconstructed",
                Json::Arr(self.reconstructed.iter().cloned().map(Json::Str).collect()),
            )
            .set(
                "unrecovered",
                Json::Arr(self.unrecovered.iter().cloned().map(Json::Str).collect()),
            )
            .set("policy", self.policy.as_str())
            .set("outcome", self.outcome)
    }

    /// Parse the store form back.
    pub fn from_json(j: &Json) -> Result<SecAggAudit> {
        let strs = |key: &str| -> Vec<String> {
            j.get(key)
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default()
        };
        Ok(SecAggAudit {
            participants: j
                .get("participants")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            threshold: j.get("threshold").and_then(Json::as_usize).unwrap_or(0),
            dropped: strs("dropped"),
            direct_reveals: j
                .get("direct_reveals")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            reconstructed: strs("reconstructed"),
            unrecovered: strs("unrecovered"),
            policy: RevealPolicy::parse(
                j.get("policy").and_then(Json::as_str).unwrap_or("abort"),
            )?,
            // map back onto the audit's static vocabulary
            outcome: match j.get("outcome").and_then(Json::as_str) {
                Some("recovered") => "recovered",
                Some("skipped") => "skipped",
                Some("aborted") => "aborted",
                _ => "ok",
            },
        })
    }
}

/// Per-round record (feeds EXPERIMENTS.md and the benches).
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub clustering_round: usize,
    pub cluster_id: usize,
    pub round: usize,
    /// clients that contributed this round
    pub n_clients: usize,
    /// cohort size dispatched this round (== cluster size without
    /// participation sampling)
    pub sampled: usize,
    /// sampled clients whose results arrived after the round closed
    /// (observed in the late-grace sweep, then discarded)
    pub late: usize,
    /// sampled clients that never delivered a counted result
    pub dropped: usize,
    /// realized sampling rate the DP accountant may claim for this round
    /// (1.0 without participation sampling or for non-amplifying
    /// strategies)
    pub sample_rate: f64,
    /// mean local training loss across contributing clients
    pub mean_loss: f32,
    /// wall time of the whole round (dispatch -> aggregated) in ms
    pub round_ms: f64,
    /// aggregation-only time in ms
    pub agg_ms: f64,
    /// mean client-reported duration (paper taskResult.duration), seconds
    pub mean_client_s: f64,
    /// secure-aggregation recovery audit (None outside secagg modes)
    pub secagg: Option<SecAggAudit>,
    /// server optimizer the aggregate was applied with ("plain", ...)
    pub server_opt: String,
    /// local strategy negotiated into the round's learn dicts
    pub local_strategy: String,
}

impl RoundRecord {
    /// Serialize for the round store (`Aggregated`/`Voided` events) so
    /// the audit history survives a coordinator restart.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("clustering_round", self.clustering_round)
            .set("cluster_id", self.cluster_id)
            .set("round", self.round)
            .set("n_clients", self.n_clients)
            .set("sampled", self.sampled)
            .set("late", self.late)
            .set("dropped", self.dropped)
            .set("sample_rate", self.sample_rate)
            .set("mean_loss", self.mean_loss)
            .set("round_ms", self.round_ms)
            .set("agg_ms", self.agg_ms)
            .set("mean_client_s", self.mean_client_s)
            .set("server_opt", self.server_opt.as_str())
            .set("local_strategy", self.local_strategy.as_str());
        if let Some(a) = &self.secagg {
            o = o.set("secagg", a.to_json());
        }
        o
    }

    /// Parse the store form back.
    pub fn from_json(j: &Json) -> Result<RoundRecord> {
        let us = |key: &str| j.get(key).and_then(Json::as_usize).unwrap_or(0);
        let f = |key: &str| j.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        Ok(RoundRecord {
            clustering_round: us("clustering_round"),
            cluster_id: us("cluster_id"),
            round: us("round"),
            n_clients: us("n_clients"),
            sampled: us("sampled"),
            late: us("late"),
            dropped: us("dropped"),
            sample_rate: f("sample_rate"),
            mean_loss: f("mean_loss") as f32,
            round_ms: f("round_ms"),
            agg_ms: f("agg_ms"),
            mean_client_s: f("mean_client_s"),
            secagg: j.get("secagg").map(SecAggAudit::from_json).transpose()?,
            // records persisted before the optimizer seam default to the
            // only behavior that existed then
            server_opt: j
                .get("server_opt")
                .and_then(Json::as_str)
                .unwrap_or("plain")
                .to_string(),
            local_strategy: j
                .get("local_strategy")
                .and_then(Json::as_str)
                .unwrap_or("plain")
                .to_string(),
        })
    }
}

/// What [`FactServer::recover`] found in the round store and what it did
/// about it.
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    /// What the store itself replayed on open (WAL/snapshot detail).
    pub status: RecoveryStatus,
    /// Closed/voided rounds restored into the audit history.
    pub replayed_records: usize,
    /// In-flight rounds queued for resumption by the next `learn()`.
    pub resumed: usize,
    /// Tainted in-flight rounds voided (reveal policy `proceed`).
    pub voided: usize,
    /// ε-ledger charges re-derived for closed-but-uncharged rounds.
    pub charges_restored: usize,
}

impl RecoveryReport {
    /// Serialize for the CLI / REST recovery surfaces.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("store", self.status.to_json())
            .set("replayed_records", self.replayed_records)
            .set("resumed", self.resumed)
            .set("voided", self.voided)
            .set("charges_restored", self.charges_restored)
    }
}

/// Evaluation summary for one cluster.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub cluster_id: usize,
    pub loss: f64,
    /// classification accuracy, or NaN for LM workloads
    pub accuracy: f64,
    /// per-token nll for LM workloads, or NaN
    pub nll_per_token: f64,
    pub n_clients: usize,
}


/// The FACT Server.
pub struct FactServer {
    wm: Arc<WorkflowManager>,
    container: ClusterContainer,
    clustering: Box<dyn ClusteringAlgorithm>,
    cluster_stop: Box<dyn ClusteringStoppingCriterion>,
    fl_stop: Arc<dyn FlStoppingCriterion>,
    pub hyper: Hyper,
    /// Server-side update rule applied to every round's aggregate (the
    /// `ServerOptimizer` seam — plain replacement by default).
    pub server_opt: Arc<dyn ServerOptimizer>,
    /// Client-side training variant negotiated into every learn dict
    /// (the `LocalStrategy` seam — plain local SGD by default).
    pub local_strategy: LocalStrategy,
    pub round_timeout: Duration,
    /// Negotiated privacy mode + parameters for every training round.
    pub privacy: PrivacyConfig,
    /// Partial-participation rounds: cohort sampling + quorum/deadline.
    /// `None` = the legacy loop (address everyone, wait for all).
    participation: Option<ParticipationConfig>,
    /// Last-known per-client sample counts (feeds weighted sampling).
    client_samples: BTreeMap<String, f64>,
    /// (ε, δ) ledger for DP-enabled sessions; persisted with snapshots.
    accountant: DpAccountant,
    /// Per-process tag mixed into round ids so pair seeds never repeat
    /// across server restarts (mask reuse across rounds would leak the
    /// difference of two updates).
    session_tag: u64,
    pool: Arc<ThreadPool>,
    metrics: Registry,
    /// Per-client learn-latency history feeding adaptive round deadlines
    /// (shared across cluster worker threads; lives for the session).
    latency: Arc<LatencyTracker>,
    history: Vec<RoundRecord>,
    /// latest local update per client (clustering input)
    latest_updates: BTreeMap<String, Vec<f32>>,
    initialized: bool,
    /// The round state machine's home: every round's lifecycle is
    /// appended here (in-memory by default, WAL-backed via
    /// [`FactServer::with_round_store`]).
    store: Arc<dyn RoundStore>,
    /// In-flight rounds loaded by [`FactServer::recover`], keyed by
    /// `(clustering_round, cluster_id, round)`; consumed by the next
    /// `learn()` call, which resumes them instead of starting fresh.
    resume_plans: BTreeMap<(usize, usize, usize), RoundState>,
    /// Rounds the store already closed (replayed by `recover()`); the
    /// next `learn()` skips them outright.
    completed_rounds: BTreeSet<(usize, usize, usize)>,
    /// ε-ledger charges already in the store — `learn()` must not charge
    /// these round indices again.
    already_charged: BTreeSet<(usize, usize)>,
    /// Replayed charges whose round index still has an in-flight sibling
    /// round: deferred so `learn()` can charge the max realized rate
    /// across replayed + resumed clusters, exactly like an uninterrupted
    /// run.
    deferred_charges: BTreeMap<(usize, usize), f64>,
    /// Flight recorder round traces are written to: the process-global
    /// recorder by default, a private one via
    /// [`FactServer::with_telemetry`] (tests simulate a restart by
    /// recovering into a fresh recorder).
    tele: Arc<crate::telemetry::Recorder>,
}

impl FactServer {
    /// Construct around a WorkflowManager (test-mode or production).
    pub fn new(wm: WorkflowManager) -> FactServer {
        FactServer {
            wm: Arc::new(wm),
            container: ClusterContainer::default(),
            clustering: Box::new(StaticClustering),
            cluster_stop: Box::new(FixedClusteringRounds(1)),
            fl_stop: Arc::new(crate::fact::stopping::FixedRoundFl(10)),
            hyper: Hyper::default(),
            server_opt: Arc::new(PlainReplace),
            local_strategy: LocalStrategy::Plain,
            round_timeout: Duration::from_secs(300),
            privacy: PrivacyConfig::default(),
            participation: None,
            client_samples: BTreeMap::new(),
            accountant: DpAccountant::new(1.0),
            session_tag: splitmix64(
                std::process::id() as u64
                    ^ std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_nanos() as u64)
                        .unwrap_or(0),
            ),
            pool: Arc::new(ThreadPool::default_size()),
            metrics: Registry::new(),
            latency: Arc::new(LatencyTracker::default()),
            history: Vec::new(),
            latest_updates: BTreeMap::new(),
            initialized: false,
            store: Arc::new(MemRoundStore::new()),
            resume_plans: BTreeMap::new(),
            completed_rounds: BTreeSet::new(),
            already_charged: BTreeSet::new(),
            deferred_charges: BTreeMap::new(),
            tele: Arc::clone(crate::telemetry::global()),
        }
    }

    /// Record round traces into an explicit flight recorder instead of
    /// the process-global one.
    pub fn with_telemetry(mut self, rec: Arc<crate::telemetry::Recorder>) -> FactServer {
        self.tele = rec;
        self
    }

    /// The flight recorder round traces land in.
    pub fn telemetry(&self) -> &Arc<crate::telemetry::Recorder> {
        &self.tele
    }

    pub fn with_hyper(mut self, hyper: Hyper) -> FactServer {
        self.hyper = hyper;
        self
    }

    /// Apply every round's aggregate through a specific server-side
    /// optimizer (see [`crate::fact::rounds::optimizer`]).  Optimizer
    /// state is persisted per cluster inside `Aggregated` round-store
    /// events, so crash recovery is exact under stateful rules too.
    pub fn with_server_opt(mut self, opt: Arc<dyn ServerOptimizer>) -> FactServer {
        self.server_opt = opt;
        self
    }

    /// Negotiate a local-training strategy into every learn dict (see
    /// [`crate::fact::rounds::strategy`]).
    pub fn with_local_strategy(mut self, s: LocalStrategy) -> FactServer {
        self.local_strategy = s;
        self
    }

    /// Enable a privacy mode for every subsequent training round.  The
    /// accountant restarts with the configured noise multiplier.
    pub fn with_privacy(mut self, cfg: PrivacyConfig) -> FactServer {
        self.accountant = DpAccountant::new(cfg.noise_multiplier as f64);
        self.privacy = cfg;
        self
    }

    /// The DP ledger accumulated so far (all zeros for non-DP modes).
    pub fn accountant(&self) -> &DpAccountant {
        &self.accountant
    }

    /// Put all round state behind a specific [`RoundStore`] backend
    /// (e.g. [`crate::coordinator::round_store::WalRoundStore`] for a
    /// durable, crash-recoverable coordinator).  Pair with
    /// [`FactServer::recover`] after initialization to resume whatever
    /// the store holds.
    pub fn with_round_store(mut self, store: Arc<dyn RoundStore>) -> FactServer {
        self.store = store;
        self
    }

    /// Pin the per-process session tag (tests: reproducible round ids).
    /// A tag already persisted in the round store still wins at
    /// [`FactServer::recover`] time.
    pub fn with_session_tag(mut self, tag: u64) -> FactServer {
        self.session_tag = tag;
        self
    }

    /// The round store every round's lifecycle is appended to.
    pub fn round_store(&self) -> &Arc<dyn RoundStore> {
        &self.store
    }

    /// The tag mixed into every derived round id this session.
    pub fn session_tag(&self) -> u64 {
        self.session_tag
    }

    /// Report into an external metrics [`Registry`] (e.g. the one a
    /// co-located DART REST server snapshots for `/metrics` and
    /// `/rounds/recovery`) instead of a private one.
    pub fn with_metrics(mut self, metrics: Registry) -> FactServer {
        self.metrics = metrics;
        self
    }

    /// The learn-latency tracker behind adaptive deadlines (warm it up
    /// in tests, or inspect the observed quantiles).
    pub fn latency_tracker(&self) -> &Arc<LatencyTracker> {
        &self.latency
    }

    /// Replay the round store and prepare to resume: adopt the stored
    /// session tag (so fresh rounds derive the ids the dead coordinator
    /// would have), rebuild the ε ledger from persisted charges, restore
    /// the audit history and fast-forward cluster params over closed
    /// rounds, heal closed-but-uncharged rounds (the snapshot/WAL fork),
    /// and queue in-flight rounds for the next [`FactServer::learn`].
    ///
    /// Tainted rounds (a truncated/corrupt WAL tail touched them) are
    /// never resumed: `RevealPolicy::Abort` fails recovery,
    /// `RevealPolicy::Proceed` voids them and continues.
    ///
    /// Call after `initialization_by_*` (clusters must exist to
    /// fast-forward) and after `with_privacy`.
    pub fn recover(&mut self) -> Result<RecoveryReport> {
        if !self.initialized {
            return Err(FedError::Fact(
                "recover() requires an initialized server".into(),
            ));
        }
        self.session_tag = self.store.set_session_tag(self.session_tag)?;
        let status = self.store.recovery();

        // 0) replay the durable flight-recorder dump (trace.jsonl lives
        //    next to the WAL): closed rounds' traces survive the crash,
        //    so `GET /trace/{round_id}` keeps answering after a restart.
        //    Span-id dedup makes the replay idempotent.
        if let Some(dir) = self.store.trace_dir() {
            match self.tele.load_jsonl(&dir.join("trace.jsonl")) {
                Ok(n) if n > 0 => log::info!(target: "fact::server",
                    "recover: replayed {n} trace records from trace.jsonl"),
                Ok(_) => {}
                Err(e) => log::warn!(target: "fact::server",
                    "recover: trace.jsonl replay failed: {e}"),
            }
        }

        // 1) the ε ledger: the store's charge log is the source of truth.
        //    A stale Snapshot accountant can never fork history — the
        //    never-backwards rule (mirroring restore_latest) keeps
        //    whichever ledger has accounted more rounds.
        let charges = self.store.charges()?;
        if self.privacy.mode.has_dp() && !charges.is_empty() {
            let mut acct =
                DpAccountant::new(self.privacy.noise_multiplier as f64);
            for c in &charges {
                acct.add_round(c.q);
            }
            if acct.steps > self.accountant.steps {
                self.accountant = acct;
            }
        }
        self.already_charged = charges.iter().map(LedgerCharge::key).collect();

        let rounds = self.store.rounds()?;
        // 2) terminal rounds: restore records + loss history in execution
        //    order, fast-forward params over closed rounds
        let mut terminal: Vec<&RoundState> =
            rounds.iter().filter(|r| r.phase.is_terminal()).collect();
        terminal.sort_by_key(|r| (r.clustering_round, r.cluster_id, r.round));
        let mut replayed_records = 0usize;
        let mut uncharged: BTreeMap<(usize, usize), f64> = BTreeMap::new();
        for r in &terminal {
            self.completed_rounds
                .insert((r.clustering_round, r.cluster_id, r.round));
            let rec = match &r.record {
                Some(rj) => match RoundRecord::from_json(rj) {
                    Ok(rec) => rec,
                    Err(_) => continue,
                },
                None => continue, // e.g. voided before any update arrived
            };
            if let Some(cluster) = self
                .container
                .clusters
                .iter_mut()
                .find(|c| c.id == r.cluster_id)
            {
                cluster.loss_history.push(rec.mean_loss);
                if r.phase == RoundPhase::Closed {
                    if let Some(pa) = &r.params_after {
                        if pa.len() == cluster.params.len() {
                            cluster.params = pa.to_vec();
                        }
                    }
                    // fast-forward the server-optimizer state too, so a
                    // stateful rule (FedAvgM/FedAdam) resumes with the
                    // exact momentum buffers the dead coordinator held
                    if let Some(oj) = &r.opt_state {
                        if let Ok(st) = OptState::from_json(oj) {
                            cluster.opt_state = st;
                        }
                    }
                }
            }
            if r.phase == RoundPhase::Closed || r.void_reason.is_some() {
                let key = (r.clustering_round, r.round);
                if self.privacy.mode.has_dp() && !self.already_charged.contains(&key)
                {
                    let e = uncharged.entry(key).or_insert(0.0);
                    if rec.sample_rate > *e {
                        *e = rec.sample_rate;
                    }
                }
            }
            self.history.push(rec);
            replayed_records += 1;
        }

        // 3) in-flight rounds: taint -> policy; otherwise queue a resume
        //    plan for learn()
        let mut resumed = 0usize;
        let mut voided = 0usize;
        let mut pending_keys: BTreeSet<(usize, usize)> = BTreeSet::new();
        for r in rounds.iter().filter(|r| !r.phase.is_terminal()) {
            if r.tainted {
                match self.privacy.reveal_policy {
                    RevealPolicy::Abort => {
                        return Err(FedError::Privacy(format!(
                            "round store has a tainted in-flight round \
                             (cluster {} round {}: corrupt WAL tail) — \
                             reveal policy abort refuses to resume",
                            r.cluster_id, r.round
                        )));
                    }
                    RevealPolicy::Proceed => {
                        self.store.append(RoundEvent::new(
                            r.round_id,
                            EventKind::Voided {
                                reason: "corrupt WAL tail truncated mid-round"
                                    .into(),
                                record: Json::Null,
                            },
                        ))?;
                        self.metrics.counter("fact.roundstore.voided").inc();
                        // the round index is burned, not re-runnable: its
                        // id is now terminal in the store
                        self.completed_rounds.insert((
                            r.clustering_round,
                            r.cluster_id,
                            r.round,
                        ));
                        voided += 1;
                        continue;
                    }
                }
            }
            pending_keys.insert((r.clustering_round, r.round));
            self.resume_plans
                .insert((r.clustering_round, r.cluster_id, r.round), r.clone());
            resumed += 1;
        }

        // 4) heal the ledger fork: closed rounds whose charge never made
        //    it to disk.  Round indices with an in-flight sibling are
        //    deferred so learn() charges the max realized rate across
        //    replayed AND resumed clusters (what an uninterrupted run
        //    would have charged).
        let mut charges_restored = 0usize;
        for (key, q) in uncharged {
            if pending_keys.contains(&key) {
                self.deferred_charges.insert(key, q);
                continue;
            }
            self.store.append_charge(LedgerCharge {
                clustering_round: key.0,
                round: key.1,
                q,
                noise_multiplier: self.privacy.noise_multiplier as f64,
            })?;
            self.accountant.add_round(q);
            self.already_charged.insert(key);
            charges_restored += 1;
        }

        self.metrics
            .counter("fact.roundstore.replayed")
            .add(replayed_records as u64);
        self.metrics
            .counter("fact.roundstore.resumed")
            .add(resumed as u64);
        if status.events_replayed > 0 || resumed > 0 || voided > 0 {
            log::info!(target: "fact::server",
                "recover: {} event(s) replayed, {} record(s) restored, \
                 {} round(s) to resume, {} voided, {} charge(s) healed",
                status.events_replayed, replayed_records, resumed, voided,
                charges_restored);
        }
        Ok(RecoveryReport {
            status,
            replayed_records,
            resumed,
            voided,
            charges_restored,
        })
    }

    /// Enable partial-participation rounds: every training round samples
    /// a cohort, over-provisions it, and closes at quorum or deadline
    /// instead of waiting for every client.  Validated at `learn()`.
    pub fn with_participation(mut self, cfg: ParticipationConfig) -> FactServer {
        self.participation = Some(cfg);
        self
    }

    /// The active participation config, if partial rounds are enabled.
    pub fn participation(&self) -> Option<&ParticipationConfig> {
        self.participation.as_ref()
    }

    pub fn with_fl_stop(mut self, s: Arc<dyn FlStoppingCriterion>) -> FactServer {
        self.fl_stop = s;
        self
    }

    pub fn workflow_manager(&self) -> &WorkflowManager {
        &self.wm
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    pub fn history(&self) -> &[RoundRecord] {
        &self.history
    }

    pub fn container(&self) -> &ClusterContainer {
        &self.container
    }

    /// Latest per-client local updates (clustering / diagnostics).
    pub fn latest_updates(&self) -> &BTreeMap<String, Vec<f32>> {
        &self.latest_updates
    }

    /// Persist every cluster's current global parameters to an object
    /// store (the paper's MinIO/S3 role, §4.2).  Key layout:
    /// `models/<model>-c<cluster>/round-<n>.json`.
    pub fn checkpoint<S: crate::fact::store::ObjectStore>(
        &self,
        store: &crate::fact::store::ModelStore<S>,
        round: u64,
    ) -> Result<()> {
        // the accountant rides with every snapshot of a privacy-enabled
        // session so a restore resumes the ε ledger
        let privacy = if self.privacy.mode == PrivacyMode::Off {
            Json::Null
        } else {
            Json::obj()
                .set("mode", self.privacy.mode.as_str())
                .set("accountant", self.accountant.to_json())
                .set(
                    "epsilon",
                    self.accountant.epsilon(self.privacy.delta),
                )
                .set("delta", self.privacy.delta)
        };
        for cluster in &self.container.clusters {
            let meta = Json::obj()
                .set("cluster_id", cluster.id)
                .set("clients", cluster.clients.len())
                .set(
                    "last_loss",
                    cluster.loss_history.last().copied().unwrap_or(f32::NAN),
                );
            store.save(&crate::fact::store::Snapshot {
                model: format!("{}-c{}", cluster.model.name(), cluster.id),
                params: crate::util::tensorbuf::TensorBuf::from_f32_slice(
                    &cluster.params,
                ),
                round,
                meta,
                privacy: privacy.clone(),
            })?;
        }
        Ok(())
    }

    /// Restore a cluster's parameters from the latest snapshot, if one
    /// exists.  Returns whether a snapshot was applied.
    pub fn restore_latest<S: crate::fact::store::ObjectStore>(
        &mut self,
        store: &crate::fact::store::ModelStore<S>,
        cluster_idx: usize,
    ) -> Result<bool> {
        let cluster = self
            .container
            .clusters
            .get_mut(cluster_idx)
            .ok_or_else(|| FedError::Fact(format!("no cluster {cluster_idx}")))?;
        let key = format!("{}-c{}", cluster.model.name(), cluster.id);
        match store.load_latest(&key)? {
            Some(snap) if snap.params.len() == cluster.params.len() => {
                cluster.params = snap.params.to_vec();
                // resume the DP ledger recorded with the snapshot (never
                // backwards — a fresher in-memory ledger wins)
                if let Some(aj) = snap.privacy.get("accountant") {
                    if let Ok(acct) = DpAccountant::from_json(aj) {
                        if acct.steps > self.accountant.steps {
                            self.accountant = acct;
                        }
                    }
                }
                Ok(true)
            }
            Some(_) => Err(FedError::Fact("snapshot size mismatch".into())),
            None => Ok(false),
        }
    }

    // ----------------------------------------------------------- Alg 3

    /// `initialization_by_model`: standard FL — one cluster with every
    /// connected client, static clustering, one clustering round.
    pub fn initialization_by_model(
        &mut self,
        model: Arc<dyn FactModel>,
        fl_stop: Arc<dyn FlStoppingCriterion>,
        seed: i32,
    ) -> Result<()> {
        let clients = self.wm.get_all_device_names()?;
        if clients.is_empty() {
            return Err(FedError::Fact("no clients connected".into()));
        }
        let params = model.init_params(seed)?;
        let container = ClusterContainer::single(model, params, clients);
        self.initialization_by_cluster_container(
            container,
            Box::new(StaticClustering),
            Box::new(FixedClusteringRounds(1)),
            fl_stop,
        )
    }

    /// `initialization_by_cluster_container`: personalized FL with explicit
    /// clusters, clustering algorithm, and stopping criteria.
    pub fn initialization_by_cluster_container(
        &mut self,
        container: ClusterContainer,
        clustering: Box<dyn ClusteringAlgorithm>,
        cluster_stop: Box<dyn ClusteringStoppingCriterion>,
        fl_stop: Arc<dyn FlStoppingCriterion>,
    ) -> Result<()> {
        if container.clusters.is_empty() {
            return Err(FedError::Fact("empty cluster container".into()));
        }
        // Alg 3: register the init task and run it on every cluster's
        // clients ("Initialize the local models on the clients ... based on
        // the global model in the cluster").
        let model0 = Arc::clone(&container.clusters[0].model);
        self.wm.create_init_task(model0.init_task_params(), "fact_init");
        for cluster in &container.clusters {
            self.wm
                .selector()
                .ensure_initialized(&cluster.clients.to_vec())?;
        }
        self.container = container;
        self.clustering = clustering;
        self.cluster_stop = cluster_stop;
        self.fl_stop = fl_stop;
        self.initialized = true;
        log::info!(target: "fact::server",
            "initialized: {} cluster(s), {} client(s)",
            self.container.clusters.len(),
            self.container.client_count());
        Ok(())
    }

    // ----------------------------------------------------------- Alg 4/5

    /// The learning method (Alg 4): clustering rounds over parallel
    /// per-cluster training sessions.
    pub fn learn(&mut self) -> Result<()> {
        if !self.initialized {
            return Err(FedError::Fact("server not initialized".into()));
        }
        if self.privacy.mode.has_secagg() {
            // masked aggregation only recovers sums — order-statistic
            // rules (median / trimmed mean) cannot run under it, and the
            // per-client updates clustering would need stay hidden
            for cluster in &self.container.clusters {
                if !cluster.model.aggregation().supports_secure_sum() {
                    return Err(FedError::Privacy(format!(
                        "aggregation {:?} is incompatible with secure \
                         aggregation (only linear rules recover from sums)",
                        cluster.model.aggregation()
                    )));
                }
            }
        }
        if let Some(p) = &self.participation {
            p.validate()?;
            if self.privacy.mode.has_secagg() {
                if p.strategy == crate::config::SamplingStrategy::Poisson {
                    // a Poisson draw can produce a 1-client cohort, whose
                    // "masked" update would be the bare quantized vector
                    return Err(FedError::Privacy(
                        "secagg requires a fixed-size cohort (>= 2 for \
                         pairwise masks) — use the uniform strategy, not \
                         poisson"
                            .into(),
                    ));
                }
                if p.min_cohort < 2 {
                    // pairwise masking needs at least one peer per cohort
                    return Err(FedError::Privacy(
                        "secagg under participation sampling requires \
                         min_cohort >= 2 (pairwise masks need a peer)"
                            .into(),
                    ));
                }
            }
        }
        // resume bookkeeping is consumed by THIS learn() call: a second
        // call is a fresh session and must not skip its own rounds
        let completed = Arc::new(std::mem::take(&mut self.completed_rounds));
        let plans = Arc::new(std::mem::take(&mut self.resume_plans));
        let mut clustering_round = 0;
        loop {
            // Alg 4 line 2: "foreach cluster ... do in parallel".
            let clusters = std::mem::take(&mut self.container.clusters);
            let wm = Arc::clone(&self.wm);
            let hyper = self.hyper.clone();
            let server_opt = Arc::clone(&self.server_opt);
            let strategy = self.local_strategy;
            let timeout = self.round_timeout;
            let fl_stop = Arc::clone(&self.fl_stop);
            let pool_for_agg = Arc::clone(&self.pool);
            let privacy = self.privacy.clone();
            let participation = self.participation.clone();
            let known_samples = self.client_samples.clone();
            let metrics = self.metrics.clone();
            let latency = Arc::clone(&self.latency);
            let session_tag = self.session_tag;
            let store = Arc::clone(&self.store);
            let completed = Arc::clone(&completed);
            let plans = Arc::clone(&plans);
            let tele = Arc::clone(&self.tele);
            let outputs = self.pool.map(clusters, move |mut cluster| {
                let ctx = RoundCtx {
                    wm: &wm,
                    hyper: &hyper,
                    server_opt: &*server_opt,
                    strategy,
                    fl_stop: fl_stop.as_ref(),
                    timeout,
                    clustering_round,
                    pool: &pool_for_agg,
                    privacy: &privacy,
                    participation: &participation,
                    known_samples: &known_samples,
                    metrics: &metrics,
                    latency: &latency,
                    session_tag,
                    store: &store,
                    completed: &completed,
                    plans: &plans,
                    tele: &tele,
                };
                let out = train_cluster(&ctx, &mut cluster);
                (cluster, out)
            });
            let mut latest = BTreeMap::new();
            let mut restored = Vec::new();
            let hist_before = self.history.len();
            // Collect EVERY cluster's outcome before propagating a
            // failure: completed rounds — including the failing cluster's
            // own rounds before the error (their noised aggregates were
            // already applied) — must be recorded and charged to the ε
            // ledger below.
            let mut first_err: Option<FedError> = None;
            for (cluster, out) in outputs {
                self.history.extend(out.records);
                for (dev, params) in out.latest {
                    latest.insert(dev, params);
                }
                self.client_samples.extend(out.samples);
                if let Some(e) = out.err {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                restored.push(cluster);
            }
            self.container.clusters = restored;
            self.latest_updates.extend(latest);
            // close out each finished round's trace BEFORE the ε charges
            // below (whose durable append may fail on a dying store): a
            // `charge` span marking the accounting step (under dp), then
            // a dump to `trace.jsonl` next to the round-store WAL so the
            // trace survives a coordinator crash (replayed by recover())
            let trace_dir = self.store.trace_dir();
            for r in self.history.get(hist_before..).unwrap_or(&[]) {
                let rid = splitmix64(
                    self.session_tag
                        ^ ((r.clustering_round as u64) << 42)
                        ^ ((r.cluster_id as u64) << 21)
                        ^ r.round as u64,
                );
                if self.privacy.mode.has_dp() {
                    if let Some(root) = self.tele.root_of_round(rid) {
                        let mut span = crate::telemetry::Span::child_of(
                            &self.tele,
                            root,
                            phase::CHARGE,
                        );
                        span.set_attr("q", format!("{:.4}", r.sample_rate));
                        span.set_attr(
                            "noise_multiplier",
                            format!("{:.3}", self.privacy.noise_multiplier),
                        );
                        span.finish();
                    }
                }
                if let Some(dir) = &trace_dir {
                    if let Err(e) =
                        self.tele.dump_round(rid, &dir.join("trace.jsonl"))
                    {
                        log::warn!(target: "fact::server",
                            "trace dump for round {} failed: {e}",
                            round_id_to_hex(rid));
                    }
                }
            }
            if self.privacy.mode.has_dp() {
                // one accountant step per aggregation round a model ran.
                // Clusters train in parallel on DISJOINT clients, so a
                // client's (and each model's) privacy loss composes over
                // its own cluster's rounds — summing records across
                // clusters would over-count ε by the cluster count.  Per
                // round index, the *max* realized sampling rate across
                // clusters upper-bounds every cluster's subsampled cost
                // (RDP of the sampled Gaussian is monotone in q).
                let mut per_round: BTreeMap<usize, f64> = BTreeMap::new();
                for r in self.history.get(hist_before..).unwrap_or(&[]) {
                    let q = per_round.entry(r.round).or_insert(0.0);
                    if r.sample_rate > *q {
                        *q = r.sample_rate;
                    }
                }
                // charges deferred at recovery (a replayed closed round
                // whose index still had a resumed sibling) join the max
                let deferred: Vec<(usize, f64)> = self
                    .deferred_charges
                    .iter()
                    .filter(|((cr, _), _)| *cr == clustering_round)
                    .map(|((_, rd), q)| (*rd, *q))
                    .collect();
                for (rd, dq) in deferred {
                    self.deferred_charges.remove(&(clustering_round, rd));
                    let q = per_round.entry(rd).or_insert(0.0);
                    if dq > *q {
                        *q = dq;
                    }
                }
                for (round, q) in per_round {
                    let key = (clustering_round, round);
                    if self.already_charged.remove(&key) {
                        // charged in the store already (replayed session
                        // or recovery heal) — charging again would fork ε
                        continue;
                    }
                    // the charge hits the durable log BEFORE the ledger:
                    // a crash in between re-derives the accountant from
                    // the log, never the other way around
                    self.store.append_charge(LedgerCharge {
                        clustering_round,
                        round,
                        q,
                        noise_multiplier: self.privacy.noise_multiplier as f64,
                    })?;
                    self.accountant.add_round(q);
                }
            }
            if let Some(e) = first_err {
                // state and ledger are consistent; surface the failure
                return Err(e);
            }
            self.metrics.counter("fact.clustering_rounds").inc();

            clustering_round += 1;
            if self.cluster_stop.should_stop(clustering_round) {
                break;
            }
            // Alg 4 line 5: apply the clustering algorithm.
            let container = std::mem::take(&mut self.container);
            self.container = self
                .clustering
                .recluster(container, &self.latest_updates)?;
            log::info!(target: "fact::server",
                "clustering round {clustering_round}: now {} cluster(s)",
                self.container.clusters.len());
        }
        Ok(())
    }

    /// Evaluate every cluster's model on its clients' held-out data.
    pub fn evaluate(&self) -> Result<Vec<EvalRecord>> {
        let mut out = Vec::new();
        for cluster in &self.container.clusters {
            // one shared buffer for the whole cluster (see train_cluster)
            let global =
                crate::util::tensorbuf::TensorBuf::from_f32_slice(&cluster.params);
            let dict: BTreeMap<String, Json> = cluster
                .clients
                .iter()
                .map(|c| (c.clone(), cluster.model.eval_params_buf(&global)))
                .collect();
            let results = self.wm.run_task(dict, "fact_evaluate", self.round_timeout)?;
            let mut loss_sum = 0.0f64;
            let mut correct = 0.0f64;
            let mut ntok = 0.0f64;
            let mut n = 0.0f64;
            for r in &results {
                loss_sum += r.result.get("loss_sum").and_then(Json::as_f64).unwrap_or(0.0);
                correct += r.result.get("correct").and_then(Json::as_f64).unwrap_or(0.0);
                ntok += r.result.get("ntok").and_then(Json::as_f64).unwrap_or(0.0);
                n += r.result.get("n").and_then(Json::as_f64).unwrap_or(0.0);
            }
            let is_lm = ntok > 0.0;
            out.push(EvalRecord {
                cluster_id: cluster.id,
                loss: if is_lm { loss_sum / ntok.max(1.0) } else { loss_sum / n.max(1.0) },
                accuracy: if is_lm { f64::NAN } else { correct / n.max(1.0) },
                nll_per_token: if is_lm { loss_sum / ntok } else { f64::NAN },
                n_clients: results.len(),
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::dart::TaskRegistry;
    use crate::fact::aggregation::Aggregation;
    use crate::fact::client::FactClientRuntime;
    use crate::fact::data::{synthesize, Partition, SyntheticConfig};
    use crate::fact::model::LinearModel;
    use crate::fact::stopping::FixedRoundFl;
    use crate::runtime::{default_artifacts_dir, Engine};

    /// Full FACT loop over test mode with the pure-Rust linear model
    /// (runs even without artifacts) — federated loss must decrease.
    fn linear_fixture(
        clients: usize,
        partition: Partition,
    ) -> Option<(FactServer, Arc<dyn FactModel>)> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None; // engine construction requires the manifest
        }
        let engine = Engine::load(&dir, 1).unwrap();
        let registry = TaskRegistry::new();
        let rt = FactClientRuntime::new(engine);
        let data = synthesize(&SyntheticConfig {
            clients,
            samples_per_client: 256,
            dim: 8,
            classes: 4,
            partition,
            ..Default::default()
        })
        .unwrap();
        for (name, d) in data {
            rt.add_supervised(&name, d);
        }
        rt.register(&registry);
        let wm = WorkflowManager::test_mode(clients, registry, 2);
        let model = LinearModel::arc(8, 4, Aggregation::WeightedFedAvg);
        Some((FactServer::new(wm), model))
    }

    #[test]
    fn standard_fl_loss_decreases() {
        let Some((mut server, model)) = linear_fixture(4, Partition::Iid) else {
            return;
        };
        server.hyper = Hyper { lr: 0.3, mu: 0.0, local_steps: 6, round: 0 };
        server
            .initialization_by_model(model, Arc::new(FixedRoundFl(10)), 42)
            .unwrap();
        server.learn().unwrap();
        let hist = server.history();
        assert_eq!(hist.len(), 10);
        let first = hist.first().unwrap().mean_loss;
        let last = hist.last().unwrap().mean_loss;
        assert!(
            last < 0.7 * first,
            "federated loss did not decrease: {first} -> {last}"
        );
        assert!(hist.iter().all(|r| r.n_clients == 4));
        // evaluation works and accuracy is above chance (0.25)
        let evals = server.evaluate().unwrap();
        assert_eq!(evals.len(), 1);
        assert!(evals[0].accuracy > 0.3, "accuracy {}", evals[0].accuracy);
    }

    #[test]
    fn learn_requires_initialization() {
        let Some((mut server, _)) = linear_fixture(2, Partition::Iid) else {
            return;
        };
        assert!(server.learn().is_err());
    }

    #[test]
    fn latest_updates_are_tracked_per_client() {
        let Some((mut server, model)) = linear_fixture(3, Partition::Iid) else {
            return;
        };
        server
            .initialization_by_model(model, Arc::new(FixedRoundFl(2)), 1)
            .unwrap();
        server.learn().unwrap();
        assert_eq!(server.latest_updates().len(), 3);
        for v in server.latest_updates().values() {
            assert_eq!(v.len(), 8 * 4 + 4);
        }
    }

    #[test]
    fn clustered_fl_runs_multiple_clustering_rounds() {
        use crate::fact::clustering::KMeansClustering;
        let Some((mut server, model)) =
            linear_fixture(6, Partition::LatentGroups { groups: 2 })
        else {
            return;
        };
        server.hyper = Hyper { lr: 0.3, mu: 0.0, local_steps: 4, round: 0 };
        let clients = server.workflow_manager().get_all_device_names().unwrap();
        let params = model.init_params(0).unwrap();
        let container = ClusterContainer::single(model, params, clients);
        server
            .initialization_by_cluster_container(
                container,
                Box::new(KMeansClustering::new(2)),
                Box::new(FixedClusteringRounds(2)),
                Arc::new(FixedRoundFl(3)),
            )
            .unwrap();
        server.learn().unwrap();
        // after round 1 the container was re-clustered into 2 clusters
        assert_eq!(server.container().clusters.len(), 2);
        // history spans both clustering rounds
        assert!(server.history().iter().any(|r| r.clustering_round == 0));
        assert!(server.history().iter().any(|r| r.clustering_round == 1));
        let evals = server.evaluate().unwrap();
        assert_eq!(evals.len(), 2);
    }
}
