//! The FACT Server — the user's entry point (paper §2.2.1, Alg 3-5).
//!
//! "The entry point for the user is the Server class. Internally it stores
//! an instance of the Workflowmanager of Fed-DART to do the communication
//! with the clients and sending tasks to them. The Server has two main
//! methods, one for initializing the server and the clients and one to
//! launch the training."

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use crate::config::ParticipationConfig;
use crate::coordinator::latency::{effective_deadline_explained, LatencyTracker};
use crate::coordinator::participation::{
    participation_round_key, Candidate, CohortSampler,
};
use crate::coordinator::round_store::{
    now_ms, EventKind, LedgerCharge, MemRoundStore, RecoveryStatus, RoundEvent,
    RoundPhase, RoundState, RoundStore, StoredUpdate,
};
use crate::coordinator::workflow::{RoundClose, WorkflowManager};
use crate::error::{FedError, Result};
use crate::fact::aggregation::ClientUpdate;
use crate::fact::clustering::{ClusterContainer, ClusteringAlgorithm, StaticClustering};
use crate::fact::model::{FactModel, Hyper};
use crate::fact::stopping::{
    ClusteringStoppingCriterion, FixedClusteringRounds, FlStoppingCriterion,
};
use crate::json::Json;
use crate::metrics::Registry;
use crate::privacy::dp::DpAccountant;
use crate::privacy::secagg::{unmask_aggregate, MaskedUpdate, RevealedSeed};
use crate::privacy::{
    from_hex, keys, resolve_reveal_threshold, round_id_to_hex, seed_from_hex,
    shamir, PrivacyConfig, PrivacyMode, RevealPolicy,
};
use crate::telemetry::{self, phase};
use crate::util::pool::ThreadPool;
use crate::util::rng::splitmix64;
use crate::util::Stopwatch;

/// Audit record of one secure-aggregation round's recovery (surfaced in
/// [`RoundRecord`] and counted in `fact.secagg.*` metrics).
#[derive(Debug, Clone)]
pub struct SecAggAudit {
    /// masking participants (clients that completed key + share setup)
    pub participants: usize,
    /// resolved t of the t-of-n share recovery
    pub threshold: usize,
    pub dropped: Vec<String>,
    /// (survivor, dropped) pairs covered by direct seed reveals
    pub direct_reveals: usize,
    /// dropped clients whose secret was reconstructed from >= t shares
    pub reconstructed: Vec<String>,
    /// dropped clients left unrecoverable (below threshold)
    pub unrecovered: Vec<String>,
    pub policy: RevealPolicy,
    /// "ok" | "recovered" | "skipped" (proceed policy voided the round)
    pub outcome: &'static str,
}

impl SecAggAudit {
    /// Serialize for the round store (`Revealed` events, `RoundRecord`s).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("participants", self.participants)
            .set("threshold", self.threshold)
            .set(
                "dropped",
                Json::Arr(self.dropped.iter().cloned().map(Json::Str).collect()),
            )
            .set("direct_reveals", self.direct_reveals)
            .set(
                "reconstructed",
                Json::Arr(self.reconstructed.iter().cloned().map(Json::Str).collect()),
            )
            .set(
                "unrecovered",
                Json::Arr(self.unrecovered.iter().cloned().map(Json::Str).collect()),
            )
            .set("policy", self.policy.as_str())
            .set("outcome", self.outcome)
    }

    /// Parse the store form back.
    pub fn from_json(j: &Json) -> Result<SecAggAudit> {
        let strs = |key: &str| -> Vec<String> {
            j.get(key)
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default()
        };
        Ok(SecAggAudit {
            participants: j
                .get("participants")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            threshold: j.get("threshold").and_then(Json::as_usize).unwrap_or(0),
            dropped: strs("dropped"),
            direct_reveals: j
                .get("direct_reveals")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            reconstructed: strs("reconstructed"),
            unrecovered: strs("unrecovered"),
            policy: RevealPolicy::parse(
                j.get("policy").and_then(Json::as_str).unwrap_or("abort"),
            )?,
            // map back onto the audit's static vocabulary
            outcome: match j.get("outcome").and_then(Json::as_str) {
                Some("recovered") => "recovered",
                Some("skipped") => "skipped",
                Some("aborted") => "aborted",
                _ => "ok",
            },
        })
    }
}

/// Per-round record (feeds EXPERIMENTS.md and the benches).
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub clustering_round: usize,
    pub cluster_id: usize,
    pub round: usize,
    /// clients that contributed this round
    pub n_clients: usize,
    /// cohort size dispatched this round (== cluster size without
    /// participation sampling)
    pub sampled: usize,
    /// sampled clients whose results arrived after the round closed
    /// (observed in the late-grace sweep, then discarded)
    pub late: usize,
    /// sampled clients that never delivered a counted result
    pub dropped: usize,
    /// realized sampling rate the DP accountant may claim for this round
    /// (1.0 without participation sampling or for non-amplifying
    /// strategies)
    pub sample_rate: f64,
    /// mean local training loss across contributing clients
    pub mean_loss: f32,
    /// wall time of the whole round (dispatch -> aggregated) in ms
    pub round_ms: f64,
    /// aggregation-only time in ms
    pub agg_ms: f64,
    /// mean client-reported duration (paper taskResult.duration), seconds
    pub mean_client_s: f64,
    /// secure-aggregation recovery audit (None outside secagg modes)
    pub secagg: Option<SecAggAudit>,
}

impl RoundRecord {
    /// Serialize for the round store (`Aggregated`/`Voided` events) so
    /// the audit history survives a coordinator restart.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("clustering_round", self.clustering_round)
            .set("cluster_id", self.cluster_id)
            .set("round", self.round)
            .set("n_clients", self.n_clients)
            .set("sampled", self.sampled)
            .set("late", self.late)
            .set("dropped", self.dropped)
            .set("sample_rate", self.sample_rate)
            .set("mean_loss", self.mean_loss)
            .set("round_ms", self.round_ms)
            .set("agg_ms", self.agg_ms)
            .set("mean_client_s", self.mean_client_s);
        if let Some(a) = &self.secagg {
            o = o.set("secagg", a.to_json());
        }
        o
    }

    /// Parse the store form back.
    pub fn from_json(j: &Json) -> Result<RoundRecord> {
        let us = |key: &str| j.get(key).and_then(Json::as_usize).unwrap_or(0);
        let f = |key: &str| j.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        Ok(RoundRecord {
            clustering_round: us("clustering_round"),
            cluster_id: us("cluster_id"),
            round: us("round"),
            n_clients: us("n_clients"),
            sampled: us("sampled"),
            late: us("late"),
            dropped: us("dropped"),
            sample_rate: f("sample_rate"),
            mean_loss: f("mean_loss") as f32,
            round_ms: f("round_ms"),
            agg_ms: f("agg_ms"),
            mean_client_s: f("mean_client_s"),
            secagg: j.get("secagg").map(SecAggAudit::from_json).transpose()?,
        })
    }
}

/// What [`FactServer::recover`] found in the round store and what it did
/// about it.
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    /// What the store itself replayed on open (WAL/snapshot detail).
    pub status: RecoveryStatus,
    /// Closed/voided rounds restored into the audit history.
    pub replayed_records: usize,
    /// In-flight rounds queued for resumption by the next `learn()`.
    pub resumed: usize,
    /// Tainted in-flight rounds voided (reveal policy `proceed`).
    pub voided: usize,
    /// ε-ledger charges re-derived for closed-but-uncharged rounds.
    pub charges_restored: usize,
}

impl RecoveryReport {
    /// Serialize for the CLI / REST recovery surfaces.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("store", self.status.to_json())
            .set("replayed_records", self.replayed_records)
            .set("resumed", self.resumed)
            .set("voided", self.voided)
            .set("charges_restored", self.charges_restored)
    }
}

/// Evaluation summary for one cluster.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub cluster_id: usize,
    pub loss: f64,
    /// classification accuracy, or NaN for LM workloads
    pub accuracy: f64,
    /// per-token nll for LM workloads, or NaN
    pub nll_per_token: f64,
    pub n_clients: usize,
}

/// Server-side update rule applied to the aggregated target (FedAvgM,
/// Hsu et al. 2019 — the "new aggregation algorithms can be added easily"
/// extension point, paper §B.3).  `lr = 1, momentum = 0` is plain
/// parameter replacement (classic FedAvg) and takes a fast path that is
/// bit-identical to assignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerOpt {
    pub lr: f32,
    pub momentum: f32,
}

impl Default for ServerOpt {
    fn default() -> Self {
        ServerOpt { lr: 1.0, momentum: 0.0 }
    }
}

impl ServerOpt {
    /// params <- params + lr * buf, where buf <- momentum*buf + (target - params).
    pub fn apply(&self, params: &mut Vec<f32>, target: Vec<f32>, buf: &mut Vec<f32>) {
        if self.lr == 1.0 && self.momentum == 0.0 {
            *params = target; // exact FedAvg replacement
            return;
        }
        if buf.len() != params.len() {
            *buf = vec![0.0; params.len()];
        }
        for ((p, t), b) in params.iter_mut().zip(target).zip(buf.iter_mut()) {
            *b = self.momentum * *b + (t - *p);
            *p += self.lr * *b;
        }
    }
}

/// The FACT Server.
pub struct FactServer {
    wm: Arc<WorkflowManager>,
    container: ClusterContainer,
    clustering: Box<dyn ClusteringAlgorithm>,
    cluster_stop: Box<dyn ClusteringStoppingCriterion>,
    fl_stop: Arc<dyn FlStoppingCriterion>,
    pub hyper: Hyper,
    pub server_opt: ServerOpt,
    pub round_timeout: Duration,
    /// Negotiated privacy mode + parameters for every training round.
    pub privacy: PrivacyConfig,
    /// Partial-participation rounds: cohort sampling + quorum/deadline.
    /// `None` = the legacy loop (address everyone, wait for all).
    participation: Option<ParticipationConfig>,
    /// Last-known per-client sample counts (feeds weighted sampling).
    client_samples: BTreeMap<String, f64>,
    /// (ε, δ) ledger for DP-enabled sessions; persisted with snapshots.
    accountant: DpAccountant,
    /// Per-process tag mixed into round ids so pair seeds never repeat
    /// across server restarts (mask reuse across rounds would leak the
    /// difference of two updates).
    session_tag: u64,
    pool: Arc<ThreadPool>,
    metrics: Registry,
    /// Per-client learn-latency history feeding adaptive round deadlines
    /// (shared across cluster worker threads; lives for the session).
    latency: Arc<LatencyTracker>,
    history: Vec<RoundRecord>,
    /// latest local update per client (clustering input)
    latest_updates: BTreeMap<String, Vec<f32>>,
    initialized: bool,
    /// The round state machine's home: every round's lifecycle is
    /// appended here (in-memory by default, WAL-backed via
    /// [`FactServer::with_round_store`]).
    store: Arc<dyn RoundStore>,
    /// In-flight rounds loaded by [`FactServer::recover`], keyed by
    /// `(clustering_round, cluster_id, round)`; consumed by the next
    /// `learn()` call, which resumes them instead of starting fresh.
    resume_plans: BTreeMap<(usize, usize, usize), RoundState>,
    /// Rounds the store already closed (replayed by `recover()`); the
    /// next `learn()` skips them outright.
    completed_rounds: BTreeSet<(usize, usize, usize)>,
    /// ε-ledger charges already in the store — `learn()` must not charge
    /// these round indices again.
    already_charged: BTreeSet<(usize, usize)>,
    /// Replayed charges whose round index still has an in-flight sibling
    /// round: deferred so `learn()` can charge the max realized rate
    /// across replayed + resumed clusters, exactly like an uninterrupted
    /// run.
    deferred_charges: BTreeMap<(usize, usize), f64>,
    /// Flight recorder round traces are written to: the process-global
    /// recorder by default, a private one via
    /// [`FactServer::with_telemetry`] (tests simulate a restart by
    /// recovering into a fresh recorder).
    tele: Arc<crate::telemetry::Recorder>,
}

impl FactServer {
    /// Construct around a WorkflowManager (test-mode or production).
    pub fn new(wm: WorkflowManager) -> FactServer {
        FactServer {
            wm: Arc::new(wm),
            container: ClusterContainer::default(),
            clustering: Box::new(StaticClustering),
            cluster_stop: Box::new(FixedClusteringRounds(1)),
            fl_stop: Arc::new(crate::fact::stopping::FixedRoundFl(10)),
            hyper: Hyper::default(),
            server_opt: ServerOpt::default(),
            round_timeout: Duration::from_secs(300),
            privacy: PrivacyConfig::default(),
            participation: None,
            client_samples: BTreeMap::new(),
            accountant: DpAccountant::new(1.0),
            session_tag: splitmix64(
                std::process::id() as u64
                    ^ std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_nanos() as u64)
                        .unwrap_or(0),
            ),
            pool: Arc::new(ThreadPool::default_size()),
            metrics: Registry::new(),
            latency: Arc::new(LatencyTracker::default()),
            history: Vec::new(),
            latest_updates: BTreeMap::new(),
            initialized: false,
            store: Arc::new(MemRoundStore::new()),
            resume_plans: BTreeMap::new(),
            completed_rounds: BTreeSet::new(),
            already_charged: BTreeSet::new(),
            deferred_charges: BTreeMap::new(),
            tele: Arc::clone(crate::telemetry::global()),
        }
    }

    /// Record round traces into an explicit flight recorder instead of
    /// the process-global one.
    pub fn with_telemetry(mut self, rec: Arc<crate::telemetry::Recorder>) -> FactServer {
        self.tele = rec;
        self
    }

    /// The flight recorder round traces land in.
    pub fn telemetry(&self) -> &Arc<crate::telemetry::Recorder> {
        &self.tele
    }

    pub fn with_hyper(mut self, hyper: Hyper) -> FactServer {
        self.hyper = hyper;
        self
    }

    /// Enable a privacy mode for every subsequent training round.  The
    /// accountant restarts with the configured noise multiplier.
    pub fn with_privacy(mut self, cfg: PrivacyConfig) -> FactServer {
        self.accountant = DpAccountant::new(cfg.noise_multiplier as f64);
        self.privacy = cfg;
        self
    }

    /// The DP ledger accumulated so far (all zeros for non-DP modes).
    pub fn accountant(&self) -> &DpAccountant {
        &self.accountant
    }

    /// Put all round state behind a specific [`RoundStore`] backend
    /// (e.g. [`crate::coordinator::round_store::WalRoundStore`] for a
    /// durable, crash-recoverable coordinator).  Pair with
    /// [`FactServer::recover`] after initialization to resume whatever
    /// the store holds.
    pub fn with_round_store(mut self, store: Arc<dyn RoundStore>) -> FactServer {
        self.store = store;
        self
    }

    /// Pin the per-process session tag (tests: reproducible round ids).
    /// A tag already persisted in the round store still wins at
    /// [`FactServer::recover`] time.
    pub fn with_session_tag(mut self, tag: u64) -> FactServer {
        self.session_tag = tag;
        self
    }

    /// The round store every round's lifecycle is appended to.
    pub fn round_store(&self) -> &Arc<dyn RoundStore> {
        &self.store
    }

    /// The tag mixed into every derived round id this session.
    pub fn session_tag(&self) -> u64 {
        self.session_tag
    }

    /// Report into an external metrics [`Registry`] (e.g. the one a
    /// co-located DART REST server snapshots for `/metrics` and
    /// `/rounds/recovery`) instead of a private one.
    pub fn with_metrics(mut self, metrics: Registry) -> FactServer {
        self.metrics = metrics;
        self
    }

    /// The learn-latency tracker behind adaptive deadlines (warm it up
    /// in tests, or inspect the observed quantiles).
    pub fn latency_tracker(&self) -> &Arc<LatencyTracker> {
        &self.latency
    }

    /// Replay the round store and prepare to resume: adopt the stored
    /// session tag (so fresh rounds derive the ids the dead coordinator
    /// would have), rebuild the ε ledger from persisted charges, restore
    /// the audit history and fast-forward cluster params over closed
    /// rounds, heal closed-but-uncharged rounds (the snapshot/WAL fork),
    /// and queue in-flight rounds for the next [`FactServer::learn`].
    ///
    /// Tainted rounds (a truncated/corrupt WAL tail touched them) are
    /// never resumed: `RevealPolicy::Abort` fails recovery,
    /// `RevealPolicy::Proceed` voids them and continues.
    ///
    /// Call after `initialization_by_*` (clusters must exist to
    /// fast-forward) and after `with_privacy`.
    pub fn recover(&mut self) -> Result<RecoveryReport> {
        if !self.initialized {
            return Err(FedError::Fact(
                "recover() requires an initialized server".into(),
            ));
        }
        self.session_tag = self.store.set_session_tag(self.session_tag)?;
        let status = self.store.recovery();

        // 0) replay the durable flight-recorder dump (trace.jsonl lives
        //    next to the WAL): closed rounds' traces survive the crash,
        //    so `GET /trace/{round_id}` keeps answering after a restart.
        //    Span-id dedup makes the replay idempotent.
        if let Some(dir) = self.store.trace_dir() {
            match self.tele.load_jsonl(&dir.join("trace.jsonl")) {
                Ok(n) if n > 0 => log::info!(target: "fact::server",
                    "recover: replayed {n} trace records from trace.jsonl"),
                Ok(_) => {}
                Err(e) => log::warn!(target: "fact::server",
                    "recover: trace.jsonl replay failed: {e}"),
            }
        }

        // 1) the ε ledger: the store's charge log is the source of truth.
        //    A stale Snapshot accountant can never fork history — the
        //    never-backwards rule (mirroring restore_latest) keeps
        //    whichever ledger has accounted more rounds.
        let charges = self.store.charges()?;
        if self.privacy.mode.has_dp() && !charges.is_empty() {
            let mut acct =
                DpAccountant::new(self.privacy.noise_multiplier as f64);
            for c in &charges {
                acct.add_round(c.q);
            }
            if acct.steps > self.accountant.steps {
                self.accountant = acct;
            }
        }
        self.already_charged = charges.iter().map(LedgerCharge::key).collect();

        let rounds = self.store.rounds()?;
        // 2) terminal rounds: restore records + loss history in execution
        //    order, fast-forward params over closed rounds
        let mut terminal: Vec<&RoundState> =
            rounds.iter().filter(|r| r.phase.is_terminal()).collect();
        terminal.sort_by_key(|r| (r.clustering_round, r.cluster_id, r.round));
        let mut replayed_records = 0usize;
        let mut uncharged: BTreeMap<(usize, usize), f64> = BTreeMap::new();
        for r in &terminal {
            self.completed_rounds
                .insert((r.clustering_round, r.cluster_id, r.round));
            let rec = match &r.record {
                Some(rj) => match RoundRecord::from_json(rj) {
                    Ok(rec) => rec,
                    Err(_) => continue,
                },
                None => continue, // e.g. voided before any update arrived
            };
            if let Some(cluster) = self
                .container
                .clusters
                .iter_mut()
                .find(|c| c.id == r.cluster_id)
            {
                cluster.loss_history.push(rec.mean_loss);
                if r.phase == RoundPhase::Closed {
                    if let Some(pa) = &r.params_after {
                        if pa.len() == cluster.params.len() {
                            cluster.params = pa.to_vec();
                        }
                    }
                }
            }
            if r.phase == RoundPhase::Closed || r.void_reason.is_some() {
                let key = (r.clustering_round, r.round);
                if self.privacy.mode.has_dp() && !self.already_charged.contains(&key)
                {
                    let e = uncharged.entry(key).or_insert(0.0);
                    if rec.sample_rate > *e {
                        *e = rec.sample_rate;
                    }
                }
            }
            self.history.push(rec);
            replayed_records += 1;
        }

        // 3) in-flight rounds: taint -> policy; otherwise queue a resume
        //    plan for learn()
        let mut resumed = 0usize;
        let mut voided = 0usize;
        let mut pending_keys: BTreeSet<(usize, usize)> = BTreeSet::new();
        for r in rounds.iter().filter(|r| !r.phase.is_terminal()) {
            if r.tainted {
                match self.privacy.reveal_policy {
                    RevealPolicy::Abort => {
                        return Err(FedError::Privacy(format!(
                            "round store has a tainted in-flight round \
                             (cluster {} round {}: corrupt WAL tail) — \
                             reveal policy abort refuses to resume",
                            r.cluster_id, r.round
                        )));
                    }
                    RevealPolicy::Proceed => {
                        self.store.append(RoundEvent::new(
                            r.round_id,
                            EventKind::Voided {
                                reason: "corrupt WAL tail truncated mid-round"
                                    .into(),
                                record: Json::Null,
                            },
                        ))?;
                        self.metrics.counter("fact.roundstore.voided").inc();
                        // the round index is burned, not re-runnable: its
                        // id is now terminal in the store
                        self.completed_rounds.insert((
                            r.clustering_round,
                            r.cluster_id,
                            r.round,
                        ));
                        voided += 1;
                        continue;
                    }
                }
            }
            pending_keys.insert((r.clustering_round, r.round));
            self.resume_plans
                .insert((r.clustering_round, r.cluster_id, r.round), r.clone());
            resumed += 1;
        }

        // 4) heal the ledger fork: closed rounds whose charge never made
        //    it to disk.  Round indices with an in-flight sibling are
        //    deferred so learn() charges the max realized rate across
        //    replayed AND resumed clusters (what an uninterrupted run
        //    would have charged).
        let mut charges_restored = 0usize;
        for (key, q) in uncharged {
            if pending_keys.contains(&key) {
                self.deferred_charges.insert(key, q);
                continue;
            }
            self.store.append_charge(LedgerCharge {
                clustering_round: key.0,
                round: key.1,
                q,
                noise_multiplier: self.privacy.noise_multiplier as f64,
            })?;
            self.accountant.add_round(q);
            self.already_charged.insert(key);
            charges_restored += 1;
        }

        self.metrics
            .counter("fact.roundstore.replayed")
            .add(replayed_records as u64);
        self.metrics
            .counter("fact.roundstore.resumed")
            .add(resumed as u64);
        if status.events_replayed > 0 || resumed > 0 || voided > 0 {
            log::info!(target: "fact::server",
                "recover: {} event(s) replayed, {} record(s) restored, \
                 {} round(s) to resume, {} voided, {} charge(s) healed",
                status.events_replayed, replayed_records, resumed, voided,
                charges_restored);
        }
        Ok(RecoveryReport {
            status,
            replayed_records,
            resumed,
            voided,
            charges_restored,
        })
    }

    /// Enable partial-participation rounds: every training round samples
    /// a cohort, over-provisions it, and closes at quorum or deadline
    /// instead of waiting for every client.  Validated at `learn()`.
    pub fn with_participation(mut self, cfg: ParticipationConfig) -> FactServer {
        self.participation = Some(cfg);
        self
    }

    /// The active participation config, if partial rounds are enabled.
    pub fn participation(&self) -> Option<&ParticipationConfig> {
        self.participation.as_ref()
    }

    pub fn with_fl_stop(mut self, s: Arc<dyn FlStoppingCriterion>) -> FactServer {
        self.fl_stop = s;
        self
    }

    pub fn workflow_manager(&self) -> &WorkflowManager {
        &self.wm
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    pub fn history(&self) -> &[RoundRecord] {
        &self.history
    }

    pub fn container(&self) -> &ClusterContainer {
        &self.container
    }

    /// Latest per-client local updates (clustering / diagnostics).
    pub fn latest_updates(&self) -> &BTreeMap<String, Vec<f32>> {
        &self.latest_updates
    }

    /// Persist every cluster's current global parameters to an object
    /// store (the paper's MinIO/S3 role, §4.2).  Key layout:
    /// `models/<model>-c<cluster>/round-<n>.json`.
    pub fn checkpoint<S: crate::fact::store::ObjectStore>(
        &self,
        store: &crate::fact::store::ModelStore<S>,
        round: u64,
    ) -> Result<()> {
        // the accountant rides with every snapshot of a privacy-enabled
        // session so a restore resumes the ε ledger
        let privacy = if self.privacy.mode == PrivacyMode::Off {
            Json::Null
        } else {
            Json::obj()
                .set("mode", self.privacy.mode.as_str())
                .set("accountant", self.accountant.to_json())
                .set(
                    "epsilon",
                    self.accountant.epsilon(self.privacy.delta),
                )
                .set("delta", self.privacy.delta)
        };
        for cluster in &self.container.clusters {
            let meta = Json::obj()
                .set("cluster_id", cluster.id)
                .set("clients", cluster.clients.len())
                .set(
                    "last_loss",
                    cluster.loss_history.last().copied().unwrap_or(f32::NAN),
                );
            store.save(&crate::fact::store::Snapshot {
                model: format!("{}-c{}", cluster.model.name(), cluster.id),
                params: crate::util::tensorbuf::TensorBuf::from_f32_slice(
                    &cluster.params,
                ),
                round,
                meta,
                privacy: privacy.clone(),
            })?;
        }
        Ok(())
    }

    /// Restore a cluster's parameters from the latest snapshot, if one
    /// exists.  Returns whether a snapshot was applied.
    pub fn restore_latest<S: crate::fact::store::ObjectStore>(
        &mut self,
        store: &crate::fact::store::ModelStore<S>,
        cluster_idx: usize,
    ) -> Result<bool> {
        let cluster = self
            .container
            .clusters
            .get_mut(cluster_idx)
            .ok_or_else(|| FedError::Fact(format!("no cluster {cluster_idx}")))?;
        let key = format!("{}-c{}", cluster.model.name(), cluster.id);
        match store.load_latest(&key)? {
            Some(snap) if snap.params.len() == cluster.params.len() => {
                cluster.params = snap.params.to_vec();
                // resume the DP ledger recorded with the snapshot (never
                // backwards — a fresher in-memory ledger wins)
                if let Some(aj) = snap.privacy.get("accountant") {
                    if let Ok(acct) = DpAccountant::from_json(aj) {
                        if acct.steps > self.accountant.steps {
                            self.accountant = acct;
                        }
                    }
                }
                Ok(true)
            }
            Some(_) => Err(FedError::Fact("snapshot size mismatch".into())),
            None => Ok(false),
        }
    }

    // ----------------------------------------------------------- Alg 3

    /// `initialization_by_model`: standard FL — one cluster with every
    /// connected client, static clustering, one clustering round.
    pub fn initialization_by_model(
        &mut self,
        model: Arc<dyn FactModel>,
        fl_stop: Arc<dyn FlStoppingCriterion>,
        seed: i32,
    ) -> Result<()> {
        let clients = self.wm.get_all_device_names()?;
        if clients.is_empty() {
            return Err(FedError::Fact("no clients connected".into()));
        }
        let params = model.init_params(seed)?;
        let container = ClusterContainer::single(model, params, clients);
        self.initialization_by_cluster_container(
            container,
            Box::new(StaticClustering),
            Box::new(FixedClusteringRounds(1)),
            fl_stop,
        )
    }

    /// `initialization_by_cluster_container`: personalized FL with explicit
    /// clusters, clustering algorithm, and stopping criteria.
    pub fn initialization_by_cluster_container(
        &mut self,
        container: ClusterContainer,
        clustering: Box<dyn ClusteringAlgorithm>,
        cluster_stop: Box<dyn ClusteringStoppingCriterion>,
        fl_stop: Arc<dyn FlStoppingCriterion>,
    ) -> Result<()> {
        if container.clusters.is_empty() {
            return Err(FedError::Fact("empty cluster container".into()));
        }
        // Alg 3: register the init task and run it on every cluster's
        // clients ("Initialize the local models on the clients ... based on
        // the global model in the cluster").
        let model0 = Arc::clone(&container.clusters[0].model);
        self.wm.create_init_task(model0.init_task_params(), "fact_init");
        for cluster in &container.clusters {
            self.wm
                .selector()
                .ensure_initialized(&cluster.clients.to_vec())?;
        }
        self.container = container;
        self.clustering = clustering;
        self.cluster_stop = cluster_stop;
        self.fl_stop = fl_stop;
        self.initialized = true;
        log::info!(target: "fact::server",
            "initialized: {} cluster(s), {} client(s)",
            self.container.clusters.len(),
            self.container.client_count());
        Ok(())
    }

    // ----------------------------------------------------------- Alg 4/5

    /// The learning method (Alg 4): clustering rounds over parallel
    /// per-cluster training sessions.
    pub fn learn(&mut self) -> Result<()> {
        if !self.initialized {
            return Err(FedError::Fact("server not initialized".into()));
        }
        if self.privacy.mode.has_secagg() {
            // masked aggregation only recovers sums — order-statistic
            // rules (median / trimmed mean) cannot run under it, and the
            // per-client updates clustering would need stay hidden
            for cluster in &self.container.clusters {
                if !cluster.model.aggregation().supports_secure_sum() {
                    return Err(FedError::Privacy(format!(
                        "aggregation {:?} is incompatible with secure \
                         aggregation (only linear rules recover from sums)",
                        cluster.model.aggregation()
                    )));
                }
            }
        }
        if let Some(p) = &self.participation {
            p.validate()?;
            if self.privacy.mode.has_secagg() {
                if p.strategy == crate::config::SamplingStrategy::Poisson {
                    // a Poisson draw can produce a 1-client cohort, whose
                    // "masked" update would be the bare quantized vector
                    return Err(FedError::Privacy(
                        "secagg requires a fixed-size cohort (>= 2 for \
                         pairwise masks) — use the uniform strategy, not \
                         poisson"
                            .into(),
                    ));
                }
                if p.min_cohort < 2 {
                    // pairwise masking needs at least one peer per cohort
                    return Err(FedError::Privacy(
                        "secagg under participation sampling requires \
                         min_cohort >= 2 (pairwise masks need a peer)"
                            .into(),
                    ));
                }
            }
        }
        // resume bookkeeping is consumed by THIS learn() call: a second
        // call is a fresh session and must not skip its own rounds
        let completed = Arc::new(std::mem::take(&mut self.completed_rounds));
        let plans = Arc::new(std::mem::take(&mut self.resume_plans));
        let mut clustering_round = 0;
        loop {
            // Alg 4 line 2: "foreach cluster ... do in parallel".
            let clusters = std::mem::take(&mut self.container.clusters);
            let wm = Arc::clone(&self.wm);
            let hyper = self.hyper.clone();
            let server_opt = self.server_opt;
            let timeout = self.round_timeout;
            let fl_stop = Arc::clone(&self.fl_stop);
            let pool_for_agg = Arc::clone(&self.pool);
            let privacy = self.privacy.clone();
            let participation = self.participation.clone();
            let known_samples = self.client_samples.clone();
            let metrics = self.metrics.clone();
            let latency = Arc::clone(&self.latency);
            let session_tag = self.session_tag;
            let store = Arc::clone(&self.store);
            let completed = Arc::clone(&completed);
            let plans = Arc::clone(&plans);
            let tele = Arc::clone(&self.tele);
            let outputs = self.pool.map(clusters, move |mut cluster| {
                let ctx = RoundCtx {
                    wm: &wm,
                    hyper: &hyper,
                    server_opt,
                    fl_stop: fl_stop.as_ref(),
                    timeout,
                    clustering_round,
                    pool: &pool_for_agg,
                    privacy: &privacy,
                    participation: &participation,
                    known_samples: &known_samples,
                    metrics: &metrics,
                    latency: &latency,
                    session_tag,
                    store: &store,
                    completed: &completed,
                    plans: &plans,
                    tele: &tele,
                };
                let out = train_cluster(&ctx, &mut cluster);
                (cluster, out)
            });
            let mut latest = BTreeMap::new();
            let mut restored = Vec::new();
            let hist_before = self.history.len();
            // Collect EVERY cluster's outcome before propagating a
            // failure: completed rounds — including the failing cluster's
            // own rounds before the error (their noised aggregates were
            // already applied) — must be recorded and charged to the ε
            // ledger below.
            let mut first_err: Option<FedError> = None;
            for (cluster, out) in outputs {
                self.history.extend(out.records);
                for (dev, params) in out.latest {
                    latest.insert(dev, params);
                }
                self.client_samples.extend(out.samples);
                if let Some(e) = out.err {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                restored.push(cluster);
            }
            self.container.clusters = restored;
            self.latest_updates.extend(latest);
            // close out each finished round's trace BEFORE the ε charges
            // below (whose durable append may fail on a dying store): a
            // `charge` span marking the accounting step (under dp), then
            // a dump to `trace.jsonl` next to the round-store WAL so the
            // trace survives a coordinator crash (replayed by recover())
            let trace_dir = self.store.trace_dir();
            for r in self.history.get(hist_before..).unwrap_or(&[]) {
                let rid = splitmix64(
                    self.session_tag
                        ^ ((r.clustering_round as u64) << 42)
                        ^ ((r.cluster_id as u64) << 21)
                        ^ r.round as u64,
                );
                if self.privacy.mode.has_dp() {
                    if let Some(root) = self.tele.root_of_round(rid) {
                        let mut span = crate::telemetry::Span::child_of(
                            &self.tele,
                            root,
                            phase::CHARGE,
                        );
                        span.set_attr("q", format!("{:.4}", r.sample_rate));
                        span.set_attr(
                            "noise_multiplier",
                            format!("{:.3}", self.privacy.noise_multiplier),
                        );
                        span.finish();
                    }
                }
                if let Some(dir) = &trace_dir {
                    if let Err(e) =
                        self.tele.dump_round(rid, &dir.join("trace.jsonl"))
                    {
                        log::warn!(target: "fact::server",
                            "trace dump for round {} failed: {e}",
                            round_id_to_hex(rid));
                    }
                }
            }
            if self.privacy.mode.has_dp() {
                // one accountant step per aggregation round a model ran.
                // Clusters train in parallel on DISJOINT clients, so a
                // client's (and each model's) privacy loss composes over
                // its own cluster's rounds — summing records across
                // clusters would over-count ε by the cluster count.  Per
                // round index, the *max* realized sampling rate across
                // clusters upper-bounds every cluster's subsampled cost
                // (RDP of the sampled Gaussian is monotone in q).
                let mut per_round: BTreeMap<usize, f64> = BTreeMap::new();
                for r in self.history.get(hist_before..).unwrap_or(&[]) {
                    let q = per_round.entry(r.round).or_insert(0.0);
                    if r.sample_rate > *q {
                        *q = r.sample_rate;
                    }
                }
                // charges deferred at recovery (a replayed closed round
                // whose index still had a resumed sibling) join the max
                let deferred: Vec<(usize, f64)> = self
                    .deferred_charges
                    .iter()
                    .filter(|((cr, _), _)| *cr == clustering_round)
                    .map(|((_, rd), q)| (*rd, *q))
                    .collect();
                for (rd, dq) in deferred {
                    self.deferred_charges.remove(&(clustering_round, rd));
                    let q = per_round.entry(rd).or_insert(0.0);
                    if dq > *q {
                        *q = dq;
                    }
                }
                for (round, q) in per_round {
                    let key = (clustering_round, round);
                    if self.already_charged.remove(&key) {
                        // charged in the store already (replayed session
                        // or recovery heal) — charging again would fork ε
                        continue;
                    }
                    // the charge hits the durable log BEFORE the ledger:
                    // a crash in between re-derives the accountant from
                    // the log, never the other way around
                    self.store.append_charge(LedgerCharge {
                        clustering_round,
                        round,
                        q,
                        noise_multiplier: self.privacy.noise_multiplier as f64,
                    })?;
                    self.accountant.add_round(q);
                }
            }
            if let Some(e) = first_err {
                // state and ledger are consistent; surface the failure
                return Err(e);
            }
            self.metrics.counter("fact.clustering_rounds").inc();

            clustering_round += 1;
            if self.cluster_stop.should_stop(clustering_round) {
                break;
            }
            // Alg 4 line 5: apply the clustering algorithm.
            let container = std::mem::take(&mut self.container);
            self.container = self
                .clustering
                .recluster(container, &self.latest_updates)?;
            log::info!(target: "fact::server",
                "clustering round {clustering_round}: now {} cluster(s)",
                self.container.clusters.len());
        }
        Ok(())
    }

    /// Evaluate every cluster's model on its clients' held-out data.
    pub fn evaluate(&self) -> Result<Vec<EvalRecord>> {
        let mut out = Vec::new();
        for cluster in &self.container.clusters {
            // one shared buffer for the whole cluster (see train_cluster)
            let global =
                crate::util::tensorbuf::TensorBuf::from_f32_slice(&cluster.params);
            let dict: BTreeMap<String, Json> = cluster
                .clients
                .iter()
                .map(|c| (c.clone(), cluster.model.eval_params_buf(&global)))
                .collect();
            let results = self.wm.run_task(dict, "fact_evaluate", self.round_timeout)?;
            let mut loss_sum = 0.0f64;
            let mut correct = 0.0f64;
            let mut ntok = 0.0f64;
            let mut n = 0.0f64;
            for r in &results {
                loss_sum += r.result.get("loss_sum").and_then(Json::as_f64).unwrap_or(0.0);
                correct += r.result.get("correct").and_then(Json::as_f64).unwrap_or(0.0);
                ntok += r.result.get("ntok").and_then(Json::as_f64).unwrap_or(0.0);
                n += r.result.get("n").and_then(Json::as_f64).unwrap_or(0.0);
            }
            let is_lm = ntok > 0.0;
            out.push(EvalRecord {
                cluster_id: cluster.id,
                loss: if is_lm { loss_sum / ntok.max(1.0) } else { loss_sum / n.max(1.0) },
                accuracy: if is_lm { f64::NAN } else { correct / n.max(1.0) },
                nll_per_token: if is_lm { loss_sum / ntok } else { f64::NAN },
                n_clients: results.len(),
            });
        }
        Ok(out)
    }
}

/// Outcome of one cluster's training session: everything that completed
/// plus the first error.  Completed rounds ride OUTSIDE the error so a
/// failure in round k never discards rounds 0..k — those aggregates were
/// already applied to the cluster and must still be charged to the DP
/// ledger.
struct ClusterOutcome {
    records: Vec<RoundRecord>,
    latest: BTreeMap<String, Vec<f32>>,
    samples: BTreeMap<String, f64>,
    err: Option<FedError>,
}

/// The per-session invariants every cluster's round loop reads — one
/// bundle instead of a dozen parameters threaded through two signatures
/// and the dispatch closure (future round-loop features extend this
/// struct, not every call site).
struct RoundCtx<'a> {
    wm: &'a WorkflowManager,
    hyper: &'a Hyper,
    server_opt: ServerOpt,
    fl_stop: &'a dyn FlStoppingCriterion,
    timeout: Duration,
    clustering_round: usize,
    pool: &'a ThreadPool,
    privacy: &'a PrivacyConfig,
    participation: &'a Option<ParticipationConfig>,
    known_samples: &'a BTreeMap<String, f64>,
    metrics: &'a Registry,
    /// observed learn latencies feeding [`effective_deadline_explained`]
    latency: &'a LatencyTracker,
    session_tag: u64,
    /// every round transition is appended (and validated) here
    store: &'a Arc<dyn RoundStore>,
    /// rounds the store already closed — skipped outright
    completed: &'a BTreeSet<(usize, usize, usize)>,
    /// in-flight rounds to resume instead of starting fresh
    plans: &'a BTreeMap<(usize, usize, usize), RoundState>,
    /// flight recorder the round's spans and events land in
    tele: &'a Arc<telemetry::Recorder>,
}

impl RoundCtx<'_> {
    /// Record one finished phase's wall time into the labeled histogram
    /// behind `fact.round.phase_ms{phase,cluster}` (surfaced by
    /// `/rounds/recovery` and the Prometheus exposition).
    fn phase_ms(&self, name: &str, cluster_id: usize, ms: f64) {
        self.metrics
            .histogram_labeled(
                "fact.round.phase_ms",
                &[("phase", name), ("cluster", &cluster_id.to_string())],
            )
            .observe(ms);
    }
}

/// Alg 5: the training session of one cluster.
fn train_cluster(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
) -> ClusterOutcome {
    let mut records = Vec::new();
    let mut latest = BTreeMap::new();
    let mut samples = BTreeMap::new();
    let err =
        train_cluster_rounds(ctx, cluster, &mut records, &mut latest, &mut samples)
            .err();
    ClusterOutcome { records, latest, samples, err }
}

/// The round loop behind [`train_cluster`]: per round index, skip what
/// the store already closed, resume what it holds in flight, and run
/// everything else fresh.  Completed rounds accumulate into the
/// out-params so they survive an error return.
fn train_cluster_rounds(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    let mut round = 0usize;
    loop {
        let key = (ctx.clustering_round, cluster.id, round);
        if ctx.completed.contains(&key) {
            // replayed by recover(): params + loss history were already
            // fast-forwarded and the record is back in the history
        } else if let Some(plan) = ctx.plans.get(&key) {
            resume_round(ctx, cluster, round, plan, records, latest, seen_samples)?;
        } else {
            fresh_round(ctx, cluster, round, records, latest, seen_samples)?;
        }
        round += 1;
        // Alg 5 line 7: stopping criterion.
        if ctx.fl_stop.should_stop(round, &cluster.loss_history) {
            break;
        }
    }
    Ok(())
}

/// Draw this round's cohort (everyone, without participation sampling).
fn draw_cohort(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    round: usize,
    seen_samples: &BTreeMap<String, f64>,
) -> (Vec<String>, f64, Option<CohortSampler>) {
    match ctx.participation {
        Some(p) => {
            let sampler = CohortSampler::new(p.clone());
            let key = participation_round_key(
                p.seed,
                ctx.clustering_round,
                cluster.id,
                round,
            );
            let candidates: Vec<Candidate> = cluster
                .clients
                .iter()
                .map(|n| Candidate {
                    name: n.clone(),
                    weight: seen_samples
                        .get(n)
                        .or_else(|| ctx.known_samples.get(n))
                        .copied()
                        .unwrap_or(1.0)
                        .max(1.0),
                })
                .collect();
            let cohort = sampler.sample(key, &candidates);
            let q = sampler.amplification_rate(cohort.len(), cluster.clients.len());
            (cohort, q, Some(sampler))
        }
        None => (cluster.clients.clone(), 1.0, None),
    }
}

/// Salt mixed into the round key for the repair draw, so a repaired
/// round's replacement order never correlates with its cohort draw.
const REPAIR_SALT: u64 = 0x5e1f_4ea1_1e55_0007;

/// In-round cohort repair: replace cohort members the scheduler already
/// knows are dead (lease expired / never connected) with fresh draws
/// from the cluster's unsampled pool — inside the same round, before any
/// setup phase addressed the dead.
///
/// The deterministic replacement draw is keyed off the round key + a
/// salt, so a resumed coordinator repairs identically.  Presumed-dead
/// members are dropped from the addressed cohort (both the selector and
/// the scheduler reject tasks addressing a disconnected client — a dead
/// member kept addressed would reject the whole learn task) and
/// replacements take their slots; a presumed-dead client that revives
/// mid-round re-registers and is eligible for the next draw.  The
/// realized sampling rate only ever grows — the DP accountant charges
/// the conservative effective inclusion probability of the UNION of the
/// original draw and the repair draw (anyone in either set could have
/// been addressed).
///
/// Legality is enforced by the round state machine: `CohortRepaired`
/// appends only in `Configured`/`Keys`, i.e. any time in clear/dp modes
/// but strictly before share dealing under secagg (after `SharesDealt`
/// the threshold-reveal path recovers dropouts instead).
fn repair_cohort(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    round: usize,
    round_id: u64,
    cohort: Vec<String>,
    realized_q: f64,
    sampler: Option<&CohortSampler>,
) -> Result<(Vec<String>, f64)> {
    let (Some(p), Some(sampler)) = (ctx.participation.as_ref(), sampler) else {
        // full participation: everyone is already addressed, there is no
        // unsampled pool to draw replacements from
        return Ok((cohort, realized_q));
    };
    let Ok(alive) = ctx.wm.get_all_device_names() else {
        return Ok((cohort, realized_q));
    };
    let alive: BTreeSet<&String> = alive.iter().collect();
    let presumed_dead: Vec<String> = cohort
        .iter()
        .filter(|c| !alive.contains(c))
        .cloned()
        .collect();
    if presumed_dead.is_empty() {
        return Ok((cohort, realized_q));
    }
    let in_cohort: BTreeSet<&String> = cohort.iter().collect();
    // candidates: alive cluster members the draw skipped, ranked by a
    // salted per-round hash (deterministic, uncorrelated with the draw)
    let key = splitmix64(
        participation_round_key(p.seed, ctx.clustering_round, cluster.id, round)
            ^ REPAIR_SALT,
    );
    let mut pool: Vec<(u64, String)> = cluster
        .clients
        .iter()
        .filter(|c| !in_cohort.contains(c) && alive.contains(c))
        .map(|c| (splitmix64(key ^ crate::util::rng::fnv1a(c)), c.clone()))
        .collect();
    pool.sort();
    let replacements: Vec<String> = pool
        .into_iter()
        .take(presumed_dead.len())
        .map(|(_, c)| c)
        .collect();
    if replacements.is_empty() {
        log::warn!(target: "fact::server",
            "cluster {} round {round}: {} cohort member(s) presumed dead \
             but no alive replacements remain in the pool; proceeding \
             with the survivors",
            cluster.id, presumed_dead.len());
    }
    // union of both draws — the conservative set the accountant charges
    let union = cohort.len() + replacements.len();
    let mut repaired: Vec<String> = cohort
        .into_iter()
        .filter(|c| alive.contains(c))
        .collect();
    repaired.extend(replacements.iter().cloned());
    repaired.sort();
    repaired.dedup();
    if repaired.is_empty() {
        // every member dead and no replacements: leave the round to fail
        // at dispatch with the backend's own (clearer) error
        return Err(FedError::Task(format!(
            "cluster {} round {round}: entire cohort presumed dead and no \
             alive replacements remain",
            cluster.id
        )));
    }
    let q = realized_q
        .max(sampler.amplification_rate(union, cluster.clients.len()));
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::CohortRepaired {
            presumed_dead: presumed_dead.clone(),
            replacements: replacements.clone(),
            cohort: repaired.clone(),
            sample_rate: q,
        },
    ))?;
    ctx.metrics.counter("fact.round.repaired").inc();
    ctx.metrics
        .counter("fact.round.replacements")
        .add(replacements.len() as u64);
    telemetry::event(
        "cohort_repaired",
        &[
            ("presumed_dead", &presumed_dead.join(",")),
            ("replacements", &replacements.join(",")),
            ("q", &format!("{q:.4}")),
        ],
    );
    log::info!(target: "fact::server",
        "cluster {} round {round}: repaired cohort in-round — {} presumed \
         dead ({:?}), {} replacement(s) drawn ({:?}), q {:.3} -> {:.3}",
        cluster.id, presumed_dead.len(), presumed_dead,
        replacements.len(), replacements, realized_q, q);
    Ok((repaired, q))
}

/// A round with no prior history in the store: derive its id, persist
/// the opening `Configured` event, and run the full pipeline.
fn fresh_round(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    round: usize,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    let sw = Stopwatch::start();
    // privacy negotiation: the round's mode and a fresh round id ride in
    // every learn task; clients transform their update accordingly.
    // Derived before anything else so the round's root span carries it.
    let round_id = splitmix64(
        ctx.session_tag
            ^ ((ctx.clustering_round as u64) << 42)
            ^ ((cluster.id as u64) << 21)
            ^ round as u64,
    );
    let mut root = telemetry::Span::root(ctx.tele, phase::ROUND, round_id);
    root.set_attr("cluster", cluster.id);
    root.set_attr("round", round);
    root.set_attr("clustering_round", ctx.clustering_round);
    root.set_attr("mode", ctx.privacy.mode.as_str());
    let _root_guard = root.enter();
    // --- participation: draw this round's cohort (everyone without) --
    let (cohort, realized_q, sampler) = {
        let span = telemetry::child_of_current(phase::DRAW_COHORT);
        let _g = span.enter();
        let psw = Stopwatch::start();
        let out = draw_cohort(ctx, cluster, round, seen_samples);
        ctx.phase_ms(phase::DRAW_COHORT, cluster.id, psw.elapsed_ms());
        out
    };
    // Alg 5 line 3 prep: the global parameters are materialized into ONE
    // shared buffer; every client's dict holds a cheap clone of it, and
    // the binary wire encoding writes it once (envelope dedup) instead
    // of one base64 copy per client.
    let global = crate::util::tensorbuf::TensorBuf::from_f32_slice(&cluster.params);
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::Configured {
            clustering_round: ctx.clustering_round,
            cluster_id: cluster.id,
            round,
            cohort: cohort.clone(),
            sample_rate: realized_q,
            mode: ctx.privacy.mode.as_str().to_string(),
            params: global.clone(),
            deadline_ms: ctx
                .participation
                .as_ref()
                .map(|p| p.deadline_ms)
                .unwrap_or(0),
            session_tag: ctx.session_tag,
        },
    ))?;
    // self-healing: members the scheduler already knows are dead get
    // replaced from the unsampled pool before any phase addresses them
    let (cohort, realized_q) =
        repair_cohort(ctx, cluster, round, round_id, cohort, realized_q, sampler.as_ref())?;
    run_round_pipeline(
        ctx,
        cluster,
        round,
        round_id,
        &cohort,
        realized_q,
        sampler.as_ref(),
        &global,
        sw,
        None,
        records,
        latest,
        seen_samples,
    )
}

/// Resume one in-flight round from its persisted state: fast-forward
/// what already happened, re-run only what the crash interrupted.
/// Client-side key/mask/noise derivation is deterministic in
/// `(round_id, device)`, so a re-run phase reproduces byte-identical
/// contributions and the resumed aggregate equals the uninterrupted one.
fn resume_round(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    round: usize,
    plan: &RoundState,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    let sw = Stopwatch::start();
    let round_id = plan.round_id;
    // a resumed round gets a fresh trace (the pre-crash spans, if any,
    // were replayed from trace.jsonl under their own trace id)
    let mut root = telemetry::Span::root(ctx.tele, phase::ROUND, round_id);
    root.set_attr("cluster", cluster.id);
    root.set_attr("round", round);
    root.set_attr("clustering_round", ctx.clustering_round);
    root.set_attr("mode", ctx.privacy.mode.as_str());
    root.set_attr("resumed", true);
    root.set_attr("from_phase", plan.phase.as_str());
    let _root_guard = root.enter();
    log::info!(target: "fact::server",
        "cluster {} round {round}: resuming from round store at phase '{}'",
        cluster.id, plan.phase.as_str());
    // the config the round was persisted under must still hold
    if plan.mode != ctx.privacy.mode.as_str() {
        return void_round(
            ctx,
            round_id,
            format!(
                "privacy mode changed across restart ('{}' -> '{}')",
                plan.mode,
                ctx.privacy.mode.as_str()
            ),
        );
    }
    if let Some(p) = &plan.params {
        if p.len() != cluster.params.len() {
            return void_round(
                ctx,
                round_id,
                format!(
                    "broadcast params len {} no longer matches the cluster ({})",
                    p.len(),
                    cluster.params.len()
                ),
            );
        }
    }
    let cohort = plan.cohort.clone();
    let realized_q = plan.sample_rate;
    let sampler = ctx
        .participation
        .as_ref()
        .map(|p| CohortSampler::new(p.clone()));
    let global = plan.params.clone().unwrap_or_else(|| {
        crate::util::tensorbuf::TensorBuf::from_f32_slice(&cluster.params)
    });
    match plan.phase {
        RoundPhase::Aggregated => {
            // the aggregate was applied and its post-apply params pinned
            // pre-crash: make them effective (plain replacement — exact
            // under any server optimizer) and close
            if let Some(pa) = &plan.params_after {
                if pa.len() == cluster.params.len() {
                    cluster.params = pa.to_vec();
                }
            }
            if let Some(rj) = &plan.record {
                if let Ok(rec) = RoundRecord::from_json(rj) {
                    cluster.loss_history.push(rec.mean_loss);
                    records.push(rec);
                }
            }
            ctx.store
                .append(RoundEvent::new(round_id, EventKind::Closed))?;
            Ok(())
        }
        RoundPhase::Learn | RoundPhase::Reveal if !plan.updates.is_empty() => {
            // learn already closed: the collected (still masked) updates
            // are in the WAL — redo recovery + aggregation without
            // touching the cohort's learn tasks
            let setup = setup_from_plan(plan);
            let updates: Vec<ClientUpdate> = plan
                .updates
                .iter()
                .map(|u| ClientUpdate {
                    device: u.device.clone(),
                    params: u.params.clone(),
                    n_samples: u.n_samples,
                    loss: u.loss,
                    duration: u.duration,
                })
                .collect();
            let sampled = plan.addressed.len().max(updates.len());
            finish_round(
                ctx,
                cluster,
                round,
                round_id,
                realized_q,
                sampled,
                plan.late,
                plan.dropped.len(),
                setup.as_ref(),
                updates,
                sw,
                records,
                latest,
                seen_samples,
            )
        }
        RoundPhase::Reveal => {
            // a Revealed event without a persisted LearnClosed should not
            // occur; refuse to guess at the missing updates
            void_round(
                ctx,
                round_id,
                "reveal phase without persisted updates".into(),
            )
        }
        RoundPhase::Learn => {
            // dispatched, never closed: honor the part of the deadline
            // that elapsed while the coordinator was down
            let now = now_ms();
            let deadline_at =
                plan.dispatched_at_ms.saturating_add(plan.learn_deadline_ms);
            if plan.learn_deadline_ms > 0 && now >= deadline_at {
                ctx.metrics.counter("fact.roundstore.voided").inc();
                log::warn!(target: "fact::server",
                    "cluster {} round {round}: learn deadline elapsed \
                     during the outage — voiding",
                    cluster.id);
                ctx.store.append(RoundEvent::new(
                    round_id,
                    EventKind::Voided {
                        reason: "learn deadline elapsed during coordinator \
                                 outage"
                            .into(),
                        record: Json::Null,
                    },
                ))?;
                return Ok(());
            }
            let remaining = if plan.learn_deadline_ms > 0 {
                Some(Duration::from_millis(deadline_at - now))
            } else {
                None
            };
            let setup = setup_from_plan(plan);
            let (updates, sampled, late, dropped) = dispatch_learn(
                ctx,
                cluster,
                round,
                round_id,
                &cohort,
                sampler.as_ref(),
                &global,
                setup.as_ref(),
                remaining,
            )?;
            finish_round(
                ctx,
                cluster,
                round,
                round_id,
                realized_q,
                sampled,
                late,
                dropped,
                setup.as_ref(),
                updates,
                sw,
                records,
                latest,
                seen_samples,
            )
        }
        _ => {
            // Configured / Keys / Shares: re-run the setup phases against
            // the pinned cohort + params.  Clients re-derive keys, masks
            // and noise deterministically from the same round id, so the
            // re-run reproduces the dead coordinator's round exactly.
            //
            // Before share dealing the cohort is still repairable: members
            // that died across the outage are replaced now (the repair is
            // evented, so a second resume replays the repaired cohort).
            let (cohort, realized_q) =
                if matches!(plan.phase, RoundPhase::Configured | RoundPhase::Keys) {
                    repair_cohort(
                        ctx,
                        cluster,
                        round,
                        round_id,
                        cohort,
                        realized_q,
                        sampler.as_ref(),
                    )?
                } else {
                    (cohort, realized_q)
                };
            run_round_pipeline(
                ctx,
                cluster,
                round,
                round_id,
                &cohort,
                realized_q,
                sampler.as_ref(),
                &global,
                sw,
                None,
                records,
                latest,
                seen_samples,
            )
        }
    }
}

/// Abandon a round that cannot be safely resumed: persist the `Voided`
/// event, then let [`RevealPolicy`] decide whether the session survives
/// (`proceed`) or fails loudly (`abort`, the default).
fn void_round(ctx: &RoundCtx<'_>, round_id: u64, reason: String) -> Result<()> {
    ctx.metrics.counter("fact.roundstore.voided").inc();
    log::warn!(target: "fact::server",
        "voiding round {}: {reason}", round_id_to_hex(round_id));
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::Voided {
            reason: reason.clone(),
            record: Json::Null,
        },
    ))?;
    match ctx.privacy.reveal_policy {
        RevealPolicy::Abort => Err(FedError::Privacy(format!(
            "cannot resume round {}: {reason} — reveal policy abort",
            round_id_to_hex(round_id)
        ))),
        RevealPolicy::Proceed => Ok(()),
    }
}

/// Rebuild the secagg setup snapshot from persisted round state (`None`
/// when the round ran without secure aggregation).
fn setup_from_plan(plan: &RoundState) -> Option<SecAggSetup> {
    if plan.pubkeys.is_empty() {
        return None;
    }
    let mut keys_json = Json::obj();
    for (name, hex) in &plan.pubkeys {
        keys_json = keys_json.set(name, hex.as_str());
    }
    Some(SecAggSetup {
        participants: plan.participants.clone(),
        keys: plan.pubkeys.clone(),
        keys_json,
        enc_shares: plan.enc_shares.clone(),
        commits: plan.commits.clone(),
        threshold: plan.threshold,
    })
}

/// The setup -> learn -> recover -> aggregate pipeline of one round,
/// entered either fresh (setup still to run) or on resume with the
/// persisted setup already rebuilt (`setup_done`).
#[allow(clippy::too_many_arguments)]
fn run_round_pipeline(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    round: usize,
    round_id: u64,
    cohort: &[String],
    realized_q: f64,
    sampler: Option<&CohortSampler>,
    global: &crate::util::tensorbuf::TensorBuf,
    sw: Stopwatch,
    setup_done: Option<Option<SecAggSetup>>,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    // secagg setup phases: per-pair key agreement + encrypted Shamir
    // share distribution run BEFORE the learn dispatch (clients that
    // fail either phase are excluded from the masking participant set)
    let secagg_setup = match setup_done {
        Some(setup) => setup,
        None => {
            if ctx.privacy.mode.has_secagg() {
                Some(secagg_setup_phases(ctx, cluster, cohort, round_id)?)
            } else {
                None
            }
        }
    };
    let (updates, sampled, late, dropped) = dispatch_learn(
        ctx,
        cluster,
        round,
        round_id,
        cohort,
        sampler,
        global,
        secagg_setup.as_ref(),
        None,
    )?;
    finish_round(
        ctx,
        cluster,
        round,
        round_id,
        realized_q,
        sampled,
        late,
        dropped,
        secagg_setup.as_ref(),
        updates,
        sw,
        records,
        latest,
        seen_samples,
    )
}

/// Dispatch the learn tasks of one round and close the collection.
/// `LearnDispatched` is persisted before the scheduler call and
/// `LearnClosed` (with every collected update) after — a crash in
/// between resumes by re-dispatching with the remaining deadline; a
/// crash after resumes from the persisted updates without touching the
/// clients again.
#[allow(clippy::too_many_arguments)]
fn dispatch_learn(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    round: usize,
    round_id: u64,
    cohort: &[String],
    sampler: Option<&CohortSampler>,
    global: &crate::util::tensorbuf::TensorBuf,
    secagg_setup: Option<&SecAggSetup>,
    deadline_override: Option<Duration>,
) -> Result<(Vec<ClientUpdate>, usize, usize, usize)> {
    let dsw = Stopwatch::start();
    let dspan = telemetry::child_of_current(phase::LEARN_DISPATCH);
    let dguard = dspan.enter();
    let hp = Hyper { round: round as u64, ..ctx.hyper.clone() };
    let privacy_round = if ctx.privacy.mode == PrivacyMode::Off {
        None
    } else {
        let mut pj = ctx
            .privacy
            .to_json()
            .set("round_id", round_id_to_hex(round_id));
        if ctx.participation.is_some() {
            // pin the sampled cohort in the task: a client outside it
            // must refuse to contribute, or the accountant's
            // amplification claim (only sampled clients respond) would
            // be unsound
            pj = pj.set(
                "cohort",
                Json::Arr(cohort.iter().map(|c| Json::Str(c.clone())).collect()),
            );
        }
        if let Some(setup) = secagg_setup {
            pj = pj
                .set(
                    "participants",
                    Json::Arr(
                        setup
                            .participants
                            .iter()
                            .map(|c| Json::Str(c.clone()))
                            .collect(),
                    ),
                )
                .set("keys", setup.keys_json.clone())
                .set("weighted", cluster.model.aggregation().is_weighted());
        }
        Some(pj)
    };
    // under secagg, only the key+share completers can mask: they are
    // the round's addressed set
    let addressed: &[String] = match secagg_setup {
        Some(setup) => &setup.participants,
        None => cohort,
    };
    // one child span per addressed client: opened at dispatch, closed
    // when the collection closes with the client's outcome.  Its context
    // rides the task params (`trace` key), so the client runtime's timed
    // `fact_learn` span echoes back into the same trace via `_span`.
    let mut client_spans: BTreeMap<String, telemetry::Span> = addressed
        .iter()
        .map(|c| {
            let mut s = telemetry::child_of_current(phase::CLIENT_LEARN);
            s.set_attr("client", c);
            (c.clone(), s)
        })
        .collect();
    let dict: BTreeMap<String, Json> = addressed
        .iter()
        .map(|c| {
            let mut params = cluster.model.learn_params_buf(global, &hp);
            if let Some(pj) = &privacy_round {
                params = params.set("privacy", pj.clone());
            }
            params = telemetry::inject(
                params,
                client_spans.get(c).and_then(telemetry::Span::context),
            );
            (c.clone(), params)
        })
        .collect();
    let sampled = dict.len();
    // the effective deadline of THIS dispatch: on resume, the remaining
    // window of the original deadline; otherwise the configured one —
    // which under an adaptive mode is the tracked cohort latency
    // percentile × margin, clamped, once the tracker is warm
    let deadline = match (deadline_override, ctx.participation) {
        (Some(d), _) => Some(d),
        (None, Some(p)) => {
            let d = effective_deadline_explained(ctx.latency, p, addressed);
            telemetry::event(
                "deadline_decision",
                &[
                    ("deadline_ms", &d.deadline_ms.to_string()),
                    ("adaptive", if d.adaptive { "true" } else { "false" }),
                    ("quantile", &format!("{:.2}", d.quantile)),
                    (
                        "observed_ms",
                        &d.observed_ms
                            .map(|v| v.to_string())
                            .unwrap_or_else(|| "cold".into()),
                    ),
                    ("tracker_len", &d.tracker_len.to_string()),
                    ("cohort", &addressed.len().to_string()),
                ],
            );
            let (ms, adaptive) = (d.deadline_ms, d.adaptive);
            if adaptive {
                ctx.metrics.counter("fact.round.adaptive_closes").inc();
                ctx.metrics
                    .counter("fact.round.deadline_adaptive_ms")
                    .add(ms);
                ctx.metrics
                    .gauge("fact.round.deadline_effective_ms")
                    .set(ms as i64);
                log::debug!(target: "fact::server",
                    "cluster {} round {round}: adaptive deadline {ms}ms \
                     ({} × {:.2}, clamp [{}, {}])",
                    cluster.id, p.deadline.as_str(), p.deadline_margin,
                    p.deadline_min_ms, p.deadline_max_ms);
            }
            if ms > 0 {
                Some(Duration::from_millis(ms))
            } else {
                None
            }
        }
        _ => None,
    };
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::LearnDispatched {
            addressed: addressed.to_vec(),
            dispatched_at_ms: now_ms(),
            deadline_ms: deadline.map(|d| d.as_millis() as u64).unwrap_or(0),
        },
    ))?;
    drop(dguard);
    ctx.phase_ms(phase::LEARN_DISPATCH, cluster.id, dsw.elapsed_ms());
    dspan.finish();
    // the collection window: the scheduler call blocks here until
    // complete/quorum/deadline — workflow.rs attaches its `quorum_close`
    // event to this span via the thread-local context
    let qsw = Stopwatch::start();
    let qspan = telemetry::child_of_current(phase::QUORUM_WAIT);
    let qguard = qspan.enter();
    let (results, late_names, dropped) = match (sampler, ctx.participation) {
        (Some(sampler), Some(p)) => {
            // production round loop: close at quorum or deadline,
            // drop (and count) stragglers
            let quorum = sampler.quorum_count(sampled);
            let deadline = deadline.unwrap_or(ctx.timeout);
            let out = ctx.wm.run_task_quorum(
                dict,
                "fact_learn",
                quorum,
                deadline,
                Duration::from_millis(p.late_grace_ms),
            )?;
            // feed the adaptive-deadline tracker: completers with their
            // reported learn duration, everyone else censored at the
            // close (their true latency is at least the elapsed window)
            let reported: BTreeSet<&String> =
                out.results.iter().map(|r| &r.device_name).collect();
            for r in &out.results {
                ctx.latency
                    .observe(&r.device_name, (r.duration * 1_000.0).round() as u64);
            }
            for name in addressed.iter().filter(|d| !reported.contains(*d)) {
                ctx.latency.observe_censored(name, out.elapsed_ms.max(1));
            }
            let late = out.late;
            let dropped = sampled.saturating_sub(out.results.len() + late.len());
            ctx.metrics
                .counter(match out.close {
                    RoundClose::Complete => "fact.participation.complete_closes",
                    RoundClose::Quorum => "fact.participation.quorum_closes",
                    RoundClose::Deadline => "fact.participation.deadline_closes",
                    RoundClose::Settled => "fact.participation.settled_closes",
                })
                .inc();
            if out.results.len() < quorum {
                log::warn!(target: "fact::server",
                    "cluster {} round {round}: closed below quorum \
                     ({}/{quorum} of {sampled} sampled)",
                    cluster.id, out.results.len());
            }
            (out.results, late, dropped)
        }
        _ => {
            let results = ctx.wm.run_task(
                dict,
                "fact_learn",
                deadline_override.unwrap_or(ctx.timeout),
            )?;
            let dropped = sampled.saturating_sub(results.len());
            (results, Vec::new(), dropped)
        }
    };
    drop(qguard);
    ctx.phase_ms(phase::QUORUM_WAIT, cluster.id, qsw.elapsed_ms());
    qspan.finish();
    // pull each client's echoed `fact_learn` span into the trace, then
    // close the coordinator-side client spans with their outcome
    for r in &results {
        telemetry::absorb_echo(ctx.tele, &r.result, round_id);
    }
    for (name, mut span) in client_spans {
        if let Some(r) = results.iter().find(|r| r.device_name == name) {
            span.set_attr("outcome", "ok");
            ctx.metrics
                .histogram_labeled("fact.client.learn_ms", &[("client", &name)])
                .observe(r.duration * 1000.0);
        } else if late_names.contains(&name) {
            span.set_attr("outcome", "late");
        } else {
            span.set_attr("outcome", "dropped");
        }
        span.finish();
    }
    ctx.metrics
        .counter("fact.participation.sampled")
        .add(sampled as u64);
    ctx.metrics
        .counter("fact.participation.reported")
        .add(results.len() as u64);
    ctx.metrics
        .counter("fact.participation.late")
        .add(late_names.len() as u64);
    ctx.metrics
        .counter("fact.participation.dropped")
        .add(dropped as u64);
    if results.is_empty() {
        return Err(FedError::Fact(format!(
            "cluster {}: no client returned a result in round {round}",
            cluster.id
        )));
    }
    // Alg 5 line 5: fetch updated parameters and aggregate.
    let mut updates: Vec<ClientUpdate> = results
        .iter()
        .map(|r| cluster.model.parse_update(&r.device_name, r.duration, &r.result))
        .collect::<Result<Vec<_>>>()?;
    // deterministic aggregation order regardless of arrival order:
    // f32 reduction is order-sensitive, and mode parity (E6) demands
    // bit-identical results between test mode and the TCP path
    updates.sort_by(|a, b| a.device.cmp(&b.device));
    let late = late_names.len();
    // the addressed clients that never delivered a counted result, by
    // name — the recovery path reports them in the audit trail
    let responded: BTreeSet<&String> =
        results.iter().map(|r| &r.device_name).collect();
    let dropped_names: Vec<String> = addressed
        .iter()
        .filter(|d| !responded.contains(*d) && !late_names.contains(*d))
        .cloned()
        .collect();
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::LearnClosed {
            updates: updates
                .iter()
                .map(|u| StoredUpdate {
                    device: u.device.clone(),
                    params: u.params.clone(),
                    n_samples: u.n_samples,
                    loss: u.loss,
                    duration: u.duration,
                })
                .collect(),
            late,
            dropped: dropped_names,
        },
    ))?;
    Ok((updates, sampled, late, dropped))
}

/// The tail of a round: recover the aggregate (under secagg), apply the
/// server optimizer, and persist the outcome — `Revealed` + `Aggregated`
/// + `Closed` on success, or `Voided` when the reveal policy `proceed`
/// abandons an unrecoverable round.  The `Aggregated` event pins the
/// post-apply parameters, so resuming AT that phase is a plain
/// replacement even under a momentum optimizer.
#[allow(clippy::too_many_arguments)]
fn finish_round(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    round: usize,
    round_id: u64,
    realized_q: f64,
    sampled: usize,
    late: usize,
    dropped: usize,
    secagg_setup: Option<&SecAggSetup>,
    updates: Vec<ClientUpdate>,
    sw: Stopwatch,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    let agg_sw = Stopwatch::start();
    let (target, secagg_audit) = if let Some(setup) = secagg_setup {
        let out = secagg_recover_aggregate(ctx, cluster, setup, &updates, round_id)?;
        ctx.store.append(RoundEvent::new(
            round_id,
            EventKind::Revealed { audit: out.audit.to_json() },
        ))?;
        (out.target, Some(out.audit))
    } else {
        // clear/dp aggregation shares the unmask phase name: same slot
        // in the span taxonomy, no masks to fold (mode=clear)
        let mut span = telemetry::child_of_current(phase::UNMASK_AGGREGATE);
        span.set_attr("mode", "clear");
        let _g = span.enter();
        let psw = Stopwatch::start();
        let target = cluster.model.aggregate(&updates, Some(ctx.pool))?;
        ctx.phase_ms(phase::UNMASK_AGGREGATE, cluster.id, psw.elapsed_ms());
        (Some(target), None)
    };
    let asw = Stopwatch::start();
    let mut aspan = telemetry::child_of_current(phase::APPLY);
    let aguard = aspan.enter();
    let applied = match target {
        Some(target) => {
            let mut buf = std::mem::take(&mut cluster.momentum);
            ctx.server_opt.apply(&mut cluster.params, target, &mut buf);
            cluster.momentum = buf;
            true
        }
        None => {
            // reveal policy `proceed`: the round is unrecoverable
            // below the share threshold — void it (parameters
            // unchanged), audit it, keep training
            ctx.metrics.counter("fact.secagg.rounds_voided").inc();
            log::warn!(target: "fact::server",
                "cluster {} round {round}: secagg recovery below \
                 threshold, policy=proceed voids the round",
                cluster.id);
            false
        }
    };
    let agg_ms = agg_sw.elapsed_ms();

    let mean_loss =
        updates.iter().map(|u| u.loss).sum::<f32>() / updates.len() as f32;
    let mean_client_s =
        updates.iter().map(|u| u.duration).sum::<f64>() / updates.len() as f64;
    cluster.loss_history.push(mean_loss);
    for u in &updates {
        // n_samples is clear even under secagg (the protocol ships it
        // alongside the masked vector); it feeds weighted sampling
        seen_samples.insert(u.device.clone(), u.n_samples as f64);
    }
    if !ctx.privacy.mode.has_secagg() {
        // under secagg the per-client vectors are masked lattice noise
        // — recording them would feed garbage to the clustering input
        for u in &updates {
            latest.insert(u.device.clone(), u.params.to_vec());
        }
    }
    let record = RoundRecord {
        clustering_round: ctx.clustering_round,
        cluster_id: cluster.id,
        round,
        n_clients: updates.len(),
        sampled,
        late,
        dropped,
        sample_rate: realized_q,
        mean_loss,
        round_ms: sw.elapsed_ms(),
        agg_ms,
        mean_client_s,
        secagg: secagg_audit,
    };
    if applied {
        // pin the post-apply params + the audit record, then close — a
        // crash between the two appends resumes at Aggregated, where
        // fast-forwarding is an idempotent replacement
        ctx.store.append(RoundEvent::new(
            round_id,
            EventKind::Aggregated {
                params: crate::util::tensorbuf::TensorBuf::from_f32_slice(
                    &cluster.params,
                ),
                record: record.to_json(),
            },
        ))?;
        ctx.store
            .append(RoundEvent::new(round_id, EventKind::Closed))?;
    } else {
        ctx.store.append(RoundEvent::new(
            round_id,
            EventKind::Voided {
                reason: "secagg recovery below threshold (reveal policy \
                         proceed)"
                    .into(),
                record: record.to_json(),
            },
        ))?;
    }
    drop(aguard);
    aspan.set_attr("applied", applied);
    ctx.phase_ms(phase::APPLY, cluster.id, asw.elapsed_ms());
    aspan.finish();
    log::debug!(target: "fact::server",
        "cluster {} round {round}: loss {mean_loss:.4} \
         ({}/{sampled} sampled clients, {:.1}ms)",
        cluster.id, record.n_clients, sw.elapsed_ms());
    records.push(record);
    Ok(())
}

/// The artifacts of a round's secagg setup phases: who completed key
/// agreement + share distribution, their public keys, and the relayed
/// (still encrypted) shares + clear commitments.
struct SecAggSetup {
    /// sorted clients that completed BOTH setup phases — the masking
    /// participant set of the round
    participants: Vec<String>,
    /// participant -> hex DH public key
    keys: BTreeMap<String, String>,
    keys_json: Json,
    /// dealer -> recipient -> hex ciphertext (end-to-end encrypted)
    enc_shares: BTreeMap<String, BTreeMap<String, String>>,
    /// dealer -> recipient -> hex share commitment
    commits: BTreeMap<String, BTreeMap<String, String>>,
    /// resolved t of the t-of-n recovery (what the dealers split with)
    threshold: usize,
}

/// Run the two secagg setup phases before a learn dispatch:
///
/// 1. `fact_keys` — every cohort client posts its per-round DH public
///    key (validated here, so a malformed key fails fast).
/// 2. `fact_shares` — every key-poster Shamir-splits its round secret at
///    the resolved threshold and returns one end-to-end encrypted share
///    per peer plus a clear commitment per share.  The coordinator
///    relays ciphertext it cannot read — holding `t` *readable* shares
///    would let it reconstruct any client's masks.
///
/// Clients whose phase task errors — or misses the participation
/// deadline, when one is configured — are excluded from the masking
/// participant set (they never derived the round's pair masks).
/// Without a deadline, a client that hangs past the round timeout
/// stalls the task like any other task.
///
/// Each completed phase is persisted to the round store (`KeysCollected`
/// / `SharesDealt`) so a resumed round can skip straight to learn.
fn secagg_setup_phases(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    cohort: &[String],
    round_id: u64,
) -> Result<SecAggSetup> {
    let wm = ctx.wm;
    let privacy = ctx.privacy;
    let participation = ctx.participation;
    let timeout = ctx.timeout;
    let metrics = ctx.metrics;
    // setup phases want EVERY response but must not wait on a hung
    // client forever: under a participation deadline, close at the
    // deadline and exclude whoever had not answered (the straggler
    // tolerance the learn phase already has)
    let run_phase = |dict: BTreeMap<String, Json>,
                     func: &str|
     -> Result<Vec<crate::dart::scheduler::TaskResult>> {
        match participation {
            Some(p) if p.deadline_ms > 0 => {
                let expected = dict.len();
                Ok(wm
                    .run_task_quorum(
                        dict,
                        func,
                        expected, // close only when everyone reported...
                        Duration::from_millis(p.deadline_ms),
                        Duration::ZERO,
                    )?
                    .results) // ...or at the deadline, with whoever did
            }
            _ => wm.run_task(dict, func, timeout),
        }
    };
    let rid_hex = round_id_to_hex(round_id);
    // phase 1: key agreement
    let ksw = Stopwatch::start();
    let kspan = telemetry::child_of_current(phase::KEYS);
    let kguard = kspan.enter();
    let kctx = kspan.context();
    let dict: BTreeMap<String, Json> = cohort
        .iter()
        .map(|c| {
            (
                c.clone(),
                telemetry::inject(
                    Json::obj().set("round_id", rid_hex.as_str()),
                    kctx,
                ),
            )
        })
        .collect();
    let results = run_phase(dict, "fact_keys")?;
    for r in &results {
        telemetry::absorb_echo(ctx.tele, &r.result, round_id);
    }
    let mut pubkeys: BTreeMap<String, String> = BTreeMap::new();
    for r in &results {
        if let Some(hex) = r.result.get("pubkey").and_then(Json::as_str) {
            // a malformed or degenerate key excludes THAT client from the
            // round (like a missing response) — it must not abort the
            // whole training session
            match keys::parse_pubkey_hex(hex) {
                Ok(_) => {
                    // lowercase: the reconstruction integrity check
                    // compares against regenerated (lowercase) hex
                    pubkeys.insert(r.device_name.clone(), hex.to_lowercase());
                }
                Err(e) => {
                    metrics.counter("fact.secagg.bad_keys").inc();
                    log::warn!(target: "fact::server",
                        "cluster {}: '{}' posted an invalid DH key ({e}) \
                         — excluded from the round",
                        cluster.id, r.device_name);
                }
            }
        }
    }
    if pubkeys.len() < 2 {
        return Err(FedError::Privacy(format!(
            "cluster {}: only {} client(s) completed secagg key agreement \
             (need >= 2)",
            cluster.id,
            pubkeys.len()
        )));
    }
    if pubkeys.len() > 255 {
        // GF(256) share x-coordinates are 1-based u8 positions: index
        // 255 would wrap to x = 0 (the secret itself), so the holder
        // list caps at 255 participants
        return Err(FedError::Privacy(format!(
            "cluster {}: {} secagg participants exceed the 255-participant \
             limit of GF(256) share coordinates — shard the cohort",
            cluster.id,
            pubkeys.len()
        )));
    }
    let threshold =
        resolve_reveal_threshold(privacy.reveal_threshold, pubkeys.len());
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::KeysCollected { pubkeys: pubkeys.clone(), threshold },
    ))?;
    drop(kguard);
    ctx.phase_ms(phase::KEYS, cluster.id, ksw.elapsed_ms());
    kspan.finish();
    let mut keys_json = Json::obj();
    for (name, hex) in &pubkeys {
        keys_json = keys_json.set(name, hex.as_str());
    }
    if pubkeys.len() < 3 {
        // a 2-client round has a single share holder per dealer — below
        // any meaningful threshold (t >= 2).  Skip share dealing and
        // rely on direct reveals, the pre-threshold recovery path.
        let participants: Vec<String> = pubkeys.keys().cloned().collect();
        return Ok(SecAggSetup {
            participants,
            keys: pubkeys,
            keys_json,
            enc_shares: BTreeMap::new(),
            commits: BTreeMap::new(),
            threshold,
        });
    }
    // phase 2: encrypted share distribution among the key posters
    let ssw = Stopwatch::start();
    let sspan = telemetry::child_of_current(phase::SHARES);
    let sguard = sspan.enter();
    let sctx = sspan.context();
    let dict: BTreeMap<String, Json> = pubkeys
        .keys()
        .map(|c| {
            (
                c.clone(),
                telemetry::inject(
                    Json::obj()
                        .set("round_id", rid_hex.as_str())
                        .set("keys", keys_json.clone())
                        .set("threshold", threshold),
                    sctx,
                ),
            )
        })
        .collect();
    let results = run_phase(dict, "fact_shares")?;
    for r in &results {
        telemetry::absorb_echo(ctx.tele, &r.result, round_id);
    }
    let mut enc_shares = BTreeMap::new();
    let mut commits = BTreeMap::new();
    for r in &results {
        let (Some(shares), Some(cs)) = (
            r.result.get("shares").and_then(Json::as_obj),
            r.result.get("commits").and_then(Json::as_obj),
        ) else {
            continue;
        };
        let to_map = |obj: &BTreeMap<String, Json>| -> BTreeMap<String, String> {
            obj.iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect()
        };
        enc_shares.insert(r.device_name.clone(), to_map(shares));
        commits.insert(r.device_name.clone(), to_map(cs));
    }
    let participants: Vec<String> = enc_shares.keys().cloned().collect();
    if participants.len() < 2 {
        return Err(FedError::Privacy(format!(
            "cluster {}: only {} client(s) dealt secagg shares (need >= 2)",
            cluster.id,
            participants.len()
        )));
    }
    if participants.len() < cohort.len() {
        metrics
            .counter("fact.secagg.setup_dropouts")
            .add((cohort.len() - participants.len()) as u64);
    }
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::SharesDealt {
            participants: participants.clone(),
            enc_shares: enc_shares.clone(),
            commits: commits.clone(),
        },
    ))?;
    drop(sguard);
    ctx.phase_ms(phase::SHARES, cluster.id, ssw.elapsed_ms());
    sspan.finish();
    Ok(SecAggSetup {
        participants,
        keys: pubkeys,
        keys_json,
        enc_shares,
        commits,
        threshold,
    })
}

/// Outcome of [`secagg_recover_aggregate`]: `target` is `None` when the
/// round was unrecoverable and the `proceed` policy voided it.
struct SecAggOutcome {
    target: Option<Vec<f32>>,
    audit: SecAggAudit,
}

/// Secure-aggregation server path for one round: every masking
/// participant that answered is a survivor, everyone else dropped
/// mid-round (under partial participation the cohort — not the whole
/// cluster — was sampled first, so a straggler cut off at the deadline is
/// recovered exactly like a crash).  Recovery is **threshold-based**:
///
/// * each responsive survivor reveals its own DH-derived pair seed with
///   every dropped peer (covering its own pairs), and its decrypted
///   Shamir share of each dropped dealer's round secret;
/// * any `t` commitment-verified shares reconstruct a dropped client's
///   secret, from which the coordinator derives the pair seed with
///   *every* survivor — including survivors that never answered the
///   reveal task, the exact wedge the PR 3 all-survivors-must-reveal
///   protocol could not recover from;
/// * below `t`, [`PrivacyConfig::reveal_policy`] decides: `abort` fails
///   the session, `proceed` voids the round (audited either way).
///
/// The coordinator never materializes an unmasked individual update —
/// `unmask_aggregate` folds zero-copy views of the masked buffers
/// straight into the integer accumulator.
fn secagg_recover_aggregate(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    setup: &SecAggSetup,
    updates: &[ClientUpdate],
    round_id: u64,
) -> Result<SecAggOutcome> {
    let wm = ctx.wm;
    let privacy = ctx.privacy;
    let timeout = ctx.timeout;
    let metrics = ctx.metrics;
    let weighted = cluster.model.aggregation().is_weighted();
    let masked: Vec<MaskedUpdate> = updates
        .iter()
        .map(|u| MaskedUpdate {
            device: u.device.clone(),
            params: u.params.clone(),
            weight: if weighted {
                u.n_samples as f64 / privacy.weight_scale as f64
            } else {
                1.0
            },
        })
        .collect();
    let survivors: Vec<String> =
        updates.iter().map(|u| u.device.clone()).collect();
    let dropped: Vec<String> = setup
        .participants
        .iter()
        .filter(|c| !survivors.contains(c))
        .cloned()
        .collect();
    let mut audit = SecAggAudit {
        participants: setup.participants.len(),
        threshold: setup.threshold,
        dropped: dropped.clone(),
        direct_reveals: 0,
        reconstructed: Vec::new(),
        unrecovered: Vec::new(),
        policy: privacy.reveal_policy,
        outcome: "ok",
    };
    // the reveal span opens even with zero dropouts — "nothing to
    // recover" is itself a phase outcome worth a slot in the trace
    let rsw = Stopwatch::start();
    let mut rspan = telemetry::child_of_current(phase::REVEAL);
    rspan.set_attr("participants", setup.participants.len());
    rspan.set_attr("dropouts", dropped.len());
    let rguard = rspan.enter();
    let mut revealed: Vec<RevealedSeed> = Vec::new();
    if !dropped.is_empty() {
        log::info!(target: "fact::server",
            "cluster {}: {} dropout(s) in secagg round, recovering masks \
             (t={} of {})",
            cluster.id, dropped.len(), setup.threshold,
            setup.participants.len());
        metrics.counter("fact.secagg.dropouts").add(dropped.len() as u64);
        let dropped_json =
            Json::Arr(dropped.iter().cloned().map(Json::Str).collect());
        let dict: BTreeMap<String, Json> = survivors
            .iter()
            .map(|s| {
                // the encrypted shares each dropped dealer addressed to
                // this survivor, relayed for client-side decryption
                let mut shares = Json::obj();
                for d in &dropped {
                    if let Some(ct) =
                        setup.enc_shares.get(d).and_then(|m| m.get(s))
                    {
                        shares = shares.set(d, ct.as_str());
                    }
                }
                (
                    s.clone(),
                    telemetry::inject(
                        Json::obj()
                            .set("round_id", round_id_to_hex(round_id))
                            .set("dropped", dropped_json.clone())
                            .set("keys", setup.keys_json.clone())
                            .set("shares", shares),
                        telemetry::current(),
                    ),
                )
            })
            .collect();
        let reveals = wm.run_task(dict, "fact_reveal", timeout)?;
        for r in &reveals {
            telemetry::absorb_echo(ctx.tele, &r.result, round_id);
        }
        // collect direct seed reveals and decrypted shares
        let mut shares_by_dealer: BTreeMap<String, Vec<shamir::Share>> =
            BTreeMap::new();
        for r in &reveals {
            if let Some(seeds) = r.result.get("seeds").and_then(Json::as_obj) {
                for (d, hex) in seeds {
                    let Some(hex) = hex.as_str() else { continue };
                    revealed.push(RevealedSeed {
                        survivor: r.device_name.clone(),
                        dropped: d.clone(),
                        seed: seed_from_hex(hex)?,
                    });
                    audit.direct_reveals += 1;
                }
            }
            if let Some(shares) = r.result.get("shares").and_then(Json::as_obj)
            {
                for (d, hex) in shares {
                    let Some(hex) = hex.as_str() else { continue };
                    // a malformed share is discarded exactly like a
                    // commitment-failing one — one bad reveal must not
                    // abort a recovery that t other valid shares can
                    // still complete
                    let share = match from_hex(hex)
                        .ok()
                        .and_then(|b| shamir::Share::from_bytes(&b).ok())
                    {
                        Some(s) => s,
                        None => {
                            metrics
                                .counter("fact.secagg.corrupt_shares")
                                .inc();
                            log::warn!(target: "fact::server",
                                "cluster {}: malformed share of '{d}' from \
                                 '{}' — discarded",
                                cluster.id, r.device_name);
                            continue;
                        }
                    };
                    // verify against the dealer's commitment for this
                    // holder — a corrupted share must not enter the pool
                    let commit_ok = setup
                        .commits
                        .get(d)
                        .and_then(|m| m.get(&r.device_name))
                        .and_then(|c| from_hex(c).ok())
                        .map(|want| match <&[u8; 32]>::try_from(want.as_slice()) {
                            Ok(w) => shamir::verify_share(&share, w),
                            Err(_) => false,
                        })
                        .unwrap_or(false);
                    if !commit_ok {
                        metrics.counter("fact.secagg.corrupt_shares").inc();
                        log::warn!(target: "fact::server",
                            "cluster {}: share of '{d}' revealed by '{}' \
                             fails its commitment — discarded",
                            cluster.id, r.device_name);
                        continue;
                    }
                    shares_by_dealer.entry(d.clone()).or_default().push(share);
                }
            }
        }
        // per dropped dealer: direct reveals may already cover every
        // survivor; otherwise reconstruct from >= t verified shares
        for d in &dropped {
            let uncovered: Vec<String> = survivors
                .iter()
                .filter(|s| {
                    !revealed
                        .iter()
                        .any(|rv| &rv.survivor == *s && &rv.dropped == d)
                })
                .cloned()
                .collect();
            if uncovered.is_empty() {
                continue;
            }
            let shares = shares_by_dealer.get(d).map(Vec::as_slice).unwrap_or(&[]);
            if shares.len() < setup.threshold {
                audit.unrecovered.push(d.clone());
                continue;
            }
            let Some(posted) = setup.keys.get(d) else {
                audit.unrecovered.push(d.clone());
                continue;
            };
            // shared with the REST board: reconstruct + length check +
            // posted-pubkey integrity check.  A failure here (duplicate
            // coordinates, or commitment-passing shares from a lying
            // dealer that fail the pubkey check) makes THIS dealer
            // unrecoverable — the reveal policy decides the round's
            // fate, not a hard error that would bypass `proceed`.
            let secret = match crate::privacy::secagg::reconstruct_dealer_secret(
                shares,
                setup.threshold,
                posted,
                d,
            ) {
                Ok(s) => s,
                Err(e) => {
                    metrics.counter("fact.secagg.corrupt_shares").inc();
                    log::warn!(target: "fact::server",
                        "cluster {}: reconstruction of '{d}' failed ({e}) \
                         — dealer unrecoverable",
                        cluster.id);
                    audit.unrecovered.push(d.clone());
                    continue;
                }
            };
            for s in &uncovered {
                let Some(posted_pk) = setup.keys.get(s) else {
                    // a survivor that never posted a key has no pair mask
                    // with this dealer to unwind
                    continue;
                };
                let their = keys::parse_pubkey_hex(posted_pk)?;
                let shared = keys::shared_key(&secret, &their);
                revealed.push(RevealedSeed {
                    survivor: s.clone(),
                    dropped: d.clone(),
                    seed: keys::pair_seed_from_shared(&shared, round_id, s, d),
                });
            }
            audit.reconstructed.push(d.clone());
        }
        metrics
            .counter("fact.secagg.reconstructions")
            .add(audit.reconstructed.len() as u64);
        if !audit.reconstructed.is_empty() {
            audit.outcome = "recovered";
        }
        if !audit.unrecovered.is_empty() {
            metrics.counter("fact.secagg.below_threshold").inc();
            let detail = format!(
                "cluster {}: secagg round below reveal threshold t={} for \
                 {:?} ({} dropout(s), {} direct reveal(s))",
                cluster.id,
                setup.threshold,
                audit.unrecovered,
                dropped.len(),
                audit.direct_reveals,
            );
            match privacy.reveal_policy {
                RevealPolicy::Abort => {
                    audit.outcome = "aborted";
                    return Err(FedError::Privacy(format!(
                        "{detail} — reveal policy abort"
                    )));
                }
                RevealPolicy::Proceed => {
                    audit.outcome = "skipped";
                    return Ok(SecAggOutcome { target: None, audit });
                }
            }
        }
    }
    drop(rguard);
    rspan.set_attr("outcome", audit.outcome);
    ctx.phase_ms(phase::REVEAL, cluster.id, rsw.elapsed_ms());
    rspan.finish();
    let usw = Stopwatch::start();
    let mut uspan = telemetry::child_of_current(phase::UNMASK_AGGREGATE);
    uspan.set_attr("mode", "secagg");
    let _uguard = uspan.enter();
    let target = unmask_aggregate(&masked, &revealed, privacy.frac_bits)?;
    ctx.phase_ms(phase::UNMASK_AGGREGATE, cluster.id, usw.elapsed_ms());
    Ok(SecAggOutcome { target: Some(target), audit })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_opt_replacement_is_exact() {
        let opt = ServerOpt::default();
        let mut p = vec![1.0f32, 2.0];
        let mut buf = Vec::new();
        opt.apply(&mut p, vec![5.0, -1.0], &mut buf);
        assert_eq!(p, vec![5.0, -1.0]);
        assert!(buf.is_empty(), "fast path must not allocate a buffer");
    }

    #[test]
    fn server_opt_momentum_accumulates() {
        let opt = ServerOpt { lr: 1.0, momentum: 0.5 };
        let mut p = vec![0.0f32];
        let mut buf = Vec::new();
        // constant target 1.0: step1 delta=1 -> p=1; step2 buf=0.5*1+(1-1)=0.5 -> p=1.5
        opt.apply(&mut p, vec![1.0], &mut buf);
        assert!((p[0] - 1.0).abs() < 1e-6);
        opt.apply(&mut p, vec![1.0], &mut buf);
        assert!((p[0] - 1.5).abs() < 1e-6, "momentum overshoot expected, got {}", p[0]);
    }

    #[test]
    fn server_opt_small_lr_damps() {
        let opt = ServerOpt { lr: 0.1, momentum: 0.0 };
        let mut p = vec![0.0f32];
        let mut buf = Vec::new();
        opt.apply(&mut p, vec![1.0], &mut buf);
        assert!((p[0] - 0.1).abs() < 1e-6);
    }
    use crate::dart::TaskRegistry;
    use crate::fact::aggregation::Aggregation;
    use crate::fact::client::FactClientRuntime;
    use crate::fact::data::{synthesize, Partition, SyntheticConfig};
    use crate::fact::model::LinearModel;
    use crate::fact::stopping::FixedRoundFl;
    use crate::runtime::{default_artifacts_dir, Engine};

    /// Full FACT loop over test mode with the pure-Rust linear model
    /// (runs even without artifacts) — federated loss must decrease.
    fn linear_fixture(
        clients: usize,
        partition: Partition,
    ) -> Option<(FactServer, Arc<dyn FactModel>)> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None; // engine construction requires the manifest
        }
        let engine = Engine::load(&dir, 1).unwrap();
        let registry = TaskRegistry::new();
        let rt = FactClientRuntime::new(engine);
        let data = synthesize(&SyntheticConfig {
            clients,
            samples_per_client: 256,
            dim: 8,
            classes: 4,
            partition,
            ..Default::default()
        })
        .unwrap();
        for (name, d) in data {
            rt.add_supervised(&name, d);
        }
        rt.register(&registry);
        let wm = WorkflowManager::test_mode(clients, registry, 2);
        let model = LinearModel::arc(8, 4, Aggregation::WeightedFedAvg);
        Some((FactServer::new(wm), model))
    }

    #[test]
    fn standard_fl_loss_decreases() {
        let Some((mut server, model)) = linear_fixture(4, Partition::Iid) else {
            return;
        };
        server.hyper = Hyper { lr: 0.3, mu: 0.0, local_steps: 6, round: 0 };
        server
            .initialization_by_model(model, Arc::new(FixedRoundFl(10)), 42)
            .unwrap();
        server.learn().unwrap();
        let hist = server.history();
        assert_eq!(hist.len(), 10);
        let first = hist.first().unwrap().mean_loss;
        let last = hist.last().unwrap().mean_loss;
        assert!(
            last < 0.7 * first,
            "federated loss did not decrease: {first} -> {last}"
        );
        assert!(hist.iter().all(|r| r.n_clients == 4));
        // evaluation works and accuracy is above chance (0.25)
        let evals = server.evaluate().unwrap();
        assert_eq!(evals.len(), 1);
        assert!(evals[0].accuracy > 0.3, "accuracy {}", evals[0].accuracy);
    }

    #[test]
    fn learn_requires_initialization() {
        let Some((mut server, _)) = linear_fixture(2, Partition::Iid) else {
            return;
        };
        assert!(server.learn().is_err());
    }

    #[test]
    fn latest_updates_are_tracked_per_client() {
        let Some((mut server, model)) = linear_fixture(3, Partition::Iid) else {
            return;
        };
        server
            .initialization_by_model(model, Arc::new(FixedRoundFl(2)), 1)
            .unwrap();
        server.learn().unwrap();
        assert_eq!(server.latest_updates().len(), 3);
        for v in server.latest_updates().values() {
            assert_eq!(v.len(), 8 * 4 + 4);
        }
    }

    #[test]
    fn clustered_fl_runs_multiple_clustering_rounds() {
        use crate::fact::clustering::KMeansClustering;
        let Some((mut server, model)) =
            linear_fixture(6, Partition::LatentGroups { groups: 2 })
        else {
            return;
        };
        server.hyper = Hyper { lr: 0.3, mu: 0.0, local_steps: 4, round: 0 };
        let clients = server.workflow_manager().get_all_device_names().unwrap();
        let params = model.init_params(0).unwrap();
        let container = ClusterContainer::single(model, params, clients);
        server
            .initialization_by_cluster_container(
                container,
                Box::new(KMeansClustering::new(2)),
                Box::new(FixedClusteringRounds(2)),
                Arc::new(FixedRoundFl(3)),
            )
            .unwrap();
        server.learn().unwrap();
        // after round 1 the container was re-clustered into 2 clusters
        assert_eq!(server.container().clusters.len(), 2);
        // history spans both clustering rounds
        assert!(server.history().iter().any(|r| r.clustering_round == 0));
        assert!(server.history().iter().any(|r| r.clustering_round == 1));
        let evals = server.evaluate().unwrap();
        assert_eq!(evals.len(), 2);
    }
}
