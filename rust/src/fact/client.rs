//! Client-side FACT runtime — the code a physical client runs (paper
//! §2.2.1 Client class, §C.2.2 client main script).
//!
//! Registers the predefined `@feddart` functions in a [`TaskRegistry`]:
//! * `fact_init` — receives the model structure; validates it is runnable.
//! * `fact_learn` — receives global parameters + hyperparameters, runs
//!   `local_steps` SGD steps on the client's own data (through the PJRT
//!   engine for HLO models, natively for linear models), returns updated
//!   parameters + metadata.
//! * `fact_evaluate` — evaluates given parameters on the client's held-out
//!   data.
//! * `fact_keys` / `fact_shares` / `fact_reveal` — the secure-aggregation
//!   side tasks: per-round DH key posting, encrypted Shamir share
//!   dealing, and dropout recovery (direct pair-seed reveals plus share
//!   reveals for threshold reconstruction).
//!
//! The same registry object serves every simulated client in test mode
//! (data is keyed by the injected `_device` name) and exactly one client in
//! a real deployment.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::error::{FedError, Result};
use crate::fact::data::{ClientCorpus, ClientData};
use crate::fact::model::LinearModel;
use crate::json::Json;
use crate::runtime::{Engine, Tensor};
use crate::util::rng::splitmix64;
use crate::util::tensorbuf::TensorBuf;
use crate::dart::TaskRegistry;

/// Local data owned by one device.
pub enum LocalData {
    Supervised { train: ClientData, test: ClientData },
    Corpus(ClientCorpus),
}

/// Per-device mutable state (cached across task calls — the paper's Client
/// class holds the local model/loaders between rounds).
#[derive(Default)]
struct DeviceState {
    /// models initialised on this device (fact_init ran)
    initialized: Vec<String>,
    /// ensemble base-learner cache (see `fact::ensemble`)
    pub base_params: BTreeMap<String, Vec<f32>>,
    /// DH crypto cache for the most recent secagg round.  The
    /// keys/shares/learn/reveal tasks of one round all need the same
    /// pairwise keys (and the learn task re-checks its own public key
    /// against the coordinator's echo), and each derivation is a
    /// 2048-bit modpow — recompute-per-task would triple the round's
    /// exponentiation cost.
    round_crypto: Option<RoundCrypto>,
}

/// Cached per-(device, round) DH material.
#[derive(Clone)]
struct RoundCrypto {
    round_id: u64,
    /// this device's own round public key (hex), for echo verification
    my_pub_hex: String,
    /// peer -> pairwise shared key
    shared: BTreeMap<String, [u8; 32]>,
}

/// The client runtime shared by all `@feddart` functions.
pub struct FactClientRuntime {
    engine: Engine,
    data: Mutex<BTreeMap<String, Arc<LocalData>>>,
    state: Mutex<BTreeMap<String, DeviceState>>,
    /// Legacy cohort key for pre-key-agreement secagg rounds (a learn
    /// task without a `keys` map).  Provisioned out of band (like the
    /// transport key) and shared among clients only — the coordinator
    /// never holds it.
    privacy_secret: Mutex<Option<Vec<u8>>>,
    /// Per-device client secrets for per-pair key agreement.  Generated
    /// from the OS CSPRNG on first use (or installed via
    /// [`FactClientRuntime::set_client_secret`] for reproducible tests);
    /// NEVER shared with anyone — per-round DH keypairs derive from it.
    client_secrets: Mutex<BTreeMap<String, [u8; 32]>>,
    /// Test hook: when set, DP noise comes from the deterministic
    /// seeded [`Rng`](crate::util::rng::Rng) instead of the OS CSPRNG.
    deterministic_noise: std::sync::atomic::AtomicBool,
    /// Client-local entropy mixed into every deterministic DP noise
    /// seed.  The seed must not be a function of public values only
    /// (device name + round id), or the coordinator could replay the
    /// stream and subtract the noise, reducing dp-mode privacy to zero.
    noise_nonce: u64,
}

impl FactClientRuntime {
    pub fn new(engine: Engine) -> Arc<FactClientRuntime> {
        Arc::new(FactClientRuntime {
            engine,
            data: Mutex::new(BTreeMap::new()),
            state: Mutex::new(BTreeMap::new()),
            privacy_secret: Mutex::new(None),
            client_secrets: Mutex::new(BTreeMap::new()),
            deterministic_noise: std::sync::atomic::AtomicBool::new(false),
            noise_nonce: crate::util::rng::entropy_seed(),
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Install the clients' shared cohort key (only needed for legacy
    /// secagg rounds without per-pair key agreement; `dp`-only rounds
    /// and key-agreement rounds work without it).
    pub fn set_privacy_secret(&self, key: &[u8]) {
        *self.privacy_secret.lock().unwrap() = Some(key.to_vec());
    }

    /// Install a device's long-lived client secret (per-pair key
    /// agreement).  Without one, a fresh secret is drawn from the OS
    /// CSPRNG at first use — call this only to pin determinism in tests
    /// or to provision a managed identity.
    pub fn set_client_secret(&self, device: &str, secret: [u8; 32]) {
        self.client_secrets
            .lock()
            .unwrap()
            .insert(device.to_string(), secret);
    }

    /// Test hook: route DP noise through the deterministic seeded Rng
    /// instead of the OS CSPRNG.
    pub fn set_deterministic_noise(&self, on: bool) {
        self.deterministic_noise
            .store(on, std::sync::atomic::Ordering::Relaxed);
    }

    fn client_secret(&self, device: &str) -> [u8; 32] {
        let mut secrets = self.client_secrets.lock().unwrap();
        *secrets.entry(device.to_string()).or_insert_with(|| {
            let mut s = [0u8; 32];
            if !crate::util::rng::entropy_bytes(&mut s) {
                log::warn!(target: "fact::client",
                    "'{device}': no OS CSPRNG, client secret from mixed \
                     time/pid entropy");
            }
            s
        })
    }

    /// The DH material of one secagg round — this device's own public
    /// key plus the pairwise key per peer — computed once per
    /// (device, round) and cached across the round's tasks.  `keys` maps
    /// participant name -> hex public key (from the round board).
    fn round_crypto(
        &self,
        device: &str,
        round_id: u64,
        keys: &BTreeMap<String, String>,
    ) -> Result<RoundCrypto> {
        {
            let state = self.state.lock().unwrap();
            if let Some(s) = state.get(device) {
                if let Some(rc) = &s.round_crypto {
                    if rc.round_id == round_id
                        && keys.keys().filter(|k| *k != device).all(|k| {
                            rc.shared.contains_key(k)
                        })
                    {
                        return Ok(rc.clone());
                    }
                }
            }
        }
        let my = crate::privacy::keys::derive_round_secret(
            &self.client_secret(device),
            round_id,
            device,
        );
        let my_pub_hex = crate::privacy::keys::pubkey_hex(
            &crate::privacy::keys::keypair(&my).public,
        );
        let mut shared = BTreeMap::new();
        for (peer, pub_hex) in keys {
            if peer == device {
                continue;
            }
            let their = crate::privacy::keys::parse_pubkey_hex(pub_hex)?;
            shared.insert(
                peer.clone(),
                crate::privacy::keys::shared_key(&my, &their),
            );
        }
        let rc = RoundCrypto { round_id, my_pub_hex, shared };
        self.state
            .lock()
            .unwrap()
            .entry(device.to_string())
            .or_default()
            .round_crypto = Some(rc.clone());
        Ok(rc)
    }

    /// Attach a device's supervised dataset (80/20 split).
    pub fn add_supervised(&self, device: &str, data: ClientData) {
        let (train, test) = data.train_test_split(0.2);
        self.data
            .lock()
            .unwrap()
            .insert(device.to_string(), Arc::new(LocalData::Supervised { train, test }));
    }

    /// Attach a device's token corpus.
    pub fn add_corpus(&self, device: &str, corpus: ClientCorpus) {
        self.data
            .lock()
            .unwrap()
            .insert(device.to_string(), Arc::new(LocalData::Corpus(corpus)));
    }

    /// Clone out a device's supervised split (ensemble tasks, diagnostics).
    pub fn supervised_of(&self, device: &str) -> Result<(ClientData, ClientData)> {
        match self.local(device)?.as_ref() {
            LocalData::Supervised { train, test } => Ok((train.clone(), test.clone())),
            _ => Err(FedError::Fact(format!(
                "device '{device}' has no supervised data"
            ))),
        }
    }

    fn local(&self, device: &str) -> Result<Arc<LocalData>> {
        self.data
            .lock()
            .unwrap()
            .get(device)
            .cloned()
            .ok_or_else(|| FedError::Fact(format!("device '{device}' has no local data")))
    }

    /// Store a value in the per-device ensemble cache.
    pub fn cache_base_params(&self, device: &str, model: &str, params: Vec<f32>) {
        self.state
            .lock()
            .unwrap()
            .entry(device.to_string())
            .or_default()
            .base_params
            .insert(model.to_string(), params);
    }

    pub fn cached_base_params(&self, device: &str, model: &str) -> Option<Vec<f32>> {
        self.state
            .lock()
            .unwrap()
            .get(device)
            .and_then(|s| s.base_params.get(model).cloned())
    }

    /// Register `fact_init`, `fact_learn`, `fact_evaluate`, `fact_keys`,
    /// `fact_shares`, `fact_reveal` on a registry.
    pub fn register(self: &Arc<Self>, registry: &TaskRegistry) {
        let rt = Arc::clone(self);
        registry.register("fact_init", move |p| rt.clone().fact_init(p));
        let rt = Arc::clone(self);
        registry.register("fact_learn", move |p| rt.clone().fact_learn(p));
        let rt = Arc::clone(self);
        registry.register("fact_evaluate", move |p| rt.clone().fact_evaluate(p));
        let rt = Arc::clone(self);
        registry.register("fact_keys", move |p| rt.clone().fact_keys(p));
        let rt = Arc::clone(self);
        registry.register("fact_shares", move |p| rt.clone().fact_shares(p));
        let rt = Arc::clone(self);
        registry.register("fact_reveal", move |p| rt.clone().fact_reveal(p));
    }

    // ------------------------------------------------------------- helpers

    fn device_of(p: &Json) -> Result<String> {
        p.get("_device")
            .and_then(Json::as_str)
            .map(String::from)
            .ok_or_else(|| FedError::Fact("missing _device".into()))
    }

    fn round_id_of(p: &Json) -> Result<u64> {
        crate::privacy::round_id_from_hex(
            p.need("round_id")?.as_str().ok_or_else(|| {
                FedError::Privacy("round_id must be a string".into())
            })?,
        )
    }

    /// Global parameters from the task dict: a binary tensor on the new
    /// wire path, a base64 string from legacy JSON peers.
    fn params_of(p: &Json) -> Result<TensorBuf> {
        TensorBuf::from_json(p.need("params")?)
            .map_err(|e| FedError::Fact(format!("bad params: {e}")))
    }

    /// Deterministic batch seed: device identity x round x step.
    fn batch_seed(device: &str, round: u64, step: u64) -> u64 {
        splitmix64(crate::util::rng::fnv1a(device) ^ (round << 20) ^ step)
    }

    // --------------------------------------------------------------- tasks

    fn fact_init(&self, p: &Json) -> Result<Json> {
        let device = Self::device_of(p)?;
        let model = p.need("model")?.as_str().unwrap_or("").to_string();
        // validate the model is servable on this client
        if !model.starts_with("linear") && !model.starts_with("ensemble") {
            self.engine.manifest().model(&model)?;
        }
        self.local(&device)?; // data must be attached
        self.state
            .lock()
            .unwrap()
            .entry(device.clone())
            .or_default()
            .initialized
            .push(model.clone());
        log::debug!(target: "fact::client", "'{device}' initialised model '{model}'");
        Ok(Json::obj().set("initialized", model))
    }

    fn fact_learn(&self, p: &Json) -> Result<Json> {
        // pure compute time, measured on the client: the coordinator
        // subtracts it from the round trip to separate training speed
        // from queueing/transport when tracking latency percentiles
        let compute_sw = std::time::Instant::now();
        let device = Self::device_of(p)?;
        let model = p.need("model")?.as_str().unwrap_or("").to_string();
        let global_buf = Self::params_of(p)?;
        // local SGD mutates its own copy; the read-only global (FedProx
        // anchor) stays a zero-copy view of the received buffer
        let mut params = global_buf.to_vec();
        let global = global_buf.as_f32_slice();
        let lr = p.get("lr").and_then(Json::as_f64).unwrap_or(0.1) as f32;
        let mu = p.get("mu").and_then(Json::as_f64).unwrap_or(0.0) as f32;
        let steps = p.get("local_steps").and_then(Json::as_usize).unwrap_or(1).max(1);
        let round = p.get("round").and_then(Json::as_i64).unwrap_or(0) as u64;
        let local = self.local(&device)?;

        let (loss_sum, n_samples);
        if let Some(rest) = model.strip_prefix("linear_") {
            // native path
            let (dim, classes) = parse_linear_dims(rest)?;
            let LocalData::Supervised { train, .. } = local.as_ref() else {
                return Err(FedError::Fact("linear model needs supervised data".into()));
            };
            let b = 32.min(train.n()).max(1);
            let mut acc = 0.0f32;
            for s in 0..steps {
                let (x, y) =
                    train.sample_batch(Self::batch_seed(&device, round, s as u64), b);
                acc += LinearModel::sgd_step(
                    &mut params, &x, &y, dim, classes, lr, mu, global,
                );
            }
            loss_sum = acc;
            n_samples = train.n() as f32;
        } else {
            let meta = self.engine.manifest().model(&model)?.clone();
            let train_entry = meta.entry("train")?.to_string();
            match (meta.kind.as_str(), local.as_ref()) {
                ("mlp", LocalData::Supervised { train, .. }) => {
                    let bt = meta.field_usize("train_batch")?;
                    let d = meta.field_usize("in_dim")?;
                    let mut acc = 0.0f32;
                    for s in 0..steps {
                        let (x, y) = train
                            .sample_batch(Self::batch_seed(&device, round, s as u64), bt);
                        let out = self.engine.execute(
                            &train_entry,
                            vec![
                                Tensor::vec_f32(params),
                                Tensor::with_shape_f32(vec![bt, d], x)?,
                                Tensor::with_shape_i32(vec![bt], y)?,
                                Tensor::scalar_f32(lr),
                                Tensor::scalar_f32(mu),
                                Tensor::vec_f32(global.to_vec()),
                            ],
                        )?;
                        let mut it = out.into_iter();
                        params = it.next().unwrap().into_f32s()?;
                        acc += it.next().unwrap().scalar()?;
                    }
                    loss_sum = acc;
                    n_samples = train.n() as f32;
                }
                ("transformer", LocalData::Corpus(corpus)) => {
                    let bt = meta.field_usize("train_batch")?;
                    let s_len = meta.field_usize("seq")?;
                    let mut acc = 0.0f32;
                    for s in 0..steps {
                        let toks = corpus.sample_windows(
                            Self::batch_seed(&device, round, s as u64),
                            bt,
                            s_len,
                        );
                        let out = self.engine.execute(
                            &train_entry,
                            vec![
                                Tensor::vec_f32(params),
                                Tensor::with_shape_i32(vec![bt, s_len + 1], toks)?,
                                Tensor::scalar_f32(lr),
                                Tensor::scalar_f32(mu),
                                Tensor::vec_f32(global.to_vec()),
                            ],
                        )?;
                        let mut it = out.into_iter();
                        params = it.next().unwrap().into_f32s()?;
                        acc += it.next().unwrap().scalar()?;
                    }
                    loss_sum = acc;
                    n_samples = corpus.tokens.len() as f32;
                }
                (kind, _) => {
                    return Err(FedError::Fact(format!(
                        "model kind '{kind}' incompatible with local data of '{device}'"
                    )))
                }
            }
        }
        // FedNova: normalize the accumulated delta by the effective
        // local step count BEFORE the privacy transform (the server
        // re-scales the merged mean by the weighted tau), and report
        // tau in the clear alongside the (possibly masked) vector
        let strategy = p.get("strategy").and_then(Json::as_str).unwrap_or("plain");
        let tau = if strategy == "fednova" {
            let tau = steps as f32;
            for (w, g) in params.iter_mut().zip(global) {
                *w = g + (*w - g) / tau;
            }
            Some(tau)
        } else {
            None
        };
        let params_out = self.apply_privacy(&device, p, params, global, n_samples)?;
        let mut out = Json::obj()
            .set("params", params_out)
            .set("n_samples", n_samples)
            .set("loss", loss_sum / steps as f32)
            .set("compute_s", compute_sw.elapsed().as_secs_f64());
        if let Some(tau) = tau {
            out = out.set("tau", tau);
        }
        Ok(out)
    }

    /// Apply the round's negotiated privacy transform to a finished local
    /// update: DP clip+noise on the delta against the (public) global
    /// parameters, then pairwise lattice masking of the weighted update.
    /// With no `privacy` object in the task (or mode `off`) the update
    /// passes through unchanged.
    fn apply_privacy(
        &self,
        device: &str,
        task: &Json,
        mut params: Vec<f32>,
        global: &[f32],
        n_samples: f32,
    ) -> Result<TensorBuf> {
        use crate::privacy::{masking, PrivacyConfig, PrivacyMode};
        let Some(pj) = task.get("privacy").filter(|j| !j.is_null()) else {
            return Ok(TensorBuf::from_f32_vec(params));
        };
        let cfg = PrivacyConfig::from_json(pj)?;
        if cfg.mode == PrivacyMode::Off {
            return Ok(TensorBuf::from_f32_vec(params));
        }
        let round_id = crate::privacy::round_id_from_hex(
            pj.get("round_id").and_then(Json::as_str).ok_or_else(|| {
                FedError::Privacy("privacy round without round_id".into())
            })?,
        )?;
        // Participation guard: when the round pins a sampled cohort, a
        // client outside it must not contribute an update.  The
        // accountant's amplification-by-subsampling claim assumes ONLY
        // sampled clients respond — a stray dispatch to a non-cohort
        // client would silently void the ε bound.
        if let Some(cohort) = pj.get("cohort").and_then(Json::as_arr) {
            if !cohort.iter().any(|c| c.as_str() == Some(device)) {
                return Err(FedError::Privacy(format!(
                    "'{device}' is not in the round's sampled cohort"
                )));
            }
        }
        if cfg.mode.has_dp() {
            use crate::util::rng::{NoiseSource, OsRng, Rng};
            let deterministic = self
                .deterministic_noise
                .load(std::sync::atomic::Ordering::Relaxed);
            // OS CSPRNG by default: privacy noise from a seeded testbed
            // stream is replayable by anyone who learns the seed inputs
            let mut det;
            let mut os;
            let rng: &mut dyn NoiseSource = if deterministic {
                det = Rng::new(self.noise_seed(device, round_id));
                &mut det
            } else {
                match OsRng::new() {
                    Ok(r) => {
                        os = r;
                        &mut os
                    }
                    Err(_) => {
                        log::warn!(target: "fact::client",
                            "'{device}': no OS CSPRNG, DP noise from the \
                             nonce-mixed deterministic fallback");
                        det = Rng::new(self.noise_seed(device, round_id));
                        &mut det
                    }
                }
            };
            crate::privacy::dp::privatize_update(
                &mut params,
                global,
                cfg.clip_norm,
                cfg.noise_multiplier,
                rng,
            )?;
        }
        if cfg.mode.has_secagg() {
            let participants: Vec<String> = pj
                .need("participants")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|j| j.as_str().map(String::from))
                .collect();
            if !participants.iter().any(|p| p == device) {
                return Err(FedError::Privacy(format!(
                    "'{device}' is not in the round's participant set"
                )));
            }
            let peers: Vec<String> = participants
                .iter()
                .filter(|p| *p != device)
                .cloned()
                .collect();
            let weighted =
                pj.get("weighted").and_then(Json::as_bool).unwrap_or(true);
            let weight = if weighted {
                n_samples as f64 / cfg.weight_scale as f64
            } else {
                1.0
            };
            if let Some(keys_obj) = pj.get("keys").and_then(Json::as_obj) {
                // per-pair key agreement: every pair seed comes from the
                // DH shared secret with that peer — no cohort key at all
                let keys: BTreeMap<String, String> = keys_obj
                    .iter()
                    .filter_map(|(k, v)| {
                        v.as_str().map(|s| (k.clone(), s.to_string()))
                    })
                    .collect();
                let rc = self.round_crypto(device, round_id, &keys)?;
                // the coordinator must echo OUR posted key back intact —
                // a swapped key would silently redirect our pair masks
                match keys.get(device) {
                    Some(echoed) if *echoed == rc.my_pub_hex => {}
                    Some(_) => {
                        return Err(FedError::Privacy(format!(
                            "round keys echo a different public key for \
                             '{device}' — refusing to mask"
                        )))
                    }
                    None => {
                        return Err(FedError::Privacy(format!(
                            "'{device}' missing from the round key set"
                        )))
                    }
                }
                let seeds: Vec<(i64, [u8; 32])> = peers
                    .iter()
                    .map(|peer| {
                        let sk = rc.shared.get(peer).ok_or_else(|| {
                            FedError::Privacy(format!(
                                "no key posted for peer '{peer}'"
                            ))
                        })?;
                        Ok((
                            masking::pair_sign(device, peer),
                            crate::privacy::keys::pair_seed_from_shared(
                                sk, round_id, device, peer,
                            ),
                        ))
                    })
                    .collect::<Result<_>>()?;
                params = masking::mask_update_with_seeds(
                    &params,
                    weight,
                    &seeds,
                    cfg.frac_bits,
                )?;
            } else {
                // legacy cohort-key round (pre-key-agreement peer)
                let key = self
                    .privacy_secret
                    .lock()
                    .unwrap()
                    .clone()
                    .ok_or_else(|| {
                        FedError::Privacy(format!(
                            "'{device}' has no cohort key for legacy secagg \
                             round"
                        ))
                    })?;
                params = masking::mask_update(
                    &params,
                    weight,
                    device,
                    &peers,
                    &key,
                    round_id,
                    cfg.frac_bits,
                )?;
            }
        }
        Ok(TensorBuf::from_f32_vec(params))
    }

    /// Key-agreement task: post this device's per-round DH public key.
    fn fact_keys(&self, p: &Json) -> Result<Json> {
        let device = Self::device_of(p)?;
        let round_id = Self::round_id_of(p)?;
        let secret = crate::privacy::keys::derive_round_secret(
            &self.client_secret(&device),
            round_id,
            &device,
        );
        let kp = crate::privacy::keys::keypair(&secret);
        Ok(Json::obj()
            .set("pubkey", crate::privacy::keys::pubkey_hex(&kp.public)))
    }

    /// Share-distribution task: Shamir-split this device's round secret
    /// and deal one end-to-end encrypted share per peer, plus a clear
    /// commitment per share so the coordinator can verify later reveals.
    fn fact_shares(&self, p: &Json) -> Result<Json> {
        use crate::privacy::{keys, shamir, to_hex};
        let device = Self::device_of(p)?;
        let round_id = Self::round_id_of(p)?;
        let threshold = p
            .need("threshold")?
            .as_usize()
            .ok_or_else(|| FedError::Privacy("threshold must be a number".into()))?;
        let keys_map: BTreeMap<String, String> = p
            .need("keys")?
            .as_obj()
            .ok_or_else(|| FedError::Privacy("keys must be an object".into()))?
            .iter()
            .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
            .collect();
        if !keys_map.contains_key(&device) {
            return Err(FedError::Privacy(format!(
                "'{device}' missing from the round key set"
            )));
        }
        if keys_map.len() > 255 {
            // GF(256) share x-coordinates are 1-based u8 positions:
            // index 255 would wrap to x = 0 (the secret itself)
            return Err(FedError::Privacy(format!(
                "{} participants exceed the 255-participant limit of \
                 GF(256) share coordinates",
                keys_map.len()
            )));
        }
        let my_secret = keys::derive_round_secret(
            &self.client_secret(&device),
            round_id,
            &device,
        );
        let rc = self.round_crypto(&device, round_id, &keys_map)?;
        // x-coordinates: 1-based index in the sorted key-poster list —
        // self-describing on the wire ([x] ‖ data) but deterministic so
        // dealers and re-dealers agree
        let peers: Vec<(String, u8)> = keys_map
            .keys()
            .enumerate()
            .filter(|(_, n)| *n != &device)
            .map(|(i, n)| (n.clone(), i as u8 + 1))
            .collect();
        let xs: Vec<u8> = peers.iter().map(|(_, x)| *x).collect();
        let mut rng_os;
        let mut rng_det;
        let rng: &mut dyn crate::util::rng::NoiseSource =
            match crate::util::rng::OsRng::new() {
                Ok(r) => {
                    rng_os = r;
                    &mut rng_os
                }
                Err(_) => {
                    rng_det = crate::util::rng::Rng::new(
                        self.noise_nonce ^ round_id,
                    );
                    &mut rng_det
                }
            };
        let split = shamir::split_at(&my_secret, threshold, &xs, rng)?;
        let mut shares = Json::obj();
        let mut commits = Json::obj();
        for (share, (peer, _)) in split.iter().zip(peers.iter()) {
            let sk = rc.shared.get(peer).ok_or_else(|| {
                FedError::Privacy(format!("no shared key with '{peer}'"))
            })?;
            let ct = keys::encrypt_share(
                sk,
                round_id,
                &device,
                peer,
                &share.to_bytes(),
            );
            shares = shares.set(peer, to_hex(&ct));
            commits =
                commits.set(peer, to_hex(&shamir::share_commitment(share)));
        }
        Ok(Json::obj().set("shares", shares).set("commits", commits))
    }

    /// Seed for one (device, round)'s DP noise stream: unique per round
    /// (no noise reuse), but mixed with client-local entropy — and the
    /// cohort key when one is installed — so the coordinator cannot
    /// regenerate the stream from the public device name + round id and
    /// subtract the noise.
    fn noise_seed(&self, device: &str, round_id: u64) -> u64 {
        let mut s = Self::batch_seed(device, 0, round_id) ^ self.noise_nonce;
        if let Some(key) = self.privacy_secret.lock().unwrap().as_ref() {
            let mac =
                crate::util::hmacsha::hmac_sha256(key, b"feddart-dp-noise");
            s ^= u64::from_le_bytes(mac[..8].try_into().unwrap());
        }
        splitmix64(s)
    }

    /// Dropout-recovery task: reveal this device's pair seeds with the
    /// listed dropped peers, and — when the round ran per-pair key
    /// agreement — the decrypted Shamir shares of each dropped dealer's
    /// round secret, so any `t` responsive survivors suffice for the
    /// coordinator to reconstruct the missing masks.
    fn fact_reveal(&self, p: &Json) -> Result<Json> {
        use crate::privacy::{from_hex, keys, masking, to_hex};
        let device = Self::device_of(p)?;
        let round_id = Self::round_id_of(p)?;
        let dropped: Vec<String> = p
            .need("dropped")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|d| d.as_str().map(String::from))
            .filter(|d| *d != device)
            .collect();
        if let Some(keys_obj) = p.get("keys").and_then(Json::as_obj) {
            // key-agreement round: derive the pair seed with each dropped
            // peer from the DH shared key, and decrypt the dealer shares
            // the coordinator relayed to us
            let keys_map: BTreeMap<String, String> = keys_obj
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect();
            let rc = self.round_crypto(&device, round_id, &keys_map)?;
            let mut seeds = Json::obj();
            let mut shares_out = Json::obj();
            for d in &dropped {
                let Some(sk) = rc.shared.get(d) else {
                    continue; // dealer never posted a key: nothing to reveal
                };
                seeds = seeds.set(
                    d,
                    to_hex(&keys::pair_seed_from_shared(
                        sk, round_id, &device, d,
                    )),
                );
                if let Some(ct_hex) =
                    p.get("shares").and_then(|s| s.get(d)).and_then(Json::as_str)
                {
                    let plain = keys::decrypt_share(
                        sk,
                        round_id,
                        d,
                        &device,
                        &from_hex(ct_hex)?,
                    )?;
                    shares_out = shares_out.set(d, to_hex(&plain));
                }
            }
            return Ok(Json::obj().set("seeds", seeds).set("shares", shares_out));
        }
        // legacy cohort-key round
        let key = self
            .privacy_secret
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| {
                FedError::Privacy(format!("'{device}' has no cohort key to reveal"))
            })?;
        let mut seeds = Json::obj();
        for name in &dropped {
            seeds = seeds.set(
                name,
                to_hex(&masking::pair_seed(&key, round_id, &device, name)),
            );
        }
        Ok(Json::obj().set("seeds", seeds))
    }

    fn fact_evaluate(&self, p: &Json) -> Result<Json> {
        let device = Self::device_of(p)?;
        let model = p.need("model")?.as_str().unwrap_or("").to_string();
        let params = Self::params_of(p)?;
        let local = self.local(&device)?;

        if let Some(rest) = model.strip_prefix("linear_") {
            let (dim, classes) = parse_linear_dims(rest)?;
            let LocalData::Supervised { test, .. } = local.as_ref() else {
                return Err(FedError::Fact("linear model needs supervised data".into()));
            };
            let (loss_sum, correct) = LinearModel::evaluate(
                params.as_f32_slice(),
                &test.x,
                &test.y,
                dim,
                classes,
            );
            return Ok(Json::obj()
                .set("loss_sum", loss_sum)
                .set("correct", correct)
                .set("n", test.n()));
        }

        let meta = self.engine.manifest().model(&model)?.clone();
        let eval_entry = meta.entry("eval")?.to_string();
        match (meta.kind.as_str(), local.as_ref()) {
            ("mlp", LocalData::Supervised { test, .. }) => {
                let be = meta.field_usize("eval_batch")?;
                let d = meta.field_usize("in_dim")?;
                // fixed deterministic eval sample (seed 0) of one eval batch
                let (x, y) = test.sample_batch(Self::batch_seed(&device, 0, u64::MAX), be);
                let out = self.engine.execute(
                    &eval_entry,
                    vec![
                        Tensor::vec_f32(params.to_vec()),
                        Tensor::with_shape_f32(vec![be, d], x)?,
                        Tensor::with_shape_i32(vec![be], y)?,
                    ],
                )?;
                Ok(Json::obj()
                    .set("loss_sum", out[0].scalar()?)
                    .set("correct", out[1].scalar()?)
                    .set("n", be))
            }
            ("transformer", LocalData::Corpus(corpus)) => {
                let be = meta.field_usize("eval_batch")?;
                let s_len = meta.field_usize("seq")?;
                let toks = corpus.sample_windows(
                    Self::batch_seed(&device, 0, u64::MAX),
                    be,
                    s_len,
                );
                let out = self.engine.execute(
                    &eval_entry,
                    vec![
                        Tensor::vec_f32(params.to_vec()),
                        Tensor::with_shape_i32(vec![be, s_len + 1], toks)?,
                    ],
                )?;
                Ok(Json::obj()
                    .set("loss_sum", out[0].scalar()?)
                    .set("ntok", out[1].scalar()?)
                    .set("n", be))
            }
            (kind, _) => Err(FedError::Fact(format!(
                "model kind '{kind}' incompatible with local data of '{device}'"
            ))),
        }
    }
}

fn parse_linear_dims(rest: &str) -> Result<(usize, usize)> {
    let (d, c) = rest
        .split_once('x')
        .ok_or_else(|| FedError::Fact(format!("bad linear model name '{rest}'")))?;
    Ok((
        d.parse()
            .map_err(|_| FedError::Fact("bad linear dim".into()))?,
        c.parse()
            .map_err(|_| FedError::Fact("bad linear classes".into()))?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fact::aggregation::Aggregation;
    use crate::fact::data::{synthesize, SyntheticConfig};
    use crate::fact::model::{FactModel, Hyper};
    use crate::runtime::default_artifacts_dir;

    fn runtime_with_data() -> Option<(Arc<FactClientRuntime>, Vec<String>)> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let engine = Engine::load(&dir, 1).unwrap();
        let rt = FactClientRuntime::new(engine);
        let data = synthesize(&SyntheticConfig {
            clients: 2,
            samples_per_client: 128,
            dim: 8,
            classes: 4,
            ..Default::default()
        })
        .unwrap();
        let names: Vec<String> = data.keys().cloned().collect();
        for (name, d) in data {
            rt.add_supervised(&name, d);
        }
        Some((rt, names))
    }

    #[test]
    fn linear_learn_evaluate_cycle_no_engine_models() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let (rt, names) = runtime_with_data().unwrap();
        let m = LinearModel::new(8, 4, Aggregation::WeightedFedAvg);
        let global = m.init_params(0).unwrap();
        let hp = Hyper { lr: 0.3, mu: 0.0, local_steps: 5, round: 0 };
        let p = m
            .learn_params(&global, &hp)
            .set("_device", names[0].as_str());
        let out = rt.fact_learn(&p).unwrap();
        let u = m.parse_update(&names[0], 0.1, &out).unwrap();
        assert_eq!(u.params.len(), m.param_count());
        assert!(u.loss.is_finite());
        assert!(u.n_samples > 0.0);

        let pe = m
            .eval_params_buf(&u.params)
            .set("_device", names[0].as_str());
        let ev = rt.fact_evaluate(&pe).unwrap();
        assert!(ev.get("loss_sum").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn mlp_learn_reduces_loss_over_rounds() {
        let Some((rt, names)) = runtime_with_data() else { return };
        let m = crate::fact::model::HloModel::new(
            rt.engine(),
            "mlp_tiny",
            Aggregation::WeightedFedAvg,
        )
        .unwrap();
        let mut global = m.init_params(1).unwrap();
        let mut first = None;
        let mut last = 0.0f32;
        for round in 0..8 {
            let hp = Hyper { lr: 0.5, mu: 0.0, local_steps: 4, round };
            let p = m
                .learn_params(&global, &hp)
                .set("_device", names[0].as_str());
            let out = rt.fact_learn(&p).unwrap();
            let u = m.parse_update(&names[0], 0.0, &out).unwrap();
            global = u.params.to_vec();
            first = first.or(Some(u.loss));
            last = u.loss;
        }
        assert!(
            last < first.unwrap(),
            "loss did not decrease: {first:?} -> {last}"
        );
    }

    #[test]
    fn init_validates_model_and_data() {
        let Some((rt, names)) = runtime_with_data() else { return };
        let ok = rt.fact_init(
            &Json::obj()
                .set("model", "mlp_tiny")
                .set("_device", names[0].as_str()),
        );
        assert!(ok.is_ok());
        let bad_model = rt.fact_init(
            &Json::obj()
                .set("model", "no_such")
                .set("_device", names[0].as_str()),
        );
        assert!(bad_model.is_err());
        let bad_device = rt.fact_init(
            &Json::obj().set("model", "mlp_tiny").set("_device", "stranger"),
        );
        assert!(bad_device.is_err());
    }

    #[test]
    fn batch_seeds_differ_by_device_round_step() {
        let a = FactClientRuntime::batch_seed("client-0", 1, 0);
        let b = FactClientRuntime::batch_seed("client-1", 1, 0);
        let c = FactClientRuntime::batch_seed("client-0", 2, 0);
        let d = FactClientRuntime::batch_seed("client-0", 1, 1);
        assert!(a != b && a != c && a != d);
        assert_eq!(a, FactClientRuntime::batch_seed("client-0", 1, 0));
    }

    #[test]
    fn parse_linear_dims_cases() {
        assert_eq!(parse_linear_dims("32x10").unwrap(), (32, 10));
        assert!(parse_linear_dims("32").is_err());
        assert!(parse_linear_dims("ax2").is_err());
    }
}
