//! Pluggable local-training strategies (the `LocalStrategy` seam).
//!
//! Generalizes the hardcoded FedProx path: the strategy is negotiated
//! into every `fact_learn` task dict (the client runtime reads the
//! `strategy` field and adjusts its local loop), and the weighted merge
//! in `fact::aggregation` applies the matching server-side correction.
//!
//! * [`LocalStrategy::Plain`] — local SGD as configured by `Hyper`
//!   (including a nonzero `--mu`, the backward-compatible FedProx knob).
//! * [`LocalStrategy::FedProx`] — proximal term `mu/2 * ||w - w_g||^2`
//!   added to every local step (Li et al. 2020); overrides `Hyper::mu`.
//! * [`LocalStrategy::FedNova`] — normalized averaging (Wang et al.
//!   2020): each client divides its accumulated delta by its effective
//!   local step count `tau` and reports `tau`; the server re-scales the
//!   merged delta by the weighted mean `tau`, removing the objective
//!   inconsistency of heterogeneous local epochs.

use crate::error::{FedError, Result};

/// The client-side training variant negotiated for a session.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LocalStrategy {
    /// Local SGD exactly as `Hyper` configures it.
    Plain,
    /// FedProx with the given proximal coefficient.
    FedProx {
        /// Proximal term weight (overrides `Hyper::mu`).
        mu: f32,
    },
    /// FedNova normalized averaging.
    FedNova,
}

impl Default for LocalStrategy {
    fn default() -> Self {
        LocalStrategy::Plain
    }
}

impl LocalStrategy {
    /// Stable lowercase name shipped in the learn dict and echoed in
    /// round records / round status.
    pub fn name(&self) -> &'static str {
        match self {
            LocalStrategy::Plain => "plain",
            LocalStrategy::FedProx { .. } => "fedprox",
            LocalStrategy::FedNova => "fednova",
        }
    }

    /// True when clients must tau-normalize their deltas and the merge
    /// must re-scale (see `fact::aggregation::fednova_rescale`).
    pub fn is_fednova(&self) -> bool {
        matches!(self, LocalStrategy::FedNova)
    }

    /// Parse a `--local-strategy` spec:
    /// `plain` | `fedprox[:mu]` (default `0.01`) | `fednova`.
    pub fn parse(spec: &str) -> Result<LocalStrategy> {
        let (name, arg) = match spec.split_once(':') {
            Some((n, a)) => (n.trim(), Some(a.trim())),
            None => (spec.trim(), None),
        };
        match (name, arg) {
            ("plain" | "", None) => Ok(LocalStrategy::Plain),
            ("fedprox", None) => Ok(LocalStrategy::FedProx { mu: 0.01 }),
            ("fedprox", Some(a)) => {
                let mu = a.parse::<f32>().map_err(|_| {
                    FedError::Config(format!(
                        "--local-strategy '{spec}': '{a}' is not a number"
                    ))
                })?;
                if !(mu >= 0.0) || !mu.is_finite() {
                    return Err(FedError::Config(format!(
                        "--local-strategy '{spec}': mu must be finite and >= 0"
                    )));
                }
                Ok(LocalStrategy::FedProx { mu })
            }
            ("fednova", None) => Ok(LocalStrategy::FedNova),
            _ => Err(FedError::Config(format!(
                "unknown --local-strategy '{spec}' \
                 (expected plain|fedprox[:mu]|fednova)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(LocalStrategy::parse("plain").expect("p"), LocalStrategy::Plain);
        assert_eq!(
            LocalStrategy::parse("fedprox").expect("fp"),
            LocalStrategy::FedProx { mu: 0.01 }
        );
        assert_eq!(
            LocalStrategy::parse("fedprox:0.1").expect("fp01"),
            LocalStrategy::FedProx { mu: 0.1 }
        );
        assert_eq!(
            LocalStrategy::parse("fednova").expect("fn"),
            LocalStrategy::FedNova
        );
        assert!(LocalStrategy::parse("scaffold").is_err());
        assert!(LocalStrategy::parse("fedprox:-1").is_err());
        assert!(LocalStrategy::parse("fednova:2").is_err());
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(LocalStrategy::Plain.name(), "plain");
        assert_eq!(LocalStrategy::FedProx { mu: 0.5 }.name(), "fedprox");
        assert_eq!(LocalStrategy::FedNova.name(), "fednova");
        assert!(LocalStrategy::FedNova.is_fednova());
        assert!(!LocalStrategy::Plain.is_fednova());
    }
}
