//! The layered round pipeline behind [`FactServer::learn`].
//!
//! [`FactServer::learn`]: crate::fact::server::FactServer::learn
//!
//! `fact::server` owns *session* orchestration — device pools, model
//! negotiation, clustering, checkpointing, the DP ledger, recovery.
//! Everything that happens *inside one federated round* lives here,
//! split into three layers:
//!
//! * [`ctx`] — the typed [`RoundCtx`](ctx::RoundCtx) every stage
//!   consumes: one bundle of per-session invariants (workflow manager,
//!   hyper-parameters, privacy config, round store, telemetry, ...).
//! * [`phases`] — the named stages: cohort draw/repair, secagg setup
//!   (keys → shares), learn dispatch + quorum wait, reveal/unmask,
//!   aggregate + apply.  Each stage appends its transition to the round
//!   store and emits exactly one span of the fixed phase taxonomy.
//! * [`pipeline`] — the driver that sequences the stages per round,
//!   fresh or resumed, following the round-store state machine
//!   (`Configured → Keys → Shares → Learn → Reveal → Aggregated →
//!   Closed/Voided`).
//!
//! Two public seams parameterize the pipeline:
//!
//! * [`optimizer::ServerOptimizer`] — the server-side update rule
//!   applied to each round's aggregate (plain replacement, FedAvgM,
//!   FedAdam).  Its state is persisted inside the `Aggregated` event,
//!   so crash recovery at that phase is exact even under a stateful
//!   optimizer.
//! * [`strategy::LocalStrategy`] — the client-side training variant
//!   negotiated into every learn dict (plain, FedProx, FedNova
//!   normalized averaging).

pub(crate) mod ctx;
pub mod optimizer;
pub(crate) mod phases;
pub(crate) mod pipeline;
pub mod strategy;
