//! The named stages of one federated round: cohort draw/repair, secagg
//! setup (keys → shares), learn dispatch + quorum wait, reveal/unmask,
//! aggregate, and apply.  Every stage consumes the typed
//! [`RoundCtx`](super::ctx::RoundCtx), appends its transition to the
//! round store, and emits exactly one span of the fixed phase taxonomy
//! (`telemetry::phase`) — the pipeline driver in `super::pipeline`
//! sequences them.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use crate::coordinator::latency::effective_deadline_explained;
use crate::coordinator::participation::{
    participation_round_key, Candidate, CohortSampler,
};
use crate::coordinator::round_store::{
    now_ms, EventKind, RoundEvent, StoredUpdate,
};
use crate::coordinator::workflow::RoundClose;
use crate::error::{FedError, Result};
use crate::fact::aggregation::ClientUpdate;
use crate::fact::model::Hyper;
use crate::fact::rounds::ctx::RoundCtx;
use crate::fact::rounds::optimizer::ServerOptimizer;
use crate::fact::rounds::strategy::LocalStrategy;
use crate::fact::server::{RoundRecord, SecAggAudit};
use crate::json::Json;
use crate::privacy::secagg::{unmask_aggregate, MaskedUpdate, RevealedSeed};
use crate::privacy::{
    from_hex, keys, resolve_reveal_threshold, round_id_to_hex, seed_from_hex,
    shamir, PrivacyMode, RevealPolicy,
};
use crate::telemetry::{self, phase};
use crate::util::rng::splitmix64;
use crate::util::Stopwatch;

/// Draw this round's cohort (everyone, without participation sampling).
pub(crate) fn draw_cohort(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    round: usize,
    seen_samples: &BTreeMap<String, f64>,
) -> (Vec<String>, f64, Option<CohortSampler>) {
    match ctx.participation {
        Some(p) => {
            let sampler = CohortSampler::new(p.clone());
            let key = participation_round_key(
                p.seed,
                ctx.clustering_round,
                cluster.id,
                round,
            );
            let candidates: Vec<Candidate> = cluster
                .clients
                .iter()
                .map(|n| Candidate {
                    name: n.clone(),
                    weight: seen_samples
                        .get(n)
                        .or_else(|| ctx.known_samples.get(n))
                        .copied()
                        .unwrap_or(1.0)
                        .max(1.0),
                })
                .collect();
            let cohort = sampler.sample(key, &candidates);
            let q = sampler.amplification_rate(cohort.len(), cluster.clients.len());
            (cohort, q, Some(sampler))
        }
        None => (cluster.clients.clone(), 1.0, None),
    }
}

/// Salt mixed into the round key for the repair draw, so a repaired
/// round's replacement order never correlates with its cohort draw.
const REPAIR_SALT: u64 = 0x5e1f_4ea1_1e55_0007;

/// In-round cohort repair: replace cohort members the scheduler already
/// knows are dead (lease expired / never connected) with fresh draws
/// from the cluster's unsampled pool — inside the same round, before any
/// setup phase addressed the dead.
///
/// The deterministic replacement draw is keyed off the round key + a
/// salt, so a resumed coordinator repairs identically.  Presumed-dead
/// members are dropped from the addressed cohort (both the selector and
/// the scheduler reject tasks addressing a disconnected client — a dead
/// member kept addressed would reject the whole learn task) and
/// replacements take their slots; a presumed-dead client that revives
/// mid-round re-registers and is eligible for the next draw.  The
/// realized sampling rate only ever grows — the DP accountant charges
/// the conservative effective inclusion probability of the UNION of the
/// original draw and the repair draw (anyone in either set could have
/// been addressed).
///
/// Legality is enforced by the round state machine: `CohortRepaired`
/// appends only in `Configured`/`Keys`, i.e. any time in clear/dp modes
/// but strictly before share dealing under secagg (after `SharesDealt`
/// the threshold-reveal path recovers dropouts instead).
pub(crate) fn repair_cohort(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    round: usize,
    round_id: u64,
    cohort: Vec<String>,
    realized_q: f64,
    sampler: Option<&CohortSampler>,
) -> Result<(Vec<String>, f64)> {
    let (Some(p), Some(sampler)) = (ctx.participation.as_ref(), sampler) else {
        // full participation: everyone is already addressed, there is no
        // unsampled pool to draw replacements from
        return Ok((cohort, realized_q));
    };
    let Ok(alive) = ctx.wm.get_all_device_names() else {
        return Ok((cohort, realized_q));
    };
    let alive: BTreeSet<&String> = alive.iter().collect();
    let presumed_dead: Vec<String> = cohort
        .iter()
        .filter(|c| !alive.contains(c))
        .cloned()
        .collect();
    if presumed_dead.is_empty() {
        return Ok((cohort, realized_q));
    }
    let in_cohort: BTreeSet<&String> = cohort.iter().collect();
    // candidates: alive cluster members the draw skipped, ranked by a
    // salted per-round hash (deterministic, uncorrelated with the draw)
    let key = splitmix64(
        participation_round_key(p.seed, ctx.clustering_round, cluster.id, round)
            ^ REPAIR_SALT,
    );
    let mut pool: Vec<(u64, String)> = cluster
        .clients
        .iter()
        .filter(|c| !in_cohort.contains(c) && alive.contains(c))
        .map(|c| (splitmix64(key ^ crate::util::rng::fnv1a(c)), c.clone()))
        .collect();
    pool.sort();
    let replacements: Vec<String> = pool
        .into_iter()
        .take(presumed_dead.len())
        .map(|(_, c)| c)
        .collect();
    if replacements.is_empty() {
        log::warn!(target: "fact::server",
            "cluster {} round {round}: {} cohort member(s) presumed dead \
             but no alive replacements remain in the pool; proceeding \
             with the survivors",
            cluster.id, presumed_dead.len());
    }
    // union of both draws — the conservative set the accountant charges
    let union = cohort.len() + replacements.len();
    let mut repaired: Vec<String> = cohort
        .into_iter()
        .filter(|c| alive.contains(c))
        .collect();
    repaired.extend(replacements.iter().cloned());
    repaired.sort();
    repaired.dedup();
    if repaired.is_empty() {
        // every member dead and no replacements: leave the round to fail
        // at dispatch with the backend's own (clearer) error
        return Err(FedError::Task(format!(
            "cluster {} round {round}: entire cohort presumed dead and no \
             alive replacements remain",
            cluster.id
        )));
    }
    let q = realized_q
        .max(sampler.amplification_rate(union, cluster.clients.len()));
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::CohortRepaired {
            presumed_dead: presumed_dead.clone(),
            replacements: replacements.clone(),
            cohort: repaired.clone(),
            sample_rate: q,
        },
    ))?;
    ctx.metrics.counter("fact.round.repaired").inc();
    ctx.metrics
        .counter("fact.round.replacements")
        .add(replacements.len() as u64);
    telemetry::event(
        "cohort_repaired",
        &[
            ("presumed_dead", &presumed_dead.join(",")),
            ("replacements", &replacements.join(",")),
            ("q", &format!("{q:.4}")),
        ],
    );
    log::info!(target: "fact::server",
        "cluster {} round {round}: repaired cohort in-round — {} presumed \
         dead ({:?}), {} replacement(s) drawn ({:?}), q {:.3} -> {:.3}",
        cluster.id, presumed_dead.len(), presumed_dead,
        replacements.len(), replacements, realized_q, q);
    Ok((repaired, q))
}

/// Dispatch the learn tasks of one round and close the collection.
/// `LearnDispatched` is persisted before the scheduler call and
/// `LearnClosed` (with every collected update) after — a crash in
/// between resumes by re-dispatching with the remaining deadline; a
/// crash after resumes from the persisted updates without touching the
/// clients again.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dispatch_learn(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    round: usize,
    round_id: u64,
    cohort: &[String],
    sampler: Option<&CohortSampler>,
    global: &crate::util::tensorbuf::TensorBuf,
    secagg_setup: Option<&SecAggSetup>,
    deadline_override: Option<Duration>,
) -> Result<(Vec<ClientUpdate>, usize, usize, usize)> {
    let dsw = Stopwatch::start();
    let dspan = telemetry::child_of_current(phase::LEARN_DISPATCH);
    let dguard = dspan.enter();
    let mut hp = Hyper { round: round as u64, ..ctx.hyper.clone() };
    // the negotiated local strategy overrides the legacy `--mu` knob
    // (a plain strategy keeps Hyper::mu for backward compatibility)
    if let LocalStrategy::FedProx { mu } = ctx.strategy {
        hp.mu = mu;
    }
    let privacy_round = if ctx.privacy.mode == PrivacyMode::Off {
        None
    } else {
        let mut pj = ctx
            .privacy
            .to_json()
            .set("round_id", round_id_to_hex(round_id));
        if ctx.participation.is_some() {
            // pin the sampled cohort in the task: a client outside it
            // must refuse to contribute, or the accountant's
            // amplification claim (only sampled clients respond) would
            // be unsound
            pj = pj.set(
                "cohort",
                Json::Arr(cohort.iter().map(|c| Json::Str(c.clone())).collect()),
            );
        }
        if let Some(setup) = secagg_setup {
            pj = pj
                .set(
                    "participants",
                    Json::Arr(
                        setup
                            .participants
                            .iter()
                            .map(|c| Json::Str(c.clone()))
                            .collect(),
                    ),
                )
                .set("keys", setup.keys_json.clone())
                .set("weighted", cluster.model.aggregation().is_weighted());
        }
        Some(pj)
    };
    // under secagg, only the key+share completers can mask: they are
    // the round's addressed set
    let addressed: &[String] = match secagg_setup {
        Some(setup) => &setup.participants,
        None => cohort,
    };
    // one child span per addressed client: opened at dispatch, closed
    // when the collection closes with the client's outcome.  Its context
    // rides the task params (`trace` key), so the client runtime's timed
    // `fact_learn` span echoes back into the same trace via `_span`.
    let mut client_spans: BTreeMap<String, telemetry::Span> = addressed
        .iter()
        .map(|c| {
            let mut s = telemetry::child_of_current(phase::CLIENT_LEARN);
            s.set_attr("client", c);
            (c.clone(), s)
        })
        .collect();
    let dict: BTreeMap<String, Json> = addressed
        .iter()
        .map(|c| {
            let mut params = cluster
                .model
                .learn_params_buf(global, &hp)
                .set("strategy", ctx.strategy.name());
            if let Some(pj) = &privacy_round {
                params = params.set("privacy", pj.clone());
            }
            params = telemetry::inject(
                params,
                client_spans.get(c).and_then(telemetry::Span::context),
            );
            (c.clone(), params)
        })
        .collect();
    let sampled = dict.len();
    // the effective deadline of THIS dispatch: on resume, the remaining
    // window of the original deadline; otherwise the configured one —
    // which under an adaptive mode is the tracked cohort latency
    // percentile × margin, clamped, once the tracker is warm
    let deadline = match (deadline_override, ctx.participation) {
        (Some(d), _) => Some(d),
        (None, Some(p)) => {
            let d = effective_deadline_explained(ctx.latency, p, addressed);
            telemetry::event(
                "deadline_decision",
                &[
                    ("deadline_ms", &d.deadline_ms.to_string()),
                    ("adaptive", if d.adaptive { "true" } else { "false" }),
                    ("quantile", &format!("{:.2}", d.quantile)),
                    (
                        "observed_ms",
                        &d.observed_ms
                            .map(|v| v.to_string())
                            .unwrap_or_else(|| "cold".into()),
                    ),
                    ("tracker_len", &d.tracker_len.to_string()),
                    ("cohort", &addressed.len().to_string()),
                ],
            );
            let (ms, adaptive) = (d.deadline_ms, d.adaptive);
            if adaptive {
                ctx.metrics.counter("fact.round.adaptive_closes").inc();
                ctx.metrics
                    .counter("fact.round.deadline_adaptive_ms")
                    .add(ms);
                ctx.metrics
                    .gauge("fact.round.deadline_effective_ms")
                    .set(ms as i64);
                log::debug!(target: "fact::server",
                    "cluster {} round {round}: adaptive deadline {ms}ms \
                     ({} × {:.2}, clamp [{}, {}])",
                    cluster.id, p.deadline.as_str(), p.deadline_margin,
                    p.deadline_min_ms, p.deadline_max_ms);
            }
            if ms > 0 {
                Some(Duration::from_millis(ms))
            } else {
                None
            }
        }
        _ => None,
    };
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::LearnDispatched {
            addressed: addressed.to_vec(),
            dispatched_at_ms: now_ms(),
            deadline_ms: deadline.map(|d| d.as_millis() as u64).unwrap_or(0),
        },
    ))?;
    drop(dguard);
    ctx.phase_ms(phase::LEARN_DISPATCH, cluster.id, dsw.elapsed_ms());
    dspan.finish();
    // the collection window: the scheduler call blocks here until
    // complete/quorum/deadline — workflow.rs attaches its `quorum_close`
    // event to this span via the thread-local context
    let qsw = Stopwatch::start();
    let qspan = telemetry::child_of_current(phase::QUORUM_WAIT);
    let qguard = qspan.enter();
    let (results, late_names, dropped) = match (sampler, ctx.participation) {
        (Some(sampler), Some(p)) => {
            // production round loop: close at quorum or deadline,
            // drop (and count) stragglers
            let quorum = sampler.quorum_count(sampled);
            let deadline = deadline.unwrap_or(ctx.timeout);
            let out = ctx.wm.run_task_quorum(
                dict,
                "fact_learn",
                quorum,
                deadline,
                Duration::from_millis(p.late_grace_ms),
            )?;
            // feed the adaptive-deadline tracker: completers with their
            // client-reported compute time when they report one (so
            // coordinator-side queueing cannot inflate the percentile),
            // falling back to the round-trip duration; everyone else
            // censored at the close (their true latency is at least the
            // elapsed window)
            let reported: BTreeSet<&String> =
                out.results.iter().map(|r| &r.device_name).collect();
            for r in &out.results {
                let total_ms = (r.duration * 1_000.0).round() as u64;
                let compute_ms = r
                    .result
                    .get("compute_s")
                    .and_then(Json::as_f64)
                    .map(|s| (s * 1_000.0).round() as u64);
                if let Some(c) = compute_ms {
                    ctx.metrics
                        .histogram("fact.client.queue_ms")
                        .observe(total_ms.saturating_sub(c) as f64);
                }
                ctx.latency.observe_round(&r.device_name, total_ms, compute_ms);
            }
            for name in addressed.iter().filter(|d| !reported.contains(*d)) {
                ctx.latency.observe_censored(name, out.elapsed_ms.max(1));
            }
            let late = out.late;
            let dropped = sampled.saturating_sub(out.results.len() + late.len());
            ctx.metrics
                .counter(match out.close {
                    RoundClose::Complete => "fact.participation.complete_closes",
                    RoundClose::Quorum => "fact.participation.quorum_closes",
                    RoundClose::Deadline => "fact.participation.deadline_closes",
                    RoundClose::Settled => "fact.participation.settled_closes",
                })
                .inc();
            if out.results.len() < quorum {
                log::warn!(target: "fact::server",
                    "cluster {} round {round}: closed below quorum \
                     ({}/{quorum} of {sampled} sampled)",
                    cluster.id, out.results.len());
            }
            (out.results, late, dropped)
        }
        _ => {
            let results = ctx.wm.run_task(
                dict,
                "fact_learn",
                deadline_override.unwrap_or(ctx.timeout),
            )?;
            let dropped = sampled.saturating_sub(results.len());
            (results, Vec::new(), dropped)
        }
    };
    drop(qguard);
    ctx.phase_ms(phase::QUORUM_WAIT, cluster.id, qsw.elapsed_ms());
    qspan.finish();
    // pull each client's echoed `fact_learn` span into the trace, then
    // close the coordinator-side client spans with their outcome
    for r in &results {
        telemetry::absorb_echo(ctx.tele, &r.result, round_id);
    }
    for (name, mut span) in client_spans {
        if let Some(r) = results.iter().find(|r| r.device_name == name) {
            span.set_attr("outcome", "ok");
            ctx.metrics
                .histogram_labeled("fact.client.learn_ms", &[("client", &name)])
                .observe(r.duration * 1000.0);
        } else if late_names.contains(&name) {
            span.set_attr("outcome", "late");
        } else {
            span.set_attr("outcome", "dropped");
        }
        span.finish();
    }
    ctx.metrics
        .counter("fact.participation.sampled")
        .add(sampled as u64);
    ctx.metrics
        .counter("fact.participation.reported")
        .add(results.len() as u64);
    ctx.metrics
        .counter("fact.participation.late")
        .add(late_names.len() as u64);
    ctx.metrics
        .counter("fact.participation.dropped")
        .add(dropped as u64);
    if results.is_empty() {
        return Err(FedError::Fact(format!(
            "cluster {}: no client returned a result in round {round}",
            cluster.id
        )));
    }
    // Alg 5 line 5: fetch updated parameters and aggregate.
    let mut updates: Vec<ClientUpdate> = results
        .iter()
        .map(|r| cluster.model.parse_update(&r.device_name, r.duration, &r.result))
        .collect::<Result<Vec<_>>>()?;
    // deterministic aggregation order regardless of arrival order:
    // f32 reduction is order-sensitive, and mode parity (E6) demands
    // bit-identical results between test mode and the TCP path
    updates.sort_by(|a, b| a.device.cmp(&b.device));
    let late = late_names.len();
    // the addressed clients that never delivered a counted result, by
    // name — the recovery path reports them in the audit trail
    let responded: BTreeSet<&String> =
        results.iter().map(|r| &r.device_name).collect();
    let dropped_names: Vec<String> = addressed
        .iter()
        .filter(|d| !responded.contains(*d) && !late_names.contains(*d))
        .cloned()
        .collect();
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::LearnClosed {
            updates: updates
                .iter()
                .map(|u| StoredUpdate {
                    device: u.device.clone(),
                    params: u.params.clone(),
                    n_samples: u.n_samples,
                    loss: u.loss,
                    duration: u.duration,
                    tau: u.tau,
                })
                .collect(),
            late,
            dropped: dropped_names,
        },
    ))?;
    Ok((updates, sampled, late, dropped))
}

/// The tail of a round: recover the aggregate (under secagg), apply the
/// server optimizer, and persist the outcome — `Revealed` + `Aggregated`
/// + `Closed` on success, or `Voided` when the reveal policy `proceed`
/// abandons an unrecoverable round.  The `Aggregated` event pins the
/// post-apply parameters *and* the post-apply optimizer state, so
/// resuming AT that phase is exact even under a stateful optimizer.
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_round(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    round: usize,
    round_id: u64,
    realized_q: f64,
    sampled: usize,
    late: usize,
    dropped: usize,
    secagg_setup: Option<&SecAggSetup>,
    updates: Vec<ClientUpdate>,
    sw: Stopwatch,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    let agg_sw = Stopwatch::start();
    let (target, secagg_audit) = if let Some(setup) = secagg_setup {
        let out = secagg_recover_aggregate(ctx, cluster, setup, &updates, round_id)?;
        ctx.store.append(RoundEvent::new(
            round_id,
            EventKind::Revealed { audit: out.audit.to_json() },
        ))?;
        (out.target, Some(out.audit))
    } else {
        // clear/dp aggregation shares the unmask phase name: same slot
        // in the span taxonomy, no masks to fold (mode=clear)
        let mut span = telemetry::child_of_current(phase::UNMASK_AGGREGATE);
        span.set_attr("mode", "clear");
        let _g = span.enter();
        let psw = Stopwatch::start();
        let target = cluster.model.aggregate(&updates, Some(ctx.pool))?;
        ctx.phase_ms(phase::UNMASK_AGGREGATE, cluster.id, psw.elapsed_ms());
        (Some(target), None)
    };
    // FedNova: clients reported tau-normalized deltas; re-scale the
    // merged delta by the weighted effective step count before the
    // optimizer sees it
    let target = target.map(|mut t| {
        if ctx.strategy.is_fednova() {
            crate::fact::aggregation::fednova_rescale(
                &mut t,
                &cluster.params,
                &updates,
                ctx.hyper.local_steps as f32,
            );
        }
        t
    });
    let asw = Stopwatch::start();
    let mut aspan = telemetry::child_of_current(phase::APPLY);
    let aguard = aspan.enter();
    let applied = match target {
        Some(target) => {
            let mut state = std::mem::take(&mut cluster.opt_state);
            ctx.server_opt.apply(&mut cluster.params, target, &mut state);
            cluster.opt_state = state;
            true
        }
        None => {
            // reveal policy `proceed`: the round is unrecoverable
            // below the share threshold — void it (parameters
            // unchanged), audit it, keep training
            ctx.metrics.counter("fact.secagg.rounds_voided").inc();
            log::warn!(target: "fact::server",
                "cluster {} round {round}: secagg recovery below \
                 threshold, policy=proceed voids the round",
                cluster.id);
            false
        }
    };
    let agg_ms = agg_sw.elapsed_ms();

    let mean_loss =
        updates.iter().map(|u| u.loss).sum::<f32>() / updates.len() as f32;
    let mean_client_s =
        updates.iter().map(|u| u.duration).sum::<f64>() / updates.len() as f64;
    cluster.loss_history.push(mean_loss);
    for u in &updates {
        // n_samples is clear even under secagg (the protocol ships it
        // alongside the masked vector); it feeds weighted sampling
        seen_samples.insert(u.device.clone(), u.n_samples as f64);
    }
    if !ctx.privacy.mode.has_secagg() {
        // under secagg the per-client vectors are masked lattice noise
        // — recording them would feed garbage to the clustering input
        for u in &updates {
            latest.insert(u.device.clone(), u.params.to_vec());
        }
    }
    let record = RoundRecord {
        clustering_round: ctx.clustering_round,
        cluster_id: cluster.id,
        round,
        n_clients: updates.len(),
        sampled,
        late,
        dropped,
        sample_rate: realized_q,
        mean_loss,
        round_ms: sw.elapsed_ms(),
        agg_ms,
        mean_client_s,
        secagg: secagg_audit,
        server_opt: ctx.server_opt.name().to_string(),
        local_strategy: ctx.strategy.name().to_string(),
    };
    if applied {
        // pin the post-apply params + optimizer state + the audit
        // record, then close — a crash between the two appends resumes
        // at Aggregated, where fast-forwarding is an idempotent
        // replacement (of both params and optimizer buffers)
        ctx.store.append(RoundEvent::new(
            round_id,
            EventKind::Aggregated {
                params: crate::util::tensorbuf::TensorBuf::from_f32_slice(
                    &cluster.params,
                ),
                record: record.to_json(),
                opt_state: cluster.opt_state.to_json(),
            },
        ))?;
        ctx.store
            .append(RoundEvent::new(round_id, EventKind::Closed))?;
    } else {
        ctx.store.append(RoundEvent::new(
            round_id,
            EventKind::Voided {
                reason: "secagg recovery below threshold (reveal policy \
                         proceed)"
                    .into(),
                record: record.to_json(),
            },
        ))?;
    }
    drop(aguard);
    aspan.set_attr("applied", applied);
    ctx.phase_ms(phase::APPLY, cluster.id, asw.elapsed_ms());
    aspan.finish();
    log::debug!(target: "fact::server",
        "cluster {} round {round}: loss {mean_loss:.4} \
         ({}/{sampled} sampled clients, {:.1}ms)",
        cluster.id, record.n_clients, sw.elapsed_ms());
    records.push(record);
    Ok(())
}

/// The artifacts of a round's secagg setup phases: who completed key
/// agreement + share distribution, their public keys, and the relayed
/// (still encrypted) shares + clear commitments.
pub(crate) struct SecAggSetup {
    /// sorted clients that completed BOTH setup phases — the masking
    /// participant set of the round
    pub(crate) participants: Vec<String>,
    /// participant -> hex DH public key
    pub(crate) keys: BTreeMap<String, String>,
    pub(crate) keys_json: Json,
    /// dealer -> recipient -> hex ciphertext (end-to-end encrypted)
    pub(crate) enc_shares: BTreeMap<String, BTreeMap<String, String>>,
    /// dealer -> recipient -> hex share commitment
    pub(crate) commits: BTreeMap<String, BTreeMap<String, String>>,
    /// resolved t of the t-of-n recovery (what the dealers split with)
    pub(crate) threshold: usize,
}

/// Run the two secagg setup phases before a learn dispatch:
///
/// 1. `fact_keys` — every cohort client posts its per-round DH public
///    key (validated here, so a malformed key fails fast).
/// 2. `fact_shares` — every key-poster Shamir-splits its round secret at
///    the resolved threshold and returns one end-to-end encrypted share
///    per peer plus a clear commitment per share.  The coordinator
///    relays ciphertext it cannot read — holding `t` *readable* shares
///    would let it reconstruct any client's masks.
///
/// Clients whose phase task errors — or misses the participation
/// deadline, when one is configured — are excluded from the masking
/// participant set (they never derived the round's pair masks).
/// Without a deadline, a client that hangs past the round timeout
/// stalls the task like any other task.
///
/// Each completed phase is persisted to the round store (`KeysCollected`
/// / `SharesDealt`) so a resumed round can skip straight to learn.
pub(crate) fn secagg_setup_phases(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    cohort: &[String],
    round_id: u64,
) -> Result<SecAggSetup> {
    let wm = ctx.wm;
    let privacy = ctx.privacy;
    let participation = ctx.participation;
    let timeout = ctx.timeout;
    let metrics = ctx.metrics;
    // setup phases want EVERY response but must not wait on a hung
    // client forever: under a participation deadline, close at the
    // deadline and exclude whoever had not answered (the straggler
    // tolerance the learn phase already has)
    let run_phase = |dict: BTreeMap<String, Json>,
                     func: &str|
     -> Result<Vec<crate::dart::scheduler::TaskResult>> {
        match participation {
            Some(p) if p.deadline_ms > 0 => {
                let expected = dict.len();
                Ok(wm
                    .run_task_quorum(
                        dict,
                        func,
                        expected, // close only when everyone reported...
                        Duration::from_millis(p.deadline_ms),
                        Duration::ZERO,
                    )?
                    .results) // ...or at the deadline, with whoever did
            }
            _ => wm.run_task(dict, func, timeout),
        }
    };
    let rid_hex = round_id_to_hex(round_id);
    // phase 1: key agreement
    let ksw = Stopwatch::start();
    let kspan = telemetry::child_of_current(phase::KEYS);
    let kguard = kspan.enter();
    let kctx = kspan.context();
    let dict: BTreeMap<String, Json> = cohort
        .iter()
        .map(|c| {
            (
                c.clone(),
                telemetry::inject(
                    Json::obj().set("round_id", rid_hex.as_str()),
                    kctx,
                ),
            )
        })
        .collect();
    let results = run_phase(dict, "fact_keys")?;
    for r in &results {
        telemetry::absorb_echo(ctx.tele, &r.result, round_id);
    }
    let mut pubkeys: BTreeMap<String, String> = BTreeMap::new();
    for r in &results {
        if let Some(hex) = r.result.get("pubkey").and_then(Json::as_str) {
            // a malformed or degenerate key excludes THAT client from the
            // round (like a missing response) — it must not abort the
            // whole training session
            match keys::parse_pubkey_hex(hex) {
                Ok(_) => {
                    // lowercase: the reconstruction integrity check
                    // compares against regenerated (lowercase) hex
                    pubkeys.insert(r.device_name.clone(), hex.to_lowercase());
                }
                Err(e) => {
                    metrics.counter("fact.secagg.bad_keys").inc();
                    log::warn!(target: "fact::server",
                        "cluster {}: '{}' posted an invalid DH key ({e}) \
                         — excluded from the round",
                        cluster.id, r.device_name);
                }
            }
        }
    }
    if pubkeys.len() < 2 {
        return Err(FedError::Privacy(format!(
            "cluster {}: only {} client(s) completed secagg key agreement \
             (need >= 2)",
            cluster.id,
            pubkeys.len()
        )));
    }
    if pubkeys.len() > 255 {
        // GF(256) share x-coordinates are 1-based u8 positions: index
        // 255 would wrap to x = 0 (the secret itself), so the holder
        // list caps at 255 participants
        return Err(FedError::Privacy(format!(
            "cluster {}: {} secagg participants exceed the 255-participant \
             limit of GF(256) share coordinates — shard the cohort",
            cluster.id,
            pubkeys.len()
        )));
    }
    let threshold =
        resolve_reveal_threshold(privacy.reveal_threshold, pubkeys.len());
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::KeysCollected { pubkeys: pubkeys.clone(), threshold },
    ))?;
    drop(kguard);
    ctx.phase_ms(phase::KEYS, cluster.id, ksw.elapsed_ms());
    kspan.finish();
    let mut keys_json = Json::obj();
    for (name, hex) in &pubkeys {
        keys_json = keys_json.set(name, hex.as_str());
    }
    if pubkeys.len() < 3 {
        // a 2-client round has a single share holder per dealer — below
        // any meaningful threshold (t >= 2).  Skip share dealing and
        // rely on direct reveals, the pre-threshold recovery path.
        let participants: Vec<String> = pubkeys.keys().cloned().collect();
        return Ok(SecAggSetup {
            participants,
            keys: pubkeys,
            keys_json,
            enc_shares: BTreeMap::new(),
            commits: BTreeMap::new(),
            threshold,
        });
    }
    // phase 2: encrypted share distribution among the key posters
    let ssw = Stopwatch::start();
    let sspan = telemetry::child_of_current(phase::SHARES);
    let sguard = sspan.enter();
    let sctx = sspan.context();
    let dict: BTreeMap<String, Json> = pubkeys
        .keys()
        .map(|c| {
            (
                c.clone(),
                telemetry::inject(
                    Json::obj()
                        .set("round_id", rid_hex.as_str())
                        .set("keys", keys_json.clone())
                        .set("threshold", threshold),
                    sctx,
                ),
            )
        })
        .collect();
    let results = run_phase(dict, "fact_shares")?;
    for r in &results {
        telemetry::absorb_echo(ctx.tele, &r.result, round_id);
    }
    let mut enc_shares = BTreeMap::new();
    let mut commits = BTreeMap::new();
    for r in &results {
        let (Some(shares), Some(cs)) = (
            r.result.get("shares").and_then(Json::as_obj),
            r.result.get("commits").and_then(Json::as_obj),
        ) else {
            continue;
        };
        let to_map = |obj: &BTreeMap<String, Json>| -> BTreeMap<String, String> {
            obj.iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect()
        };
        enc_shares.insert(r.device_name.clone(), to_map(shares));
        commits.insert(r.device_name.clone(), to_map(cs));
    }
    let participants: Vec<String> = enc_shares.keys().cloned().collect();
    if participants.len() < 2 {
        return Err(FedError::Privacy(format!(
            "cluster {}: only {} client(s) dealt secagg shares (need >= 2)",
            cluster.id,
            participants.len()
        )));
    }
    if participants.len() < cohort.len() {
        metrics
            .counter("fact.secagg.setup_dropouts")
            .add((cohort.len() - participants.len()) as u64);
    }
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::SharesDealt {
            participants: participants.clone(),
            enc_shares: enc_shares.clone(),
            commits: commits.clone(),
        },
    ))?;
    drop(sguard);
    ctx.phase_ms(phase::SHARES, cluster.id, ssw.elapsed_ms());
    sspan.finish();
    Ok(SecAggSetup {
        participants,
        keys: pubkeys,
        keys_json,
        enc_shares,
        commits,
        threshold,
    })
}

/// Outcome of [`secagg_recover_aggregate`]: `target` is `None` when the
/// round was unrecoverable and the `proceed` policy voided it.
pub(crate) struct SecAggOutcome {
    pub(crate) target: Option<Vec<f32>>,
    pub(crate) audit: SecAggAudit,
}

/// Secure-aggregation server path for one round: every masking
/// participant that answered is a survivor, everyone else dropped
/// mid-round (under partial participation the cohort — not the whole
/// cluster — was sampled first, so a straggler cut off at the deadline is
/// recovered exactly like a crash).  Recovery is **threshold-based**:
///
/// * each responsive survivor reveals its own DH-derived pair seed with
///   every dropped peer (covering its own pairs), and its decrypted
///   Shamir share of each dropped dealer's round secret;
/// * any `t` commitment-verified shares reconstruct a dropped client's
///   secret, from which the coordinator derives the pair seed with
///   *every* survivor — including survivors that never answered the
///   reveal task, the exact wedge the PR 3 all-survivors-must-reveal
///   protocol could not recover from;
/// * below `t`, [`PrivacyConfig::reveal_policy`] decides: `abort` fails
///   the session, `proceed` voids the round (audited either way).
///
/// The coordinator never materializes an unmasked individual update —
/// `unmask_aggregate` folds zero-copy views of the masked buffers
/// straight into the integer accumulator.
///
/// [`PrivacyConfig::reveal_policy`]: crate::privacy::PrivacyConfig
pub(crate) fn secagg_recover_aggregate(
    ctx: &RoundCtx<'_>,
    cluster: &crate::fact::clustering::Cluster,
    setup: &SecAggSetup,
    updates: &[ClientUpdate],
    round_id: u64,
) -> Result<SecAggOutcome> {
    let wm = ctx.wm;
    let privacy = ctx.privacy;
    let timeout = ctx.timeout;
    let metrics = ctx.metrics;
    let weighted = cluster.model.aggregation().is_weighted();
    let masked: Vec<MaskedUpdate> = updates
        .iter()
        .map(|u| MaskedUpdate {
            device: u.device.clone(),
            params: u.params.clone(),
            weight: if weighted {
                u.n_samples as f64 / privacy.weight_scale as f64
            } else {
                1.0
            },
        })
        .collect();
    let survivors: Vec<String> =
        updates.iter().map(|u| u.device.clone()).collect();
    let dropped: Vec<String> = setup
        .participants
        .iter()
        .filter(|c| !survivors.contains(c))
        .cloned()
        .collect();
    let mut audit = SecAggAudit {
        participants: setup.participants.len(),
        threshold: setup.threshold,
        dropped: dropped.clone(),
        direct_reveals: 0,
        reconstructed: Vec::new(),
        unrecovered: Vec::new(),
        policy: privacy.reveal_policy,
        outcome: "ok",
    };
    // the reveal span opens even with zero dropouts — "nothing to
    // recover" is itself a phase outcome worth a slot in the trace
    let rsw = Stopwatch::start();
    let mut rspan = telemetry::child_of_current(phase::REVEAL);
    rspan.set_attr("participants", setup.participants.len());
    rspan.set_attr("dropouts", dropped.len());
    let rguard = rspan.enter();
    let mut revealed: Vec<RevealedSeed> = Vec::new();
    if !dropped.is_empty() {
        log::info!(target: "fact::server",
            "cluster {}: {} dropout(s) in secagg round, recovering masks \
             (t={} of {})",
            cluster.id, dropped.len(), setup.threshold,
            setup.participants.len());
        metrics.counter("fact.secagg.dropouts").add(dropped.len() as u64);
        let dropped_json =
            Json::Arr(dropped.iter().cloned().map(Json::Str).collect());
        let dict: BTreeMap<String, Json> = survivors
            .iter()
            .map(|s| {
                // the encrypted shares each dropped dealer addressed to
                // this survivor, relayed for client-side decryption
                let mut shares = Json::obj();
                for d in &dropped {
                    if let Some(ct) =
                        setup.enc_shares.get(d).and_then(|m| m.get(s))
                    {
                        shares = shares.set(d, ct.as_str());
                    }
                }
                (
                    s.clone(),
                    telemetry::inject(
                        Json::obj()
                            .set("round_id", round_id_to_hex(round_id))
                            .set("dropped", dropped_json.clone())
                            .set("keys", setup.keys_json.clone())
                            .set("shares", shares),
                        telemetry::current(),
                    ),
                )
            })
            .collect();
        let reveals = wm.run_task(dict, "fact_reveal", timeout)?;
        for r in &reveals {
            telemetry::absorb_echo(ctx.tele, &r.result, round_id);
        }
        // collect direct seed reveals and decrypted shares
        let mut shares_by_dealer: BTreeMap<String, Vec<shamir::Share>> =
            BTreeMap::new();
        for r in &reveals {
            if let Some(seeds) = r.result.get("seeds").and_then(Json::as_obj) {
                for (d, hex) in seeds {
                    let Some(hex) = hex.as_str() else { continue };
                    revealed.push(RevealedSeed {
                        survivor: r.device_name.clone(),
                        dropped: d.clone(),
                        seed: seed_from_hex(hex)?,
                    });
                    audit.direct_reveals += 1;
                }
            }
            if let Some(shares) = r.result.get("shares").and_then(Json::as_obj)
            {
                for (d, hex) in shares {
                    let Some(hex) = hex.as_str() else { continue };
                    // a malformed share is discarded exactly like a
                    // commitment-failing one — one bad reveal must not
                    // abort a recovery that t other valid shares can
                    // still complete
                    let share = match from_hex(hex)
                        .ok()
                        .and_then(|b| shamir::Share::from_bytes(&b).ok())
                    {
                        Some(s) => s,
                        None => {
                            metrics
                                .counter("fact.secagg.corrupt_shares")
                                .inc();
                            log::warn!(target: "fact::server",
                                "cluster {}: malformed share of '{d}' from \
                                 '{}' — discarded",
                                cluster.id, r.device_name);
                            continue;
                        }
                    };
                    // verify against the dealer's commitment for this
                    // holder — a corrupted share must not enter the pool
                    let commit_ok = setup
                        .commits
                        .get(d)
                        .and_then(|m| m.get(&r.device_name))
                        .and_then(|c| from_hex(c).ok())
                        .map(|want| match <&[u8; 32]>::try_from(want.as_slice()) {
                            Ok(w) => shamir::verify_share(&share, w),
                            Err(_) => false,
                        })
                        .unwrap_or(false);
                    if !commit_ok {
                        metrics.counter("fact.secagg.corrupt_shares").inc();
                        log::warn!(target: "fact::server",
                            "cluster {}: share of '{d}' revealed by '{}' \
                             fails its commitment — discarded",
                            cluster.id, r.device_name);
                        continue;
                    }
                    shares_by_dealer.entry(d.clone()).or_default().push(share);
                }
            }
        }
        // per dropped dealer: direct reveals may already cover every
        // survivor; otherwise reconstruct from >= t verified shares
        for d in &dropped {
            let uncovered: Vec<String> = survivors
                .iter()
                .filter(|s| {
                    !revealed
                        .iter()
                        .any(|rv| &rv.survivor == *s && &rv.dropped == d)
                })
                .cloned()
                .collect();
            if uncovered.is_empty() {
                continue;
            }
            let shares = shares_by_dealer.get(d).map(Vec::as_slice).unwrap_or(&[]);
            if shares.len() < setup.threshold {
                audit.unrecovered.push(d.clone());
                continue;
            }
            let Some(posted) = setup.keys.get(d) else {
                audit.unrecovered.push(d.clone());
                continue;
            };
            // shared with the REST board: reconstruct + length check +
            // posted-pubkey integrity check.  A failure here (duplicate
            // coordinates, or commitment-passing shares from a lying
            // dealer that fail the pubkey check) makes THIS dealer
            // unrecoverable — the reveal policy decides the round's
            // fate, not a hard error that would bypass `proceed`.
            let secret = match crate::privacy::secagg::reconstruct_dealer_secret(
                shares,
                setup.threshold,
                posted,
                d,
            ) {
                Ok(s) => s,
                Err(e) => {
                    metrics.counter("fact.secagg.corrupt_shares").inc();
                    log::warn!(target: "fact::server",
                        "cluster {}: reconstruction of '{d}' failed ({e}) \
                         — dealer unrecoverable",
                        cluster.id);
                    audit.unrecovered.push(d.clone());
                    continue;
                }
            };
            for s in &uncovered {
                let Some(posted_pk) = setup.keys.get(s) else {
                    // a survivor that never posted a key has no pair mask
                    // with this dealer to unwind
                    continue;
                };
                let their = keys::parse_pubkey_hex(posted_pk)?;
                let shared = keys::shared_key(&secret, &their);
                revealed.push(RevealedSeed {
                    survivor: s.clone(),
                    dropped: d.clone(),
                    seed: keys::pair_seed_from_shared(&shared, round_id, s, d),
                });
            }
            audit.reconstructed.push(d.clone());
        }
        metrics
            .counter("fact.secagg.reconstructions")
            .add(audit.reconstructed.len() as u64);
        if !audit.reconstructed.is_empty() {
            audit.outcome = "recovered";
        }
        if !audit.unrecovered.is_empty() {
            metrics.counter("fact.secagg.below_threshold").inc();
            let detail = format!(
                "cluster {}: secagg round below reveal threshold t={} for \
                 {:?} ({} dropout(s), {} direct reveal(s))",
                cluster.id,
                setup.threshold,
                audit.unrecovered,
                dropped.len(),
                audit.direct_reveals,
            );
            match privacy.reveal_policy {
                RevealPolicy::Abort => {
                    audit.outcome = "aborted";
                    return Err(FedError::Privacy(format!(
                        "{detail} — reveal policy abort"
                    )));
                }
                RevealPolicy::Proceed => {
                    audit.outcome = "skipped";
                    return Ok(SecAggOutcome { target: None, audit });
                }
            }
        }
    }
    drop(rguard);
    rspan.set_attr("outcome", audit.outcome);
    ctx.phase_ms(phase::REVEAL, cluster.id, rsw.elapsed_ms());
    rspan.finish();
    let usw = Stopwatch::start();
    let mut uspan = telemetry::child_of_current(phase::UNMASK_AGGREGATE);
    uspan.set_attr("mode", "secagg");
    let _uguard = uspan.enter();
    let target = unmask_aggregate(&masked, &revealed, privacy.frac_bits)?;
    ctx.phase_ms(phase::UNMASK_AGGREGATE, cluster.id, usw.elapsed_ms());
    Ok(SecAggOutcome { target: Some(target), audit })
}
