//! The typed context each round stage consumes — one bundle of
//! per-session invariants instead of a dozen parameters threaded
//! through every stage signature.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use crate::config::ParticipationConfig;
use crate::coordinator::latency::LatencyTracker;
use crate::coordinator::round_store::{RoundState, RoundStore};
use crate::coordinator::workflow::WorkflowManager;
use crate::error::FedError;
use crate::fact::model::Hyper;
use crate::fact::rounds::optimizer::ServerOptimizer;
use crate::fact::rounds::strategy::LocalStrategy;
use crate::fact::server::RoundRecord;
use crate::fact::stopping::FlStoppingCriterion;
use crate::metrics::Registry;
use crate::privacy::PrivacyConfig;
use crate::telemetry;
use crate::util::pool::ThreadPool;

/// Outcome of one cluster's training session: everything that completed
/// plus the first error.  Completed rounds ride OUTSIDE the error so a
/// failure in round k never discards rounds 0..k — those aggregates were
/// already applied to the cluster and must still be charged to the DP
/// ledger.
pub(crate) struct ClusterOutcome {
    /// Audit records of every completed round, in order.
    pub(crate) records: Vec<RoundRecord>,
    /// Per-client latest (clear) update vectors, for clustering input.
    pub(crate) latest: BTreeMap<String, Vec<f32>>,
    /// Per-client reported sample counts, for weighted sampling.
    pub(crate) samples: BTreeMap<String, f64>,
    /// First error the round loop hit, if any.
    pub(crate) err: Option<FedError>,
}

/// The per-session invariants every cluster's round loop reads — one
/// bundle instead of a dozen parameters threaded through two signatures
/// and the dispatch closure (future round-loop features extend this
/// struct, not every call site).
pub(crate) struct RoundCtx<'a> {
    pub(crate) wm: &'a WorkflowManager,
    pub(crate) hyper: &'a Hyper,
    /// server-side update rule applied to every round's aggregate
    pub(crate) server_opt: &'a dyn ServerOptimizer,
    /// local-training variant negotiated into every learn dict
    pub(crate) strategy: LocalStrategy,
    pub(crate) fl_stop: &'a dyn FlStoppingCriterion,
    pub(crate) timeout: Duration,
    pub(crate) clustering_round: usize,
    pub(crate) pool: &'a ThreadPool,
    pub(crate) privacy: &'a PrivacyConfig,
    pub(crate) participation: &'a Option<ParticipationConfig>,
    pub(crate) known_samples: &'a BTreeMap<String, f64>,
    pub(crate) metrics: &'a Registry,
    /// observed learn latencies feeding `effective_deadline_explained`
    pub(crate) latency: &'a LatencyTracker,
    pub(crate) session_tag: u64,
    /// every round transition is appended (and validated) here
    pub(crate) store: &'a Arc<dyn RoundStore>,
    /// rounds the store already closed — skipped outright
    pub(crate) completed: &'a BTreeSet<(usize, usize, usize)>,
    /// in-flight rounds to resume instead of starting fresh
    pub(crate) plans: &'a BTreeMap<(usize, usize, usize), RoundState>,
    /// flight recorder the round's spans and events land in
    pub(crate) tele: &'a Arc<telemetry::Recorder>,
}

impl RoundCtx<'_> {
    /// Record one finished phase's wall time into the labeled histogram
    /// behind `fact.round.phase_ms{phase,cluster}` (surfaced by
    /// `/rounds/recovery` and the Prometheus exposition).
    pub(crate) fn phase_ms(&self, name: &str, cluster_id: usize, ms: f64) {
        self.metrics
            .histogram_labeled(
                "fact.round.phase_ms",
                &[("phase", name), ("cluster", &cluster_id.to_string())],
            )
            .observe(ms);
    }
}
