//! The round pipeline driver: sequences the named stages in
//! `super::phases` over a [`RoundCtx`], driven by the round-store state
//! machine — per round index it skips what the store already closed,
//! resumes what it holds in flight, and runs everything else fresh.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::coordinator::participation::CohortSampler;
use crate::coordinator::round_store::{
    now_ms, EventKind, RoundEvent, RoundPhase, RoundState,
};
use crate::error::{FedError, Result};
use crate::fact::aggregation::ClientUpdate;
use crate::fact::rounds::ctx::{ClusterOutcome, RoundCtx};
use crate::fact::rounds::optimizer::OptState;
use crate::fact::rounds::phases::{
    dispatch_learn, draw_cohort, finish_round, repair_cohort,
    secagg_setup_phases, SecAggSetup,
};
use crate::fact::server::RoundRecord;
use crate::json::Json;
use crate::privacy::{round_id_to_hex, RevealPolicy};
use crate::telemetry::{self, phase};
use crate::util::rng::splitmix64;
use crate::util::Stopwatch;

/// Alg 5: the training session of one cluster.
pub(crate) fn train_cluster(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
) -> ClusterOutcome {
    let mut records = Vec::new();
    let mut latest = BTreeMap::new();
    let mut samples = BTreeMap::new();
    let err =
        train_cluster_rounds(ctx, cluster, &mut records, &mut latest, &mut samples)
            .err();
    ClusterOutcome { records, latest, samples, err }
}

/// The round loop behind [`train_cluster`]: per round index, skip what
/// the store already closed, resume what it holds in flight, and run
/// everything else fresh.  Completed rounds accumulate into the
/// out-params so they survive an error return.
pub(crate) fn train_cluster_rounds(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    let mut round = 0usize;
    loop {
        let key = (ctx.clustering_round, cluster.id, round);
        if ctx.completed.contains(&key) {
            // replayed by recover(): params + loss history were already
            // fast-forwarded and the record is back in the history
        } else if let Some(plan) = ctx.plans.get(&key) {
            resume_round(ctx, cluster, round, plan, records, latest, seen_samples)?;
        } else {
            fresh_round(ctx, cluster, round, records, latest, seen_samples)?;
        }
        round += 1;
        // Alg 5 line 7: stopping criterion.
        if ctx.fl_stop.should_stop(round, &cluster.loss_history) {
            break;
        }
    }
    Ok(())
}

/// A round with no prior history in the store: derive its id, persist
/// the opening `Configured` event, and run the full pipeline.
fn fresh_round(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    round: usize,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    let sw = Stopwatch::start();
    // privacy negotiation: the round's mode and a fresh round id ride in
    // every learn task; clients transform their update accordingly.
    // Derived before anything else so the round's root span carries it.
    let round_id = splitmix64(
        ctx.session_tag
            ^ ((ctx.clustering_round as u64) << 42)
            ^ ((cluster.id as u64) << 21)
            ^ round as u64,
    );
    let mut root = telemetry::Span::root(ctx.tele, phase::ROUND, round_id);
    root.set_attr("cluster", cluster.id);
    root.set_attr("round", round);
    root.set_attr("clustering_round", ctx.clustering_round);
    root.set_attr("mode", ctx.privacy.mode.as_str());
    let _root_guard = root.enter();
    // --- participation: draw this round's cohort (everyone without) --
    let (cohort, realized_q, sampler) = {
        let span = telemetry::child_of_current(phase::DRAW_COHORT);
        let _g = span.enter();
        let psw = Stopwatch::start();
        let out = draw_cohort(ctx, cluster, round, seen_samples);
        ctx.phase_ms(phase::DRAW_COHORT, cluster.id, psw.elapsed_ms());
        out
    };
    // Alg 5 line 3 prep: the global parameters are materialized into ONE
    // shared buffer; every client's dict holds a cheap clone of it, and
    // the binary wire encoding writes it once (envelope dedup) instead
    // of one base64 copy per client.
    let global = crate::util::tensorbuf::TensorBuf::from_f32_slice(&cluster.params);
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::Configured {
            clustering_round: ctx.clustering_round,
            cluster_id: cluster.id,
            round,
            cohort: cohort.clone(),
            sample_rate: realized_q,
            mode: ctx.privacy.mode.as_str().to_string(),
            params: global.clone(),
            deadline_ms: ctx
                .participation
                .as_ref()
                .map(|p| p.deadline_ms)
                .unwrap_or(0),
            session_tag: ctx.session_tag,
        },
    ))?;
    // self-healing: members the scheduler already knows are dead get
    // replaced from the unsampled pool before any phase addresses them
    let (cohort, realized_q) =
        repair_cohort(ctx, cluster, round, round_id, cohort, realized_q, sampler.as_ref())?;
    run_round_pipeline(
        ctx,
        cluster,
        round,
        round_id,
        &cohort,
        realized_q,
        sampler.as_ref(),
        &global,
        sw,
        None,
        records,
        latest,
        seen_samples,
    )
}

/// Resume one in-flight round from its persisted state: fast-forward
/// what already happened, re-run only what the crash interrupted.
/// Client-side key/mask/noise derivation is deterministic in
/// `(round_id, device)`, so a re-run phase reproduces byte-identical
/// contributions and the resumed aggregate equals the uninterrupted one.
fn resume_round(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    round: usize,
    plan: &RoundState,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    let sw = Stopwatch::start();
    let round_id = plan.round_id;
    // a resumed round gets a fresh trace (the pre-crash spans, if any,
    // were replayed from trace.jsonl under their own trace id)
    let mut root = telemetry::Span::root(ctx.tele, phase::ROUND, round_id);
    root.set_attr("cluster", cluster.id);
    root.set_attr("round", round);
    root.set_attr("clustering_round", ctx.clustering_round);
    root.set_attr("mode", ctx.privacy.mode.as_str());
    root.set_attr("resumed", true);
    root.set_attr("from_phase", plan.phase.as_str());
    let _root_guard = root.enter();
    log::info!(target: "fact::server",
        "cluster {} round {round}: resuming from round store at phase '{}'",
        cluster.id, plan.phase.as_str());
    // the config the round was persisted under must still hold
    if plan.mode != ctx.privacy.mode.as_str() {
        return void_round(
            ctx,
            round_id,
            format!(
                "privacy mode changed across restart ('{}' -> '{}')",
                plan.mode,
                ctx.privacy.mode.as_str()
            ),
        );
    }
    if let Some(p) = &plan.params {
        if p.len() != cluster.params.len() {
            return void_round(
                ctx,
                round_id,
                format!(
                    "broadcast params len {} no longer matches the cluster ({})",
                    p.len(),
                    cluster.params.len()
                ),
            );
        }
    }
    let cohort = plan.cohort.clone();
    let realized_q = plan.sample_rate;
    let sampler = ctx
        .participation
        .as_ref()
        .map(|p| CohortSampler::new(p.clone()));
    let global = plan.params.clone().unwrap_or_else(|| {
        crate::util::tensorbuf::TensorBuf::from_f32_slice(&cluster.params)
    });
    match plan.phase {
        RoundPhase::Aggregated => {
            // the aggregate was applied and its post-apply params AND
            // optimizer state pinned pre-crash: make both effective
            // (plain replacement — exact under any server optimizer)
            // and close
            if let Some(pa) = &plan.params_after {
                if pa.len() == cluster.params.len() {
                    cluster.params = pa.to_vec();
                }
            }
            if let Some(oj) = &plan.opt_state {
                if let Ok(st) = OptState::from_json(oj) {
                    cluster.opt_state = st;
                }
            }
            if let Some(rj) = &plan.record {
                if let Ok(rec) = RoundRecord::from_json(rj) {
                    cluster.loss_history.push(rec.mean_loss);
                    records.push(rec);
                }
            }
            ctx.store
                .append(RoundEvent::new(round_id, EventKind::Closed))?;
            Ok(())
        }
        RoundPhase::Learn | RoundPhase::Reveal if !plan.updates.is_empty() => {
            // learn already closed: the collected (still masked) updates
            // are in the WAL — redo recovery + aggregation without
            // touching the cohort's learn tasks
            let setup = setup_from_plan(plan);
            let updates: Vec<ClientUpdate> = plan
                .updates
                .iter()
                .map(|u| ClientUpdate {
                    device: u.device.clone(),
                    params: u.params.clone(),
                    n_samples: u.n_samples,
                    loss: u.loss,
                    duration: u.duration,
                    tau: u.tau,
                })
                .collect();
            let sampled = plan.addressed.len().max(updates.len());
            finish_round(
                ctx,
                cluster,
                round,
                round_id,
                realized_q,
                sampled,
                plan.late,
                plan.dropped.len(),
                setup.as_ref(),
                updates,
                sw,
                records,
                latest,
                seen_samples,
            )
        }
        RoundPhase::Reveal => {
            // a Revealed event without a persisted LearnClosed should not
            // occur; refuse to guess at the missing updates
            void_round(
                ctx,
                round_id,
                "reveal phase without persisted updates".into(),
            )
        }
        RoundPhase::Learn => {
            // dispatched, never closed: honor the part of the deadline
            // that elapsed while the coordinator was down
            let now = now_ms();
            let deadline_at =
                plan.dispatched_at_ms.saturating_add(plan.learn_deadline_ms);
            if plan.learn_deadline_ms > 0 && now >= deadline_at {
                ctx.metrics.counter("fact.roundstore.voided").inc();
                log::warn!(target: "fact::server",
                    "cluster {} round {round}: learn deadline elapsed \
                     during the outage — voiding",
                    cluster.id);
                ctx.store.append(RoundEvent::new(
                    round_id,
                    EventKind::Voided {
                        reason: "learn deadline elapsed during coordinator \
                                 outage"
                            .into(),
                        record: Json::Null,
                    },
                ))?;
                return Ok(());
            }
            let remaining = if plan.learn_deadline_ms > 0 {
                Some(Duration::from_millis(deadline_at - now))
            } else {
                None
            };
            let setup = setup_from_plan(plan);
            let (updates, sampled, late, dropped) = dispatch_learn(
                ctx,
                cluster,
                round,
                round_id,
                &cohort,
                sampler.as_ref(),
                &global,
                setup.as_ref(),
                remaining,
            )?;
            finish_round(
                ctx,
                cluster,
                round,
                round_id,
                realized_q,
                sampled,
                late,
                dropped,
                setup.as_ref(),
                updates,
                sw,
                records,
                latest,
                seen_samples,
            )
        }
        _ => {
            // Configured / Keys / Shares: re-run the setup phases against
            // the pinned cohort + params.  Clients re-derive keys, masks
            // and noise deterministically from the same round id, so the
            // re-run reproduces the dead coordinator's round exactly.
            //
            // Before share dealing the cohort is still repairable: members
            // that died across the outage are replaced now (the repair is
            // evented, so a second resume replays the repaired cohort).
            let (cohort, realized_q) =
                if matches!(plan.phase, RoundPhase::Configured | RoundPhase::Keys) {
                    repair_cohort(
                        ctx,
                        cluster,
                        round,
                        round_id,
                        cohort,
                        realized_q,
                        sampler.as_ref(),
                    )?
                } else {
                    (cohort, realized_q)
                };
            run_round_pipeline(
                ctx,
                cluster,
                round,
                round_id,
                &cohort,
                realized_q,
                sampler.as_ref(),
                &global,
                sw,
                None,
                records,
                latest,
                seen_samples,
            )
        }
    }
}

/// Abandon a round that cannot be safely resumed: persist the `Voided`
/// event, then let [`RevealPolicy`] decide whether the session survives
/// (`proceed`) or fails loudly (`abort`, the default).
fn void_round(ctx: &RoundCtx<'_>, round_id: u64, reason: String) -> Result<()> {
    ctx.metrics.counter("fact.roundstore.voided").inc();
    log::warn!(target: "fact::server",
        "voiding round {}: {reason}", round_id_to_hex(round_id));
    ctx.store.append(RoundEvent::new(
        round_id,
        EventKind::Voided {
            reason: reason.clone(),
            record: Json::Null,
        },
    ))?;
    match ctx.privacy.reveal_policy {
        RevealPolicy::Abort => Err(FedError::Privacy(format!(
            "cannot resume round {}: {reason} — reveal policy abort",
            round_id_to_hex(round_id)
        ))),
        RevealPolicy::Proceed => Ok(()),
    }
}

/// Rebuild the secagg setup snapshot from persisted round state (`None`
/// when the round ran without secure aggregation).
fn setup_from_plan(plan: &RoundState) -> Option<SecAggSetup> {
    if plan.pubkeys.is_empty() {
        return None;
    }
    let mut keys_json = Json::obj();
    for (name, hex) in &plan.pubkeys {
        keys_json = keys_json.set(name, hex.as_str());
    }
    Some(SecAggSetup {
        participants: plan.participants.clone(),
        keys: plan.pubkeys.clone(),
        keys_json,
        enc_shares: plan.enc_shares.clone(),
        commits: plan.commits.clone(),
        threshold: plan.threshold,
    })
}

/// The setup -> learn -> recover -> aggregate pipeline of one round,
/// entered either fresh (setup still to run) or on resume with the
/// persisted setup already rebuilt (`setup_done`).
#[allow(clippy::too_many_arguments)]
fn run_round_pipeline(
    ctx: &RoundCtx<'_>,
    cluster: &mut crate::fact::clustering::Cluster,
    round: usize,
    round_id: u64,
    cohort: &[String],
    realized_q: f64,
    sampler: Option<&CohortSampler>,
    global: &crate::util::tensorbuf::TensorBuf,
    sw: Stopwatch,
    setup_done: Option<Option<SecAggSetup>>,
    records: &mut Vec<RoundRecord>,
    latest: &mut BTreeMap<String, Vec<f32>>,
    seen_samples: &mut BTreeMap<String, f64>,
) -> Result<()> {
    // secagg setup phases: per-pair key agreement + encrypted Shamir
    // share distribution run BEFORE the learn dispatch (clients that
    // fail either phase are excluded from the masking participant set)
    let secagg_setup = match setup_done {
        Some(setup) => setup,
        None => {
            if ctx.privacy.mode.has_secagg() {
                Some(secagg_setup_phases(ctx, cluster, cohort, round_id)?)
            } else {
                None
            }
        }
    };
    let (updates, sampled, late, dropped) = dispatch_learn(
        ctx,
        cluster,
        round,
        round_id,
        cohort,
        sampler,
        global,
        secagg_setup.as_ref(),
        None,
    )?;
    finish_round(
        ctx,
        cluster,
        round,
        round_id,
        realized_q,
        sampled,
        late,
        dropped,
        secagg_setup.as_ref(),
        updates,
        sw,
        records,
        latest,
        seen_samples,
    )
}
